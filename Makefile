# HexGen reproduction — top-level targets.

# Lower the demo model to HLO-text artifacts + weights + manifest
# (requires JAX; the Rust reference backend does not need this). The
# output lands in rust/artifacts/ — where the tests (CARGO_MANIFEST_DIR)
# and benches (package-root cwd) look for it.
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

# Regenerate the checked-in reference-backend parity fixture.
fixture:
	cd python && python -m compile.make_ref_fixture \
		--out-dir ../rust/tests/fixtures/ref_demo

build:
	cargo build --release

test:
	cargo test -q

# Continuous vs static batching on the serving path (runs over the
# checked-in fixture model; no artifacts needed).
bench-batching:
	cargo bench -p hexgen --bench batching

.PHONY: artifacts fixture build test bench-batching
