# HexGen reproduction — top-level targets.

# Lower the demo model to HLO-text artifacts + weights + manifest
# (requires JAX; the Rust reference backend does not need this). The
# output lands in rust/artifacts/ — where the tests (CARGO_MANIFEST_DIR)
# and benches (package-root cwd) look for it.
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

# Regenerate the checked-in reference-backend parity fixture.
fixture:
	cd python && python -m compile.make_ref_fixture \
		--out-dir ../rust/tests/fixtures/ref_demo
	cd python && python -m compile.make_ref_fixture \
		--out-dir ../rust/tests/fixtures/ref_demo --draft

build:
	cargo build --release

test:
	cargo test -q

# Continuous vs static batching on the serving path (runs over the
# checked-in fixture model; no artifacts needed).
bench-batching:
	cargo bench -p hexgen --bench batching

# Decode hot-path microbenchmark: in-place caches + threaded TP shards +
# tiled matmul vs the seed's functional baseline, over a synthetic model
# (tp x bucket sweep). Writes machine-readable BENCH_decode.json at the
# repo root — the tracked perf baseline (CI runs the quick variant and
# uploads the JSON as an artifact).
bench-decode:
	cargo bench -p hexgen --bench decode

bench-decode-quick:
	cargo bench -p hexgen --bench decode -- --quick

# Close the plan→serve loop end-to-end on the checked-in fixture model:
# schedule the §3.1 case-study pool (small search budget), emit the
# deployment plan, then boot the live service from it with the reference
# backend; then boot the checked-in v2 mixed-role plan, where a
# prefill-only replica hands block-granular KV segments to a decode-only
# replica. This is the CI smoke test.
PLAN_FILE ?= /tmp/hexgen-plan.json
plan-serve:
	cargo run --release -p hexgen -- schedule --cluster case-study \
		--population 4 --iterations 6 --patience 3 \
		--fitness-requests 40 --emit-plan $(PLAN_FILE)
	cargo run --release -p hexgen -- serve --plan $(PLAN_FILE) \
		--artifacts rust/tests/fixtures/ref_demo \
		--prompt "the quick brown fox" --max-new 8
	cargo run --release -p hexgen -- serve \
		--plan rust/tests/fixtures/plan_golden_v2.json \
		--artifacts rust/tests/fixtures/ref_demo \
		--prompt "the quick brown fox" --max-new 8

# Boot `serve --listen` on an ephemeral port against the checked-in
# fixture, run a streaming + a non-streaming completion through the HTTP
# front-end, and assert token parity with the blocking generate() path —
# then repeat under a fixed-seed fault plan and assert the SSE stream
# surfaces `retrying` before completing with the same tokens.
serve-smoke: build
	bash scripts/serve_smoke.sh

# The fault-tolerance chaos suite (see rust/README.md "Fault
# tolerance"): failover golden parity, retry-budget exhaustion,
# breaker quarantine/recovery, deadline expiry, seeded fault storm.
chaos:
	cargo test --release -p hexgen --test service_e2e chaos_

# Project-invariant static analysis over rust/src (serving-path panic
# freedom, hot-path allocation freedom, lock discipline). Zero external
# deps; see rust/README.md "Correctness tooling" for the rule catalog.
lint:
	cargo xtask lint

# ThreadSanitizer over the concurrency-heavy integration tests. Needs a
# nightly toolchain with the rust-src component (TSan instruments std
# via -Zbuild-std).
TSAN_TARGET ?= x86_64-unknown-linux-gnu
tsan:
	RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Zbuild-std \
		--target $(TSAN_TARGET) -p hexgen \
		--test service_e2e --test http_streaming --test reference_parity

# Miri over the unit tests that exercise raw indexing arithmetic and the
# sync primitives (full integration tests are too slow under Miri).
# Needs: rustup +nightly component add miri.
miri:
	MIRIFLAGS="-Zmiri-disable-isolation" cargo +nightly miri test \
		-p hexgen --lib -- util:: runtime::weights

.PHONY: artifacts fixture build test bench-batching bench-decode bench-decode-quick plan-serve serve-smoke chaos lint tsan miri
