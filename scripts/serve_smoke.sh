#!/usr/bin/env bash
# serve-smoke: boot `hexgen serve --listen` on an ephemeral port against
# the checked-in fixture model, run a streaming and a non-streaming
# completion through the HTTP front-end, and assert token parity with the
# blocking one-shot `generate()` path — then boot again under a
# fixed-seed fault plan and assert the SSE stream surfaces `retrying`
# before completing with the same tokens. Run via `make serve-smoke`.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${BIN:-target/release/hexgen}
FIXTURE=rust/tests/fixtures/ref_demo
PROMPT="serve smoke prompt"
MAX_NEW=6
LOG=$(mktemp)
FLOG=$(mktemp)
FAULT_PLAN=$(mktemp)
cleanup() {
    if [ -n "${SERVER_PID:-}" ]; then
        kill "$SERVER_PID" 2>/dev/null || true
    fi
    if [ -n "${FAULT_PID:-}" ]; then
        kill "$FAULT_PID" 2>/dev/null || true
    fi
    rm -f "$LOG" "$FLOG" "$FAULT_PLAN"
}
trap cleanup EXIT

[ -x "$BIN" ] || { echo "binary $BIN missing — run 'make build' first" >&2; exit 1; }

# 1) Reference tokens from the blocking generate() path (one-shot serve).
REF=$("$BIN" serve --artifacts "$FIXTURE" --replicas 1 \
        --prompt "$PROMPT" --max-new "$MAX_NEW" | sed -n 's/^tokens   : //p')
[ -n "$REF" ] || { echo "one-shot serve printed no tokens" >&2; exit 1; }
echo "blocking generate() tokens: $REF"

# 2) Long-running HTTP front-end on an ephemeral port.
"$BIN" serve --artifacts "$FIXTURE" --replicas 1 --listen 127.0.0.1:0 >"$LOG" 2>&1 &
SERVER_PID=$!
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's|^listening on http://||p' "$LOG" | head -n1)
    [ -n "$ADDR" ] && break
    kill -0 "$SERVER_PID" 2>/dev/null || { echo "server died:" >&2; cat "$LOG" >&2; exit 1; }
    sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never reported its address:" >&2; cat "$LOG" >&2; exit 1; }
echo "server up at $ADDR"

curl -fsS "http://$ADDR/healthz" >/dev/null
curl -fsS "http://$ADDR/metrics" >/dev/null
curl -fsS "http://$ADDR/v1/plan" >/dev/null

NONSTREAM=$(curl -fsS -X POST "http://$ADDR/v1/completions" \
    -d "{\"prompt\": \"$PROMPT\", \"max_new\": $MAX_NEW}")
STREAM=$(curl -fsS -N -X POST "http://$ADDR/v1/completions" \
    -d "{\"prompt\": \"$PROMPT\", \"max_new\": $MAX_NEW, \"stream\": true}")

python3 - "$REF" "$NONSTREAM" "$STREAM" <<'EOF'
import json
import sys

ref = json.loads(sys.argv[1])                 # "[1, 2, 3]" printed by one-shot serve
nonstream = json.loads(sys.argv[2])["tokens"]

stream_tokens, event, saw_done_after_token = [], None, False
for line in sys.argv[3].splitlines():
    if line.startswith("event: "):
        event = line[len("event: "):].strip()
    elif line.startswith("data: "):
        data = json.loads(line[len("data: "):])
        if event == "token":
            stream_tokens.append(data["token"])
        elif event == "done":
            saw_done_after_token = bool(stream_tokens)

assert nonstream == ref, f"non-streaming HTTP diverged: {nonstream} != {ref}"
assert stream_tokens == ref, f"SSE stream diverged: {stream_tokens} != {ref}"
assert saw_done_after_token, "done event must follow the token events"
print(f"serve-smoke OK: {len(ref)} tokens, parity across generate()/HTTP/SSE: {ref}")
EOF

kill "$SERVER_PID" 2>/dev/null || true
SERVER_PID=""

# 3) Fault-storm leg: a fixed-seed plan errors the replica's first
#    decode call, so the request faults mid-stream, fails over (the
#    sole replica is re-dispatched once the fault is consumed), and
#    completes. The SSE stream must surface `retrying` and still end
#    with the undisturbed run's exact tokens.
cat >"$FAULT_PLAN" <<'JSON'
{
  "seed": 7,
  "faults": [
    {"replica": 0, "op": "decode", "nth": 1, "kind": "error",
     "message": "smoke storm"}
  ]
}
JSON
"$BIN" serve --artifacts "$FIXTURE" --replicas 1 --listen 127.0.0.1:0 \
    --fault-plan "$FAULT_PLAN" --max-retries 3 >"$FLOG" 2>&1 &
FAULT_PID=$!
FADDR=""
for _ in $(seq 1 100); do
    FADDR=$(sed -n 's|^listening on http://||p' "$FLOG" | head -n1)
    [ -n "$FADDR" ] && break
    kill -0 "$FAULT_PID" 2>/dev/null || { echo "fault-plan server died:" >&2; cat "$FLOG" >&2; exit 1; }
    sleep 0.1
done
[ -n "$FADDR" ] || { echo "fault-plan server never reported its address:" >&2; cat "$FLOG" >&2; exit 1; }
echo "fault-plan server up at $FADDR"

FSTREAM=$(curl -fsS -N -X POST "http://$FADDR/v1/completions" \
    -d "{\"prompt\": \"$PROMPT\", \"max_new\": $MAX_NEW, \"stream\": true}")
FMETRICS=$(curl -fsS "http://$FADDR/metrics")

python3 - "$REF" "$FSTREAM" "$FMETRICS" <<'EOF'
import json
import sys

ref = json.loads(sys.argv[1])

tokens, events, event = [], [], None
for line in sys.argv[2].splitlines():
    if line.startswith("event: "):
        event = line[len("event: "):].strip()
        events.append(event)
    elif line.startswith("data: ") and event == "token":
        tokens.append(json.loads(line[len("data: "):])["token"])

assert "retrying" in events, f"SSE never surfaced the failover: {events}"
assert events.index("retrying") < events.index("done"), f"retrying must precede done: {events}"
assert tokens == ref, f"failover broke token parity: {tokens} != {ref}"

m = json.loads(sys.argv[3])
reqs = m["requests"]
assert reqs["retries"] >= 1, f"metrics never counted the retry: {reqs}"
assert reqs["requests_lost"] == 0, f"the request must not be lost: {reqs}"
print(f"fault-storm OK: retrying surfaced, {len(ref)} tokens byte-identical across failover")
EOF
