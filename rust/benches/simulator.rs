//! Simulator benchmarks: cost-model evaluation, batch timing, and
//! end-to-end discrete-event throughput (events/s) — the inner loop of
//! every figure and of the GA's fitness function.

use std::time::Duration;

use hexgen::cluster;
use hexgen::costmodel::{CostModel, InferenceTask, Phase};
use hexgen::model::ModelSpec;
use hexgen::parallelism::{Deployment, Pipeline, Stage};
use hexgen::simulator::{batch_timing, simulate, SimConfig};
use hexgen::workload::{LengthDist, WorkloadSpec};

fn main() {
    let budget = Duration::from_millis(800);
    let m = ModelSpec::llama2_70b();
    let c = cluster::heterogeneous_full_price();
    let cm = CostModel::new(&c, &m);

    hexgen::util::bench::group("cost model primitives");
    let t = InferenceTask::new(4, 256, 64);
    let tp_group: Vec<usize> = (0..8).collect();
    hexgen::util::bench::bench("comp_cost/tp8", 10, budget, || {
        std::hint::black_box(cm.comp_cost(&tp_group, 40, &t, Phase::Both));
    });
    hexgen::util::bench::bench("comm_tp_cost/tp8", 10, budget, || {
        std::hint::black_box(cm.comm_tp_cost(&tp_group, 40, &t, Phase::Both));
    });
    let next: Vec<usize> = (16..24).collect();
    hexgen::util::bench::bench("comm_pp_cost/8x8", 10, budget, || {
        std::hint::black_box(cm.comm_pp_cost(&tp_group, &next, &t, Phase::Both));
    });

    let stages: Vec<(Vec<usize>, usize)> = vec![
        ((0..8).collect(), 40),
        ((16..22).collect(), 24),
        ((38..42).collect(), 16),
    ];
    hexgen::util::bench::bench("pipeline_cost/3stage", 10, budget, || {
        std::hint::black_box(cm.pipeline_cost(&stages, &t, Phase::Both));
    });
    hexgen::util::bench::bench("batch_timing/3stage", 10, budget, || {
        std::hint::black_box(batch_timing(&cm, &stages, &t, false));
    });

    hexgen::util::bench::group("discrete-event simulation");
    let deployment = Deployment {
        pipelines: (0..4)
            .map(|i| Pipeline {
                stages: vec![Stage { devices: (i * 8..i * 8 + 8).collect(), layers: 80 }],
            })
            .collect(),
    };
    for n in [200usize, 1000, 5000] {
        let trace = WorkloadSpec {
            rate: 4.0,
            num_requests: n,
            lengths: LengthDist::LmsysLike { s_out: 32 },
            seed: 5,
        }
        .generate();
        let r = hexgen::util::bench::bench(
            &format!("simulate/{n}req-4replica"),
            2,
            budget,
            || {
                std::hint::black_box(simulate(&cm, &deployment, &trace, &SimConfig::default()));
            },
        );
        let req_per_sec = n as f64 / r.mean_secs();
        println!("    → {req_per_sec:.0} simulated requests/s");
    }

    hexgen::util::bench::group("workload generation");
    hexgen::util::bench::bench("poisson-trace/10k", 2, budget, || {
        std::hint::black_box(
            WorkloadSpec {
                rate: 4.0,
                num_requests: 10_000,
                lengths: LengthDist::LmsysLike { s_out: 64 },
                seed: 6,
            }
            .generate(),
        );
    });
}
