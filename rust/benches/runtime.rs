//! Runtime benchmarks over an artifacts directory: per-stage execute
//! latency, all-reduce, and whole prefill/decode steps across plan
//! shapes, on this build's default execution backend (PJRT with
//! `--features pjrt`, pure-Rust reference otherwise). Skipped (with a
//! message) when artifacts are not built.

use std::path::PathBuf;
use std::time::Duration;

use hexgen::coordinator::{all_reduce_sum, plan_from_strategy, CommStats, PipelineExecutor};
use hexgen::runtime::{load_backend, tokenizer, BackendKind, InputArg, Tensor};

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ not built — run `make artifacts` first; skipping runtime benches");
        return;
    }
    let budget = Duration::from_millis(1000);

    let rt = load_backend(BackendKind::default(), &dir).unwrap();
    hexgen::util::bench::group(&format!("stage executions on '{}' backend (b=1)", rt.name()));
    let info = rt.manifest().model.clone();
    let x_prefill = Tensor {
        dims: vec![1, info.prompt_len, info.hidden],
        data: vec![0.1; info.prompt_len * info.hidden],
    };
    let ln = rt.weights().get("layers.0.ln1").unwrap().clone();
    for tp in [1usize, 2, 4] {
        let wq = rt.weights().get(&shard("wq", tp)).unwrap().clone();
        let wk = rt.weights().get(&shard("wk", tp)).unwrap().clone();
        let wv = rt.weights().get(&shard("wv", tp)).unwrap().clone();
        let wo = rt.weights().get(&shard("wo", tp)).unwrap().clone();
        let name = format!("attn_prefill_tp{tp}_b1");
        let args = [
            InputArg::F32(&x_prefill),
            InputArg::F32(&ln),
            InputArg::F32(&wq),
            InputArg::F32(&wk),
            InputArg::F32(&wv),
            InputArg::F32(&wo),
        ];
        // warm any backend-side compile cache outside the timed region
        rt.execute(&name, &args).unwrap();
        hexgen::util::bench::bench(&format!("attn_prefill/tp{tp}"), 3, budget, || {
            std::hint::black_box(rt.execute(&name, &args).unwrap());
        });
    }

    hexgen::util::bench::group("host collectives");
    let parts: Vec<Tensor> = (0..4)
        .map(|_| Tensor {
            dims: vec![1, info.prompt_len, info.hidden],
            data: vec![0.25; info.prompt_len * info.hidden],
        })
        .collect();
    hexgen::util::bench::bench("all_reduce_sum/4x(32x128)", 5, budget, || {
        let mut stats = CommStats::default();
        std::hint::black_box(all_reduce_sum(parts.clone(), &mut stats));
    });

    hexgen::util::bench::group("end-to-end generation (prefill + 4 decode steps)");
    let prompt = tokenizer::encode("benchmark prompt for the demo model", info.prompt_len);
    for (name, tps, layers) in [
        ("tp1-fused-stage", vec![1usize], vec![6usize]),
        ("tp2-pp2-asym", vec![2, 1], vec![4, 2]),
        ("tp1-pp2", vec![1, 1], vec![3, 3]),
    ] {
        let exec =
            PipelineExecutor::new(&dir, plan_from_strategy(&tps, &layers).unwrap()).unwrap();
        let _ = exec.generate(&[prompt.clone()], 2).unwrap(); // warm compile
        hexgen::util::bench::bench(
            &format!("generate/{name}"),
            1,
            Duration::from_millis(2500),
            || {
                std::hint::black_box(exec.generate(&[prompt.clone()], 4).unwrap());
            },
        );
    }
}

fn shard(w: &str, tp: usize) -> String {
    if tp == 1 {
        format!("layers.0.{w}")
    } else {
        format!("layers.0.{w}.tp{tp}.r0")
    }
}
