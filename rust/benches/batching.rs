//! Continuous vs static batching on the serving path.
//!
//! Replays a staggered-arrival, mixed-`max_new` workload through the full
//! threaded service twice — once with run-to-completion batching
//! (`BatchPolicy.continuous = false`, the pre-iteration-level baseline)
//! and once with continuous batching — and reports mean/p50 latency and
//! delivered tokens/s. Runs over the checked-in fixture model, so it
//! needs no artifacts directory:
//!
//! ```bash
//! cargo bench --bench batching          # or: make bench-batching
//! ```
//!
//! The workload alternates short (2-token) and long (8-token) requests:
//! under static batching a short row's slot idles until its co-batched
//! long neighbour drains, and every queued request waits for the whole
//! batch; continuous batching retires the short row at its own limit and
//! admits the next request at the following decode-step boundary.

use std::path::PathBuf;
use std::time::Duration;

use hexgen::coordinator::{
    collect_all, plan_from_strategy, BatchPolicy, FaultPolicy, GenRequest, HexGenService,
    RoutePolicy, ServiceConfig,
};
use hexgen::runtime::BackendKind;
use hexgen::util::stats::Summary;

const REQUESTS: usize = 200;
const STAGGER: Duration = Duration::from_micros(50);

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo")
}

struct RunStats {
    mean_latency: f64,
    p50_latency: f64,
    tokens_per_sec: f64,
    wall: f64,
}

fn run(continuous: bool) -> RunStats {
    let cfg = ServiceConfig {
        artifacts_dir: fixture_dir(),
        backend: BackendKind::Reference,
        replicas: vec![plan_from_strategy(&[1], &[2]).unwrap()],
        batch: BatchPolicy { max_batch: 2, window: Duration::from_millis(1), continuous },
        route: RoutePolicy::RoundRobin,
        speeds: None,
        prefill_speeds: None,
        roles: Vec::new(),
        adapt_speeds: true,
        max_new_tokens: 8,
        stop_token: None,
        kv: Default::default(),
        spec: None,
        faults: FaultPolicy::default(),
    };
    let service = HexGenService::start(cfg).unwrap();

    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        // Mixed per-request limits: a short row next to a long one is
        // exactly where run-to-completion batching wastes slot time.
        let max_new = if i % 2 == 0 { 2 } else { 8 };
        let req = GenRequest::new(format!("bench request {i}")).with_max_new(max_new);
        handles.push(service.submit(req));
        std::thread::sleep(STAGGER);
    }
    let results = collect_all(handles, Duration::from_secs(600));
    let wall = t0.elapsed().as_secs_f64();
    service.shutdown();

    let mut latencies = Vec::with_capacity(REQUESTS);
    let mut tokens = 0usize;
    for r in &results {
        let c = r.as_ref().expect("bench request failed");
        latencies.push(c.latency);
        tokens += c.tokens.len();
    }
    let s = Summary::from_samples(&latencies).expect("no samples");
    RunStats {
        mean_latency: s.mean,
        p50_latency: s.p50,
        tokens_per_sec: tokens as f64 / wall,
        wall,
    }
}

fn report(name: &str, s: &RunStats) {
    println!(
        "{name:<28} mean {:>8.2}ms  p50 {:>8.2}ms  {:>9.0} tok/s  (wall {:.2}s)",
        s.mean_latency * 1e3,
        s.p50_latency * 1e3,
        s.tokens_per_sec,
        s.wall
    );
}

fn main() {
    hexgen::util::bench::group(&format!(
        "serving {REQUESTS} staggered requests (max_new 2/8 alternating, 1 replica, 2 slots)"
    ));
    // Warm both paths once so neither pays first-touch costs in the
    // measured run.
    let _ = run(false);
    let _ = run(true);
    let stat = run(false);
    let cont = run(true);
    report("static run-to-completion", &stat);
    report("continuous batching", &cont);
    println!(
        "continuous vs static: {:.2}x mean latency, {:.2}x tokens/s",
        stat.mean_latency / cont.mean_latency,
        cont.tokens_per_sec / stat.tokens_per_sec
    );
}
