//! Decode hot-path microbenchmark: the in-place/threaded/tiled decode
//! path vs the seed's functional baseline, with machine-readable output.
//!
//! Runs a synthetic model (large enough that KV-cache traffic matters;
//! the checked-in 2-layer fixture is too small to resolve the clone
//! cost) through a full-batch [`DecodeSession`] per config and measures:
//!
//! * decode tokens/s and per-step p50/p99 latency,
//! * prefill tokens/s,
//! * the same numbers over [`FunctionalBackend`] — the exact seed
//!   semantics (two full cache clones + two full returned copies per
//!   shard per layer per token, serial TP shards) — and the speedup.
//!
//! A paged-KV section reports what the block pool buys on top: admitted
//! concurrent sessions per GB of KV memory (peak blocks actually used
//! vs the dense max_seq footprint every slot used to pin) and a
//! shared-prefix workload — the same prompt admitted across all slots —
//! measuring the prefix-cache prefill speedup and block dedup.
//!
//! A disaggregated-serving section compares the fused (hybrid) path —
//! prefill and decode on one session — against the split path: prefill
//! on one session, block-granular KV export/import, decode on another.
//! It reports TTFT, decode TPOT, the hand-off latency, and the KV bytes
//! shipped per batch, asserting greedy-token parity between the two
//! paths. A prefill-skip probe pins the full-prefix-hit TTFT win: a
//! prompt re-admitted while a live row still holds its blocks must skip
//! the prefill forward pass and beat a cold admission.
//!
//! A speculative-decoding section pairs a 1-layer draft with the
//! 4-layer target, both carrying "successor-chain" weights (the argmax
//! provably walks `t → t+1`, so draft/target agreement — and thus the
//! acceptance rate — is pinned at 1.0 by construction while every
//! matmul still runs at full shape). It reports net tokens/s vs plain
//! greedy on the same target, the acceptance rate, and per-round
//! p50/p99, asserting token parity and a > 1x net speedup at tp=2,
//! where one batched verify pass amortizes the per-step gather/scatter
//! and TP thread-spawn overheads over k+1 positions.
//!
//! Configs sweep `tp ∈ {1, 2} × bucket ∈ {1, 4, 8}`; the headline number
//! is `(tp=2, bucket=8)`. Results are printed and written as JSON to
//! `BENCH_decode.json` at the repository root (override with `--out`),
//! so CI can track the perf trajectory as an artifact:
//!
//! ```bash
//! make bench-decode          # full run
//! make bench-decode-quick    # CI variant (fewer steps)
//! ```
//!
//! [`DecodeSession`]: hexgen::coordinator::DecodeSession
//! [`FunctionalBackend`]: hexgen::runtime::FunctionalBackend

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use hexgen::coordinator::{plan_from_strategy, PipelineExecutor, SlotRequest};
use hexgen::runtime::{
    ExecutionBackend, FunctionalBackend, Manifest, ReferenceBackend, Tensor, WeightStore,
};
use hexgen::util::json::Json;
use hexgen::util::rng::Xoshiro256pp;
use hexgen::util::stats::percentile;

const LAYERS: usize = 4;
const HIDDEN: usize = 64;
const HEADS: usize = 8;
const HEAD_DIM: usize = 8;
const FFN: usize = 256;
const VOCAB: usize = 256;
const PROMPT_LEN: usize = 16;
const MAX_SEQ: usize = 160;
const TPS: [usize; 2] = [1, 2];
const BUCKETS: [usize; 3] = [1, 4, 8];
/// Decode iterations measured per config (the quick CI variant quarters
/// this). Positions advance identically for both paths, so per-step
/// attention depth — which grows with position — stays comparable.
const STEPS: usize = 64;
const WARMUP_STEPS: usize = 2;

fn synthetic_manifest() -> Manifest {
    let text = format!(
        r#"{{
          "model": {{"name":"bench-decode","layers":{LAYERS},"hidden":{HIDDEN},
                    "heads":{HEADS},"vocab":{VOCAB},"prompt_len":{PROMPT_LEN},
                    "max_seq":{MAX_SEQ},"head_dim":{HEAD_DIM},"ffn":{FFN}}},
          "tp_degrees":[1,2],
          "batch_buckets":[1,4,8],
          "weight_order":[],
          "artifacts":{{}}
        }}"#
    );
    Manifest::parse(&text).expect("synthetic manifest")
}

fn rand_tensor(rng: &mut Xoshiro256pp, dims: Vec<usize>) -> Tensor {
    let n: usize = dims.iter().product();
    // Small weights keep activations bounded over many layers.
    let data = (0..n).map(|_| (rng.next_f64() * 0.2 - 0.1) as f32).collect();
    Tensor { dims, data }
}

fn ones(dims: Vec<usize>) -> Tensor {
    let n: usize = dims.iter().product();
    Tensor { dims, data: vec![1.0; n] }
}

/// Deterministic synthetic weights for every TP degree the sweep uses.
fn synthetic_weights() -> WeightStore {
    let mut rng = Xoshiro256pp::seed_from_u64(0xDEC0DE);
    let mut ws = WeightStore::default();
    ws.insert("embed", rand_tensor(&mut rng, vec![VOCAB, HIDDEN]));
    ws.insert("final_ln", ones(vec![HIDDEN]));
    ws.insert("lm_head", rand_tensor(&mut rng, vec![HIDDEN, VOCAB]));
    for layer in 0..LAYERS {
        ws.insert(format!("layers.{layer}.ln1"), ones(vec![HIDDEN]));
        ws.insert(format!("layers.{layer}.ln2"), ones(vec![HIDDEN]));
        for tp in TPS {
            let hs = HEADS / tp * HEAD_DIM;
            let fs = FFN / tp;
            for rank in 0..tp {
                for (w, dims) in [
                    ("wq", vec![HIDDEN, hs]),
                    ("wk", vec![HIDDEN, hs]),
                    ("wv", vec![HIDDEN, hs]),
                    ("wo", vec![hs, HIDDEN]),
                    ("w1", vec![HIDDEN, fs]),
                    ("w2", vec![fs, HIDDEN]),
                ] {
                    ws.insert(
                        WeightStore::shard_name(layer, w, tp, rank),
                        rand_tensor(&mut rng, dims),
                    );
                }
            }
        }
    }
    ws
}

struct RunStats {
    decode_tok_s: f64,
    step_p50_ms: f64,
    step_p99_ms: f64,
    prefill_tok_s: f64,
    /// High-water mark of KV blocks the whole run actually pinned.
    kv_blocks_peak: usize,
    /// KV rows per block in the session under test.
    block_tokens: usize,
}

fn run_config(exec: &PipelineExecutor, bucket: usize, steps: usize) -> RunStats {
    let m = exec.manifest().model.clone();
    let mut session = exec.new_session(bucket).expect("session");
    let reqs: Vec<(usize, SlotRequest)> = (0..bucket)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..m.prompt_len).map(|j| ((i * 31 + j * 7) % 255 + 1) as i32).collect();
            // Rows stay active for the whole measured run and retire on
            // the final step.
            (i, SlotRequest { prompt, max_new: WARMUP_STEPS + steps + 1, stop: None })
        })
        .collect();
    let t0 = Instant::now();
    let out = session.prefill_into_slots(reqs).expect("prefill");
    let prefill_s = t0.elapsed().as_secs_f64();
    assert!(out.finished.is_empty());
    for _ in 0..WARMUP_STEPS {
        session.decode_step().expect("warmup step");
    }
    let mut samples = Vec::with_capacity(steps);
    for _ in 0..steps {
        let t = Instant::now();
        let out = session.decode_step().expect("decode step");
        samples.push(t.elapsed().as_secs_f64());
        assert_eq!(out.tokens.len(), bucket, "every row decodes each step");
    }
    assert_eq!(session.active(), 0, "rows retire on the final measured step");
    let total: f64 = samples.iter().sum();
    RunStats {
        decode_tok_s: (bucket * steps) as f64 / total,
        step_p50_ms: percentile(&samples, 0.50) * 1e3,
        step_p99_ms: percentile(&samples, 0.99) * 1e3,
        prefill_tok_s: (bucket * m.prompt_len) as f64 / prefill_s,
        kv_blocks_peak: session.kv_blocks_peak(),
        block_tokens: session.block_tokens(),
    }
}

/// Bytes of KV storage one block pins across all layers and both K/V
/// tensors (f32).
fn block_bytes(block_tokens: usize) -> usize {
    2 * LAYERS * HEADS * block_tokens * HEAD_DIM * 4
}

struct SharedPrefixStats {
    distinct_prefill_tok_s: f64,
    shared_prefill_tok_s: f64,
    /// Blocks pinned right after admitting the full batch.
    distinct_blocks: usize,
    shared_blocks: usize,
    prefix_cache_hits: u64,
}

/// Admit a full batch of identical prompts vs distinct prompts and
/// measure prefill throughput and the blocks each admission pins: the
/// shared batch resolves all but the first row from the prefix cache
/// (no KV hand-off copies, deduped prompt blocks).
fn measure_shared_prefix(exec: &PipelineExecutor, bucket: usize, iters: usize) -> SharedPrefixStats {
    let m = exec.manifest().model.clone();
    let reqs_for = |shared: bool| -> Vec<(usize, SlotRequest)> {
        (0..bucket)
            .map(|i| {
                let salt = if shared { 0 } else { i * 31 };
                let prompt: Vec<i32> =
                    (0..m.prompt_len).map(|j| ((salt + j * 7) % 255 + 1) as i32).collect();
                (i, SlotRequest { prompt, max_new: 2, stop: None })
            })
            .collect()
    };
    let mut run = |shared: bool| -> (f64, usize, u64) {
        let mut total = 0.0;
        let mut blocks = 0;
        let mut hits = 0;
        for _ in 0..iters {
            let mut session = exec.new_session(bucket).expect("session");
            let t0 = Instant::now();
            session.prefill_into_slots(reqs_for(shared)).expect("prefill");
            total += t0.elapsed().as_secs_f64();
            blocks = session.kv_blocks_used();
            hits = session.prefix_cache_hits();
        }
        ((iters * bucket * m.prompt_len) as f64 / total, blocks, hits)
    };
    let (distinct_prefill_tok_s, distinct_blocks, _) = run(false);
    let (shared_prefill_tok_s, shared_blocks, prefix_cache_hits) = run(true);
    SharedPrefixStats {
        distinct_prefill_tok_s,
        shared_prefill_tok_s,
        distinct_blocks,
        shared_blocks,
        prefix_cache_hits,
    }
}

struct DisaggStats {
    hybrid_ttft_ms: f64,
    hybrid_tpot_ms: f64,
    disagg_ttft_ms: f64,
    disagg_tpot_ms: f64,
    /// Export + retire + import for the whole batch, per iteration.
    handoff_ms: f64,
    /// KV bytes shipped prefill→decode per iteration (whole batch).
    kv_transfer_bytes: f64,
    kv_transfers: usize,
}

/// Fused (hybrid) serving vs disaggregated serving over the same batch:
/// the hybrid session prefills and decodes in place; the disaggregated
/// pair prefills on one session, exports each row as a [`KvSegment`],
/// retires the prefill slot, imports into a second session, and decodes
/// there. Both sessions run the same plan so the TPOT delta isolates
/// the hand-off itself. The first token streams from the prefill side
/// before the hand-off (as the service does), so TTFT is measured to
/// the end of prefill on both paths. Greedy token streams must match.
///
/// [`KvSegment`]: hexgen::coordinator::KvSegment
fn measure_disagg(
    exec: &PipelineExecutor,
    bucket: usize,
    steps: usize,
    iters: usize,
) -> DisaggStats {
    let m = exec.manifest().model.clone();
    let reqs = || -> Vec<(usize, SlotRequest)> {
        (0..bucket)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..m.prompt_len).map(|j| ((i * 17 + j * 11) % 255 + 1) as i32).collect();
                (i, SlotRequest { prompt, max_new: steps + 1, stop: None })
            })
            .collect()
    };
    let mut hybrid_ttft = 0.0;
    let mut hybrid_samples = Vec::with_capacity(iters * steps);
    let mut hybrid_tokens: Vec<Vec<i32>> = vec![Vec::new(); bucket];
    for it in 0..iters {
        let mut session = exec.new_session(bucket).expect("hybrid session");
        let t0 = Instant::now();
        let out = session.prefill_into_slots(reqs()).expect("hybrid prefill");
        hybrid_ttft += t0.elapsed().as_secs_f64();
        if it == 0 {
            for &(s, tok) in &out.tokens {
                hybrid_tokens[s].push(tok);
            }
        }
        for _ in 0..steps {
            let t = Instant::now();
            let out = session.decode_step().expect("hybrid decode");
            hybrid_samples.push(t.elapsed().as_secs_f64());
            assert_eq!(out.tokens.len(), bucket);
            if it == 0 {
                for &(s, tok) in &out.tokens {
                    hybrid_tokens[s].push(tok);
                }
            }
        }
        assert_eq!(session.active(), 0);
    }
    let mut disagg_ttft = 0.0;
    let mut handoff = 0.0;
    let mut disagg_samples = Vec::with_capacity(iters * steps);
    let mut disagg_tokens: Vec<Vec<i32>> = vec![Vec::new(); bucket];
    let mut kv_bytes = 0.0;
    let mut kv_transfers = 0usize;
    for it in 0..iters {
        let mut prefiller = exec.new_session(bucket).expect("prefill session");
        let mut decoder = exec.new_session(bucket).expect("decode session");
        let t0 = Instant::now();
        let out = prefiller.prefill_into_slots(reqs()).expect("disagg prefill");
        disagg_ttft += t0.elapsed().as_secs_f64();
        if it == 0 {
            for &(s, tok) in &out.tokens {
                disagg_tokens[s].push(tok);
            }
        }
        let t1 = Instant::now();
        for slot in 0..bucket {
            let seg = prefiller.export_rows(slot).expect("export");
            prefiller.cancel_slot(slot).expect("retire prefill slot");
            decoder.import_rows(slot, &seg, steps + 1, None).expect("import");
        }
        handoff += t1.elapsed().as_secs_f64();
        let comm = prefiller.take_comm();
        kv_bytes += comm.kv_transfer_bytes;
        kv_transfers += comm.kv_transfers;
        for _ in 0..steps {
            let t = Instant::now();
            let out = decoder.decode_step().expect("disagg decode");
            disagg_samples.push(t.elapsed().as_secs_f64());
            assert_eq!(out.tokens.len(), bucket);
            if it == 0 {
                for &(s, tok) in &out.tokens {
                    disagg_tokens[s].push(tok);
                }
            }
        }
        assert_eq!(decoder.active(), 0);
    }
    assert_eq!(
        hybrid_tokens, disagg_tokens,
        "disaggregated decode must reproduce the hybrid greedy streams"
    );
    DisaggStats {
        hybrid_ttft_ms: hybrid_ttft / iters as f64 * 1e3,
        hybrid_tpot_ms: percentile(&hybrid_samples, 0.50) * 1e3,
        disagg_ttft_ms: disagg_ttft / iters as f64 * 1e3,
        disagg_tpot_ms: percentile(&disagg_samples, 0.50) * 1e3,
        handoff_ms: handoff / iters as f64 * 1e3,
        kv_transfer_bytes: kv_bytes / iters as f64,
        kv_transfers: kv_transfers / iters,
    }
}

struct PrefillSkipStats {
    /// Fastest cold admission (full prefill forward pass), ms.
    cold_ttft_ms: f64,
    /// Fastest full-prefix-hit admission (forward pass skipped), ms.
    skip_ttft_ms: f64,
    skips: usize,
}

/// Pin the prefill-compute skip: an anchor row computes a prompt once
/// (memoizing its first token) and stays active so its blocks — and the
/// prefix-cache entries they carry — remain live. Re-admitting the same
/// prompt then skips the forward pass entirely, while a distinct prompt
/// (whose blocks free on retirement each round) recomputes every time.
/// Min-of-iters TTFTs make the comparison robust to scheduler noise.
fn measure_prefill_skip(exec: &PipelineExecutor, iters: usize) -> PrefillSkipStats {
    let m = exec.manifest().model.clone();
    let shared: Vec<i32> = (0..m.prompt_len).map(|j| ((j * 13) % 255 + 1) as i32).collect();
    let distinct: Vec<i32> = (0..m.prompt_len).map(|j| ((j * 29 + 5) % 255 + 1) as i32).collect();
    assert_ne!(shared, distinct);
    let mut session = exec.new_session(4).expect("session");
    let out = session
        .prefill_into_slots(vec![(
            0,
            SlotRequest { prompt: shared.clone(), max_new: 2, stop: None },
        )])
        .expect("anchor prefill");
    let anchor_tok = out.tokens[0].1;
    let mut cold = f64::INFINITY;
    let mut skip = f64::INFINITY;
    for _ in 0..iters {
        let t = Instant::now();
        let out = session
            .prefill_into_slots(vec![(
                1,
                SlotRequest { prompt: distinct.clone(), max_new: 1, stop: None },
            )])
            .expect("cold prefill");
        cold = cold.min(t.elapsed().as_secs_f64());
        assert_eq!(out.finished.len(), 1, "max_new=1 rows finish at prefill");

        let t = Instant::now();
        let out = session
            .prefill_into_slots(vec![(
                1,
                SlotRequest { prompt: shared.clone(), max_new: 1, stop: None },
            )])
            .expect("probe prefill");
        skip = skip.min(t.elapsed().as_secs_f64());
        assert_eq!(out.finished.len(), 1);
        assert_eq!(out.finished[0].1, vec![anchor_tok], "memoized first token must match");
    }
    let skips = session.prefill_skips();
    assert_eq!(skips, iters, "every shared-prefix probe must skip the prefill forward pass");
    assert!(
        skip < cold,
        "skipped admission ({:.1}us) must beat a cold prefill ({:.1}us)",
        skip * 1e6,
        cold * 1e6
    );
    PrefillSkipStats { cold_ttft_ms: cold * 1e3, skip_ttft_ms: skip * 1e3, skips }
}

// ---- speculative decoding: draft-propose / target-verify ---------------

const SPEC_K: usize = 3;
const SPEC_DRAFT_LAYERS: usize = 1;
const SPEC_DRAFT_HIDDEN: usize = 16;
const SPEC_DRAFT_HEADS: usize = 2;
const SPEC_DRAFT_FFN: usize = 64;

/// ±1 code vector for token `t`, length `h` (a multiple of 16): the
/// 8-bit token id and its bit-complement, tiled. Every 16-lane group
/// holds exactly 8 positive lanes, so all codes share one norm, and
/// distinct tokens differ in ≥ 2 lanes per group — `code(a)·code(a)`
/// beats every `code(a)·code(b)` by the Hamming gap.
fn successor_code(t: usize, h: usize) -> Vec<f32> {
    (0..h)
        .map(|i| {
            let bit = (t >> (i % 8)) & 1;
            let bit = if i % 16 < 8 { bit } else { 1 - bit };
            if bit == 1 {
                0.1
            } else {
                -0.1
            }
        })
        .collect()
}

/// A model that provably decodes the successor chain `t → t+1 (mod V)`:
/// `embed[t] = code(t+1)`, `lm_head[:, j] = code(j)`, every layer weight
/// zero (attention and MLP contribute exactly 0 to the residual stream
/// while still paying their full matmul/attention cost), norms all ones
/// (RMSNorm only rescales, preserving the argmax). Target and draft
/// built this way follow the *same* chain, so speculative acceptance is
/// exactly 1.0 — the bench isolates the per-round cost structure rather
/// than draft quality.
fn successor_model(
    name: &str,
    layers: usize,
    hidden: usize,
    heads: usize,
    ffn: usize,
    tps: &[usize],
) -> (Manifest, Arc<WeightStore>) {
    let head_dim = hidden / heads;
    let tps_json: Vec<String> = tps.iter().map(|t| t.to_string()).collect();
    let text = format!(
        r#"{{
          "model": {{"name":"{name}","layers":{layers},"hidden":{hidden},
                    "heads":{heads},"vocab":{VOCAB},"prompt_len":{PROMPT_LEN},
                    "max_seq":{MAX_SEQ},"head_dim":{head_dim},"ffn":{ffn}}},
          "tp_degrees":[{}],
          "batch_buckets":[1,4,8],
          "weight_order":[],
          "artifacts":{{}}
        }}"#,
        tps_json.join(",")
    );
    let manifest = Manifest::parse(&text).expect("speculative manifest");
    let mut ws = WeightStore::default();
    let mut embed = Tensor { dims: vec![VOCAB, hidden], data: vec![0.0; VOCAB * hidden] };
    let mut lm = Tensor { dims: vec![hidden, VOCAB], data: vec![0.0; hidden * VOCAB] };
    for t in 0..VOCAB {
        let succ = successor_code((t + 1) % VOCAB, hidden);
        embed.data[t * hidden..(t + 1) * hidden].copy_from_slice(&succ);
        let own = successor_code(t, hidden);
        for (i, v) in own.iter().enumerate() {
            lm.data[i * VOCAB + t] = *v;
        }
    }
    ws.insert("embed", embed);
    ws.insert("final_ln", ones(vec![hidden]));
    ws.insert("lm_head", lm);
    let zeros = |dims: Vec<usize>| {
        let n: usize = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    };
    for layer in 0..layers {
        ws.insert(format!("layers.{layer}.ln1"), ones(vec![hidden]));
        ws.insert(format!("layers.{layer}.ln2"), ones(vec![hidden]));
        for &tp in tps {
            let hs = heads / tp * head_dim;
            let fs = ffn / tp;
            for rank in 0..tp {
                for (w, dims) in [
                    ("wq", vec![hidden, hs]),
                    ("wk", vec![hidden, hs]),
                    ("wv", vec![hidden, hs]),
                    ("wo", vec![hs, hidden]),
                    ("w1", vec![hidden, fs]),
                    ("w2", vec![fs, hidden]),
                ] {
                    ws.insert(WeightStore::shard_name(layer, w, tp, rank), zeros(dims));
                }
            }
        }
    }
    (manifest, Arc::new(ws))
}

struct SpecRunStats {
    plain_tok_s: f64,
    spec_tok_s: f64,
    speedup: f64,
    acceptance: f64,
    rounds: u64,
    round_p50_ms: f64,
    round_p99_ms: f64,
}

/// Plain greedy decode vs a speculative session over the same batch and
/// the same target model; the streams must be token-identical (the
/// parity contract), and net tokens/s counts only true decode tokens
/// (prefill excluded on both paths).
fn measure_speculative(
    target: &PipelineExecutor,
    draft: &PipelineExecutor,
    bucket: usize,
    max_new: usize,
    k: usize,
) -> SpecRunStats {
    use hexgen::coordinator::SpeculativeSession;
    let m = target.manifest().model.clone();
    let reqs = || -> Vec<(usize, SlotRequest)> {
        (0..bucket)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..m.prompt_len).map(|j| ((i * 31 + j * 7) % 255 + 1) as i32).collect();
                (i, SlotRequest { prompt, max_new, stop: None })
            })
            .collect()
    };

    let mut plain_tokens: Vec<Vec<i32>> = vec![Vec::new(); bucket];
    let mut session = target.new_session(bucket).expect("plain session");
    let out = session.prefill_into_slots(reqs()).expect("plain prefill");
    for &(s, t) in &out.tokens {
        plain_tokens[s].push(t);
    }
    let t0 = Instant::now();
    while session.active() > 0 {
        let out = session.decode_step().expect("plain step");
        for &(s, t) in &out.tokens {
            plain_tokens[s].push(t);
        }
    }
    let plain_wall = t0.elapsed().as_secs_f64();

    let mut spec_tokens: Vec<Vec<i32>> = vec![Vec::new(); bucket];
    let mut spec = SpeculativeSession::new(
        target.new_session(bucket).expect("target session"),
        draft.new_session(bucket).expect("draft session"),
        k,
    )
    .expect("speculative session");
    let out = spec.admit(reqs()).expect("spec admit");
    for &(s, t) in &out.tokens {
        spec_tokens[s].push(t);
    }
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while spec.active() > 0 {
        let t = Instant::now();
        let out = spec.spec_round().expect("spec round");
        samples.push(t.elapsed().as_secs_f64());
        for &(s, t) in &out.tokens {
            spec_tokens[s].push(t);
        }
    }
    let spec_wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        spec_tokens, plain_tokens,
        "speculative decode must be token-identical to plain greedy"
    );
    let stats = spec.stats();
    let decoded = bucket * (max_new - 1);
    SpecRunStats {
        plain_tok_s: decoded as f64 / plain_wall,
        spec_tok_s: decoded as f64 / spec_wall,
        speedup: plain_wall / spec_wall,
        acceptance: stats.acceptance_rate(),
        rounds: stats.rounds,
        round_p50_ms: percentile(&samples, 0.50) * 1e3,
        round_p99_ms: percentile(&samples, 0.99) * 1e3,
    }
}

fn stats_json(s: &RunStats) -> Json {
    let mut j = Json::obj();
    j.set("decode_tok_s", Json::from(s.decode_tok_s))
        .set("step_p50_ms", Json::from(s.step_p50_ms))
        .set("step_p99_ms", Json::from(s.step_p99_ms))
        .set("prefill_tok_s", Json::from(s.prefill_tok_s))
        .set("kv_blocks_peak", Json::from(s.kv_blocks_peak));
    j
}

fn main() {
    let mut quick = false;
    let mut out_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("BENCH_decode.json");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--out" => {
                out_path = PathBuf::from(args.next().expect("--out needs a path"));
            }
            // cargo bench forwards a `--bench` flag; ignore it and
            // anything else the harness passes through.
            _ => {}
        }
    }
    let steps = if quick { STEPS / 4 } else { STEPS };

    let manifest = synthetic_manifest();
    let weights = Arc::new(synthetic_weights());

    hexgen::util::bench::group(&format!(
        "decode hot path vs functional baseline ({LAYERS} layers, hidden {HIDDEN}, \
         max_seq {MAX_SEQ}, {steps} steps/config)"
    ));
    let mut configs = Vec::new();
    let mut headline = 0.0;
    let mut headline_peak = 0usize;
    let mut headline_bt = 0usize;
    for tp in TPS {
        for bucket in BUCKETS {
            let plan = plan_from_strategy(&[tp], &[LAYERS]).expect("plan");
            let hot = PipelineExecutor::with_backend(
                Box::new(ReferenceBackend::with_weights(manifest.clone(), weights.clone())),
                plan.clone(),
            )
            .expect("hot executor");
            let base = PipelineExecutor::with_backend(
                Box::new(FunctionalBackend::new(ReferenceBackend::with_weights(
                    manifest.clone(),
                    weights.clone(),
                ))),
                plan,
            )
            .expect("baseline executor");
            assert!(hot.backend().sync_view().is_some());
            assert!(base.backend().sync_view().is_none());

            let opt = run_config(&hot, bucket, steps);
            let fun = run_config(&base, bucket, steps);
            let speedup = opt.decode_tok_s / fun.decode_tok_s;
            println!(
                "tp{tp} b{bucket}: {:>9.0} tok/s vs {:>9.0} baseline ({speedup:>5.2}x)  \
                 p50 {:.3}ms p99 {:.3}ms",
                opt.decode_tok_s, fun.decode_tok_s, opt.step_p50_ms, opt.step_p99_ms
            );
            if tp == 2 && bucket == 8 {
                headline = speedup;
                headline_peak = opt.kv_blocks_peak;
                headline_bt = opt.block_tokens;
            }
            let mut j = Json::obj();
            j.set("tp", Json::from(tp))
                .set("bucket", Json::from(bucket))
                .set("optimized", stats_json(&opt))
                .set("baseline", stats_json(&fun))
                .set("decode_speedup", Json::from(speedup));
            configs.push(j);
        }
    }
    println!("headline (tp=2, bucket=8): {headline:.2}x decode tokens/s over the seed baseline");

    // ---- paged-KV capacity and shared-prefix workload (tp=2, b=8) ------
    hexgen::util::bench::group("paged KV: capacity per GB and shared-prefix prefill");
    let headline_bucket = 8usize;
    // Per-session KV footprint: what the headline run actually pinned at
    // its peak (paged) vs the dense max_seq backing every slot used to
    // pin up front.
    let paged_session_bytes =
        headline_peak as f64 / headline_bucket as f64 * block_bytes(headline_bt) as f64;
    let dense_session_bytes = block_bytes(MAX_SEQ) as f64;
    let gb = 1e9;
    let sessions_per_gb_paged = gb / paged_session_bytes;
    let sessions_per_gb_dense = gb / dense_session_bytes;
    println!(
        "capacity: {sessions_per_gb_paged:.0} admitted sessions/GB paged vs \
         {sessions_per_gb_dense:.0} dense ({:.2}x, peak {headline_peak} blocks of \
         {headline_bt} tokens)",
        sessions_per_gb_paged / sessions_per_gb_dense
    );
    let shared_exec = PipelineExecutor::with_backend(
        Box::new(ReferenceBackend::with_weights(manifest.clone(), weights.clone())),
        plan_from_strategy(&[2], &[LAYERS]).expect("plan"),
    )
    .expect("shared-prefix executor");
    let sp = measure_shared_prefix(&shared_exec, headline_bucket, if quick { 4 } else { 16 });
    let prefill_speedup = sp.shared_prefill_tok_s / sp.distinct_prefill_tok_s;
    println!(
        "shared prefix: {:.0} prefill tok/s shared vs {:.0} distinct ({prefill_speedup:.2}x), \
         {} blocks pinned vs {} ({} prefix-cache hits)",
        sp.shared_prefill_tok_s,
        sp.distinct_prefill_tok_s,
        sp.shared_blocks,
        sp.distinct_blocks,
        sp.prefix_cache_hits
    );

    // ---- disaggregated vs hybrid serving (tp=2, b=8) -------------------
    hexgen::util::bench::group("disaggregated serving: KV hand-off vs fused prefill+decode");
    let disagg_iters = if quick { 2 } else { 4 };
    let dg = measure_disagg(&shared_exec, headline_bucket, steps, disagg_iters);
    println!(
        "hybrid:        ttft {:.3}ms  tpot p50 {:.3}ms",
        dg.hybrid_ttft_ms, dg.hybrid_tpot_ms
    );
    println!(
        "disaggregated: ttft {:.3}ms  tpot p50 {:.3}ms  handoff {:.3}ms  \
         ({} segments, {:.0} KV bytes/batch)",
        dg.disagg_ttft_ms, dg.disagg_tpot_ms, dg.handoff_ms, dg.kv_transfers, dg.kv_transfer_bytes
    );
    let sk = measure_prefill_skip(&shared_exec, if quick { 4 } else { 16 });
    println!(
        "prefill skip: {:.3}ms cold vs {:.3}ms full-prefix hit ({:.2}x, {} skips)",
        sk.cold_ttft_ms,
        sk.skip_ttft_ms,
        sk.cold_ttft_ms / sk.skip_ttft_ms,
        sk.skips
    );

    // ---- speculative decoding (draft k=3, successor-chain models) ------
    hexgen::util::bench::group(&format!(
        "speculative decoding: {SPEC_DRAFT_LAYERS}-layer h{SPEC_DRAFT_HIDDEN} draft proposing \
         k={SPEC_K} vs plain greedy on the {LAYERS}-layer target"
    ));
    let spec_new = steps;
    let (dmanifest, dweights) = successor_model(
        "bench-spec-draft",
        SPEC_DRAFT_LAYERS,
        SPEC_DRAFT_HIDDEN,
        SPEC_DRAFT_HEADS,
        SPEC_DRAFT_FFN,
        &[1],
    );
    let draft_exec = PipelineExecutor::with_backend(
        Box::new(ReferenceBackend::with_weights(dmanifest, dweights)),
        plan_from_strategy(&[1], &[SPEC_DRAFT_LAYERS]).expect("draft plan"),
    )
    .expect("draft executor");
    let mut spec_configs = Vec::new();
    let mut spec_headline = 0.0;
    for tp in TPS {
        let (tmanifest, tweights) =
            successor_model("bench-spec-target", LAYERS, HIDDEN, HEADS, FFN, &TPS);
        let target_exec = PipelineExecutor::with_backend(
            Box::new(ReferenceBackend::with_weights(tmanifest, tweights)),
            plan_from_strategy(&[tp], &[LAYERS]).expect("target plan"),
        )
        .expect("target executor");
        // Warm both paths (first-touch allocation, thread pools).
        let _ = measure_speculative(&target_exec, &draft_exec, 8, 8, SPEC_K);
        let sp = measure_speculative(&target_exec, &draft_exec, 8, spec_new, SPEC_K);
        println!(
            "tp{tp} b8: {:>9.0} tok/s speculative vs {:>9.0} plain ({:>5.2}x)  \
             acceptance {:.2}  {} rounds  round p50 {:.3}ms p99 {:.3}ms",
            sp.spec_tok_s,
            sp.plain_tok_s,
            sp.speedup,
            sp.acceptance,
            sp.rounds,
            sp.round_p50_ms,
            sp.round_p99_ms
        );
        // The successor-chain construction pins draft/target agreement;
        // anything below ~1.0 means the verify or rollback path drifted.
        assert!(sp.acceptance >= 0.9, "acceptance collapsed: {:.3}", sp.acceptance);
        if tp == 2 {
            spec_headline = sp.speedup;
            assert!(
                sp.speedup > 1.0,
                "speculative decoding must beat plain greedy at tp=2: {:.3}x",
                sp.speedup
            );
        }
        let mut j = Json::obj();
        j.set("tp", Json::from(tp))
            .set("bucket", Json::from(8usize))
            .set("plain_tok_s", Json::from(sp.plain_tok_s))
            .set("spec_tok_s", Json::from(sp.spec_tok_s))
            .set("net_speedup", Json::from(sp.speedup))
            .set("acceptance_rate", Json::from(sp.acceptance))
            .set("rounds", Json::from(sp.rounds))
            .set("round_p50_ms", Json::from(sp.round_p50_ms))
            .set("round_p99_ms", Json::from(sp.round_p99_ms));
        spec_configs.push(j);
    }
    println!("speculative headline (tp=2, b=8): {spec_headline:.2}x net tokens/s over plain greedy");

    let mut model = Json::obj();
    model
        .set("layers", Json::from(LAYERS))
        .set("hidden", Json::from(HIDDEN))
        .set("heads", Json::from(HEADS))
        .set("head_dim", Json::from(HEAD_DIM))
        .set("ffn", Json::from(FFN))
        .set("prompt_len", Json::from(PROMPT_LEN))
        .set("max_seq", Json::from(MAX_SEQ));
    let mut headline_j = Json::obj();
    headline_j
        .set("tp", Json::from(2usize))
        .set("bucket", Json::from(8usize))
        .set("decode_speedup", Json::from(headline));
    let mut shared_j = Json::obj();
    shared_j
        .set("distinct_prefill_tok_s", Json::from(sp.distinct_prefill_tok_s))
        .set("shared_prefill_tok_s", Json::from(sp.shared_prefill_tok_s))
        .set("prefill_speedup", Json::from(prefill_speedup))
        .set("distinct_blocks", Json::from(sp.distinct_blocks))
        .set("shared_blocks", Json::from(sp.shared_blocks))
        .set("prefix_cache_hits", Json::from(sp.prefix_cache_hits));
    let mut paged = Json::obj();
    paged
        .set("block_tokens", Json::from(headline_bt))
        .set("kv_blocks_peak", Json::from(headline_peak))
        .set("sessions_per_gb_paged", Json::from(sessions_per_gb_paged))
        .set("sessions_per_gb_dense", Json::from(sessions_per_gb_dense))
        .set("capacity_gain", Json::from(sessions_per_gb_paged / sessions_per_gb_dense))
        .set("shared_prefix", shared_j);
    let mut hybrid_j = Json::obj();
    hybrid_j.set("ttft_ms", Json::from(dg.hybrid_ttft_ms)).set("tpot_ms", Json::from(dg.hybrid_tpot_ms));
    let mut split_j = Json::obj();
    split_j
        .set("ttft_ms", Json::from(dg.disagg_ttft_ms))
        .set("tpot_ms", Json::from(dg.disagg_tpot_ms))
        .set("handoff_ms", Json::from(dg.handoff_ms))
        .set("kv_transfer_bytes", Json::from(dg.kv_transfer_bytes))
        .set("kv_transfers", Json::from(dg.kv_transfers));
    let mut skip_j = Json::obj();
    skip_j
        .set("cold_ttft_ms", Json::from(sk.cold_ttft_ms))
        .set("skip_ttft_ms", Json::from(sk.skip_ttft_ms))
        .set("ttft_speedup", Json::from(sk.cold_ttft_ms / sk.skip_ttft_ms))
        .set("prefill_skips", Json::from(sk.skips));
    let mut disagg_j = Json::obj();
    disagg_j
        .set("bucket", Json::from(headline_bucket))
        .set("steps", Json::from(steps))
        .set("hybrid", hybrid_j)
        .set("disaggregated", split_j)
        .set("prefill_skip", skip_j);
    let mut j = Json::obj();
    j.set("bench", Json::from("decode"))
        .set("quick", Json::from(quick))
        .set("decode_steps", Json::from(steps))
        .set("model", model)
        .set("configs", Json::Arr(configs))
        .set("headline", headline_j)
        .set("paged_kv", paged)
        .set("disaggregated_serving", disagg_j);
    let mut spec_j = Json::obj();
    spec_j
        .set("k", Json::from(SPEC_K))
        .set("max_new", Json::from(spec_new))
        .set(
            "draft",
            Json::from(format!(
                "{SPEC_DRAFT_LAYERS}l-h{SPEC_DRAFT_HIDDEN} successor-chain (tp=1)"
            )),
        )
        .set("configs", Json::Arr(spec_configs))
        .set("net_speedup", Json::from(spec_headline));
    j.set("speculative", spec_j);
    std::fs::write(&out_path, format!("{j}\n")).expect("write BENCH_decode.json");
    println!("wrote {}", out_path.display());
}
