//! Scheduler benchmarks: Algorithm-1 DP, layer partitioning, k-means
//! init, full GA iterations. The paper's headline is 2.1 min / 1.5 min
//! wall-clock to schedule the full/half-price clusters — these benches
//! track the components that budget is spent on.

use std::time::Duration;

use hexgen::cluster;
use hexgen::costmodel::{CostModel, InferenceTask};
use hexgen::model::ModelSpec;
use hexgen::scheduler::{
    kmeans, solve_dp, optimal_pipeline, GaConfig, GeneticScheduler, GroupPool,
};
use hexgen::util::bench::{bench, group};
use hexgen::util::rng::Xoshiro256pp;

fn main() {
    let budget = Duration::from_millis(800);
    let m = ModelSpec::llama2_70b();

    group("Algorithm-1 DP (solve_dp, fixed partition)");
    {
        let c = cluster::case_study();
        let cm = CostModel::new(&c, &m);
        let pool = GroupPool::new(&c, &(0..8).collect::<Vec<_>>());
        let t = InferenceTask::case_study();
        bench("dp/case-study-8gpu-3stage", 3, budget, || {
            std::hint::black_box(solve_dp(&cm, &pool, &[48, 20, 12], &t, 8, false));
        });
    }
    {
        let c = cluster::heterogeneous_full_price();
        let cm = CostModel::new(&c, &m);
        let devs: Vec<usize> = (0..16).collect(); // one Iceland 16-GPU group
        let pool = GroupPool::new(&c, &devs);
        let t = InferenceTask::new(1, 64, 32);
        bench("dp/16x3090Ti-4stage", 3, budget, || {
            std::hint::black_box(solve_dp(&cm, &pool, &[20, 20, 20, 20], &t, 8, false));
        });
    }

    group("full pipeline optimizer (S sweep + EM)");
    {
        let c = cluster::heterogeneous_full_price();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 64, 32);
        for n in [8usize, 16, 24] {
            let devs: Vec<usize> = (0..n).collect();
            bench(&format!("optimal_pipeline/{n}gpu"), 1, budget, || {
                std::hint::black_box(optimal_pipeline(&cm, &c, &devs, &t, 8, 8));
            });
        }
    }

    group("k-means initialization");
    {
        let c = cluster::heterogeneous_full_price();
        let devs = c.online_devices();
        bench("kmeans/init-58gpu", 2, budget, || {
            let mut rng = Xoshiro256pp::seed_from_u64(1);
            std::hint::black_box(kmeans::initial_groups(&c, &devs, &mut rng));
        });
    }

    group("genetic search (small budget end-to-end)");
    for (name, c) in [
        ("half-price", cluster::heterogeneous_half_price()),
        ("full-price", cluster::heterogeneous_full_price()),
    ] {
        bench(
            &format!("ga/5-iterations-{name}"),
            0,
            Duration::from_millis(1500),
            || {
                let cfg = GaConfig {
                    population: 6,
                    iterations: 5,
                    patience: 5,
                    seed: 9,
                    fitness_requests: 60,
                    ..GaConfig::default()
                };
                std::hint::black_box(GeneticScheduler::new(&c, &m, cfg).run());
            },
        );
    }
}
