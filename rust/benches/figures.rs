//! Figure-harness timing: how long each paper-experiment regeneration
//! takes at the reduced default budget. One timed run per experiment
//! (these are end-to-end sweeps, not micro-benches).

use std::time::Instant;

use hexgen::experiments;
use hexgen::util::cli::Args;

fn main() {
    // quiet, tiny budgets: this measures harness cost, not statistics
    let args = Args::parse(
        [
            "--requests".to_string(),
            "80".to_string(),
            "--population".to_string(),
            "6".to_string(),
            "--iterations".to_string(),
            "8".to_string(),
            "--patience".to_string(),
            "6".to_string(),
            "--fitness-requests".to_string(),
            "60".to_string(),
            "--rates".to_string(),
            "1".to_string(),
            "--s-out".to_string(),
            "32".to_string(),
        ]
        .into_iter(),
    );
    println!("timing each experiment harness at reduced budget:\n");
    let runs: Vec<(&str, fn(&Args) -> anyhow::Result<()>)> = vec![
        ("figure1", experiments::figure1::run),
        ("figure3", experiments::figure3::run),
        ("figure4", experiments::figure4::run),
        ("figure6", experiments::figure6::run),
        ("figure7", experiments::figure7::run),
        ("table3", experiments::table3::run),
        ("table4", experiments::table4::run),
    ];
    let mut rows = Vec::new();
    for (name, f) in runs {
        let t0 = Instant::now();
        // Swallow the harness's own stdout? No — keep it, benches are logs.
        f(&args).unwrap();
        rows.push((name, t0.elapsed().as_secs_f64()));
    }
    println!("\n== harness timing summary ==");
    for (name, secs) in rows {
        println!("{name:<10} {secs:>8.1}s");
    }
    println!("(figure2/figure5 excluded: they are figure3-shaped sweeps at 4x the points)");
}
