//! Type-level stub of the `xla` (PJRT) crate.
//!
//! Mirrors exactly the API surface `hexgen`'s PJRT backend uses —
//! `PjRtClient`, `PjRtLoadedExecutable`, `Literal`, `HloModuleProto`,
//! `XlaComputation`, `Shape` — so `cargo check --features pjrt`
//! type-checks in environments without XLA binaries. Host-side literal
//! construction works; every operation that would need the native PJRT
//! runtime returns [`Error`] at runtime. Swap this path dependency for
//! the real `xla` crate to serve on an actual PJRT client.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type; implements `std::error::Error` so callers can attach
/// `anyhow` context to it.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} requires the native PJRT runtime (build with the real `xla` crate)"
    ))
}

/// Element types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn to_f64(self) -> f64;
    fn from_f64(x: f64) -> Self;
}

impl NativeType for f32 {
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(x: f64) -> Self {
        x as f32
    }
}

impl NativeType for i32 {
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(x: f64) -> Self {
        x as i32
    }
}

/// Array shape: dimension sizes.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of a literal or computation result.
#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host tensor (stub: stores elements as f64 plus dimensions).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            data: data.iter().map(|x| x.to_f64()).collect(),
            dims: vec![data.len() as i64],
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { data: vec![x.to_f64()], dims: vec![] }
    }

    /// Reshape without changing element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n.max(1) as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {dims:?} incompatible with {} elements",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f64(x)).collect())
    }

    /// Destructure a tuple literal (stub literals are never tuples).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literals"))
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone() }))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable("HLO text parsing"))
    }
}

/// An XLA computation handle (stub).
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// PJRT client handle (stub: construction fails at runtime).
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compilation"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("buffer transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        match r.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 2]),
            other => panic!("unexpected shape {other:?}"),
        }
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7i32).to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn runtime_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }
}
