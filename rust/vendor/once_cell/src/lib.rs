//! Offline API-compatible subset of the `once_cell` crate: just
//! [`sync::Lazy`], backed by `std::sync::OnceLock`. Vendored as a
//! workspace path crate because the build environment has no network
//! registry.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access, safe to share across threads.
    ///
    /// Unlike upstream `once_cell`, the initializer is `Fn` rather than
    /// `FnOnce` (it is only ever invoked once; `Fn` keeps the cell `Sync`
    /// without interior mutability around the closure).
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }

        /// Force evaluation and return a reference to the value.
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(&this.init)
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        static N: Lazy<u64> = Lazy::new(|| 40 + 2);

        #[test]
        fn static_lazy_initializes_once() {
            assert_eq!(*N, 42);
            assert_eq!(*N, 42);
        }

        #[test]
        fn closure_lazy() {
            let l: Lazy<String, _> = Lazy::new(|| "hi".to_string());
            assert_eq!(l.len(), 2);
        }
    }
}
