//! Offline API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no network registry, so HexGen vendors the
//! slice of `anyhow` it actually uses as a workspace path crate: the
//! [`Error`] type with context chaining, the [`Result`] alias, the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!` /
//! `bail!` macros. Display follows upstream conventions: `{e}` prints the
//! outermost message, `{e:#}` prints the full `a: b: c` chain, and
//! `{e:?}` prints the message plus a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    /// Leaf message (from `anyhow!` / `Option::context`).
    Msg(String),
    /// Adopted standard error (from the blanket `From` impl).
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
    /// Context layer wrapping an earlier `Error`.
    Context { msg: String, source: Box<Error> },
}

/// A dynamically typed error with human-readable context layers.
pub struct Error(Repr);

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(Repr::Msg(message.to_string()))
    }

    /// Wrap this error in a new context layer.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error(Repr::Context { msg: context.to_string(), source: Box::new(self) })
    }

    /// The messages of every layer, outermost first.
    fn chain_strings(&self) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = self;
        loop {
            match &cur.0 {
                Repr::Msg(m) => {
                    out.push(m.clone());
                    break;
                }
                Repr::Boxed(e) => {
                    out.push(e.to_string());
                    let mut src = e.source();
                    while let Some(s) = src {
                        out.push(s.to_string());
                        src = s.source();
                    }
                    break;
                }
                Repr::Context { msg, source } => {
                    out.push(msg.clone());
                    cur = source;
                }
            }
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        if f.alternate() {
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        write!(f, "{}", chain[0])?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(Repr::Boxed(Box::new(e)))
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("loading weights").context("starting runtime");
        assert_eq!(format!("{e}"), "starting runtime");
        assert_eq!(format!("{e:#}"), "starting runtime: loading weights: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("count {n} of {}", 7);
        assert_eq!(format!("{e}"), "count 3 of 7");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(format!("{}", f(true).unwrap_err()), "boom 1");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(4).context("present").unwrap(), 4);
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "file missing");
    }
}
