//! Dynamic GPU pools (the Figure-4 flow): schedule the half-price
//! cluster, take GPUs offline, re-run the search, and compare estimated
//! SLO attainment before/after plus the re-search wall time.
//!
//! ```bash
//! cargo run --release --example dynamic_pool -- [--offline 4]
//! ```

use anyhow::Result;

use hexgen::cluster;
use hexgen::model::ModelSpec;
use hexgen::scheduler::{GaConfig, GeneticScheduler};
use hexgen::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_offline = args.get_usize("offline", 4);
    let m = ModelSpec::llama2_70b();
    let ga = GaConfig {
        population: args.get_usize("population", 10),
        iterations: args.get_usize("iterations", 25),
        patience: 10,
        seed: args.get_u64("seed", 4),
        fitness_requests: 100,
        ..GaConfig::default()
    };

    let c = cluster::heterogeneous_half_price();
    println!("initial pool: {} GPUs", c.devices.len());
    let before = GeneticScheduler::new(&c, &m, ga.clone()).run();
    println!(
        "scheduled {} replicas, est. attainment {:.3} ({:.1}s search)\n",
        before.deployment.num_replicas(),
        before.fitness,
        before.wall_time
    );
    print!("{}", before.deployment.describe(&c));

    // GPUs leave (the paper removes 4).
    let mut degraded = cluster::heterogeneous_half_price();
    let leaving: Vec<usize> = (24..24 + n_offline.min(6)).collect();
    degraded.take_offline(&leaving);
    println!("\n{} GPUs leave the pool: {leaving:?}", leaving.len());

    let t0 = std::time::Instant::now();
    let after = GeneticScheduler::new(&degraded, &m, ga).run();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "re-scheduled in {dt:.1}s (paper: <30s): {} replicas, est. attainment {:.3}",
        after.deployment.num_replicas(),
        after.fitness
    );
    print!("{}", after.deployment.describe(&degraded));
    println!(
        "\nattainment gap after churn: {:.3} (paper: 'considerably small')",
        before.fitness - after.fitness
    );
    Ok(())
}
