//! Quickstart: load the AOT-compiled demo model and generate text through
//! an asymmetric TP×PP pipeline — the minimal end-to-end path.
//!
//! ```bash
//! make artifacts            # once: python lowers the model to HLO
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use hexgen::coordinator::{plan_from_strategy, PipelineExecutor};
use hexgen::runtime::tokenizer;

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // An asymmetric plan in the paper's Appendix-F notation: two pipeline
    // stages, the first serving 4 layers at TP=2, the second 2 layers at
    // TP=1 — exactly the kind of layout symmetric systems cannot express.
    let plan = plan_from_strategy(&[2, 1], &[4, 2])?;
    let exec = PipelineExecutor::new(dir, plan)?;
    println!(
        "loaded demo model ({} layers, backend {}, strategy {})",
        exec.manifest().model.layers,
        exec.backend().name(),
        exec.strategy_string()
    );

    let prompt = "the quick brown fox jumps over the lazy dog";
    let tokens = tokenizer::encode(prompt, exec.manifest().model.prompt_len);
    let result = exec.generate(&[tokens], 12)?;

    println!("prompt : {prompt}");
    println!("tokens : {:?}", result.tokens[0]);
    println!("text   : {:?}", tokenizer::decode(&result.tokens[0]));
    println!(
        "prefill {:.1}ms | decode {:.1}ms for {} tokens ({:.1}ms/token)",
        result.prefill_seconds * 1e3,
        result.decode_seconds * 1e3,
        result.decode_steps,
        result.decode_seconds * 1e3 / result.decode_steps.max(1) as f64,
    );
    println!(
        "collectives: {} all-reduces, {} stage hand-offs",
        result.comm.allreduce_ops, result.comm.pp_sends
    );
    Ok(())
}
