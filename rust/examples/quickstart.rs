//! Quickstart: load a demo model and generate text through an asymmetric
//! TP×PP pipeline — the minimal end-to-end path.
//!
//! ```bash
//! make artifacts            # optional: python lowers the 6-layer model
//! cargo run --release --example quickstart
//! ```
//!
//! Without `artifacts/` (no JAX on the machine) this falls back to the
//! checked-in 2-layer parity fixture, which the pure-Rust reference
//! backend serves out of the box — so this example always runs (and is
//! exercised in CI).

use anyhow::Result;

use hexgen::coordinator::{plan_from_strategy, PipelineExecutor};
use hexgen::runtime::tokenizer;

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");
    let dir = if artifacts.join("manifest.json").exists() {
        artifacts
    } else {
        let fixture = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("tests/fixtures/ref_demo");
        eprintln!("artifacts/ missing — falling back to the checked-in fixture model");
        fixture
    };

    // An asymmetric plan in the paper's Appendix-F notation: two pipeline
    // stages, the first at TP=2, the second at TP=1 — exactly the kind of
    // layout symmetric systems cannot express. Stage sizes follow the
    // model's layer count (4+2 on the 6-layer demo, 1+1 on the fixture).
    let model = hexgen::runtime::Manifest::load(&dir.join("manifest.json"))?.model;
    let tail = (model.layers / 3).max(1);
    let plan = plan_from_strategy(&[2, 1], &[model.layers - tail, tail])?;
    let exec = PipelineExecutor::new(&dir, plan)?;
    println!(
        "loaded demo model ({} layers, backend {}, strategy {})",
        model.layers,
        exec.backend().name(),
        exec.strategy_string()
    );

    let prompt = "the quick brown fox jumps over the lazy dog";
    let tokens = tokenizer::encode(prompt, model.prompt_len);
    let max_new = (model.max_seq - model.prompt_len).min(12);
    let result = exec.generate(&[tokens], max_new)?;

    println!("prompt : {prompt}");
    println!("tokens : {:?}", result.tokens[0]);
    println!("text   : {:?}", tokenizer::decode(&result.tokens[0]));
    println!(
        "prefill {:.1}ms ({} token) | decode {:.1}ms over {} iterations ({:.1}ms/token)",
        result.prefill_seconds * 1e3,
        result.prefill_tokens,
        result.decode_seconds * 1e3,
        result.decode_steps,
        result.decode_seconds * 1e3 / result.decode_steps.max(1) as f64,
    );
    println!(
        "collectives: {} all-reduces, {} stage hand-offs",
        result.comm.allreduce_ops, result.comm.pp_sends
    );
    Ok(())
}
