//! Run the two-phase scheduler (Algorithm-1 DP inside a genetic search)
//! on the paper's full-price heterogeneous cluster and print the
//! Table-4-style deployment, then compare against the homogeneous pool.
//!
//! ```bash
//! cargo run --release --example schedule_explore -- [--iterations 40]
//! ```

use anyhow::Result;

use hexgen::cluster;
use hexgen::model::ModelSpec;
use hexgen::scheduler::{GaConfig, GeneticScheduler, PipelinePlanner};
use hexgen::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let m = ModelSpec::llama2_70b();
    let ga = GaConfig {
        population: args.get_usize("population", 12),
        iterations: args.get_usize("iterations", 30),
        patience: args.get_usize("patience", 12),
        seed: args.get_u64("seed", 42),
        fitness_requests: args.get_usize("fitness-requests", 120),
        ..GaConfig::default()
    };

    for preset in ["full-price", "half-price"] {
        let c = cluster::preset(preset).unwrap();
        println!(
            "== {} — {} GPUs, {} machines, {} regions, ${:.2}/hour ==",
            c.name,
            c.devices.len(),
            c.machines.len(),
            c.regions.len(),
            c.budget_per_hour
        );
        let res = GeneticScheduler::new(&c, &m, ga.clone()).run();
        println!(
            "search: {} iterations in {:.1}s; est. SLO attainment {:.3} (init {:.3})",
            res.iterations_run, res.wall_time, res.fitness, res.init_fitness
        );
        print!("{}", res.deployment.describe(&c));
        println!();
    }

    // The same budget's homogeneous alternative, symmetric-only.
    let c = cluster::homogeneous_a100();
    println!(
        "== {} — {} GPUs, ${:.2}/hour (symmetric baseline) ==",
        c.name,
        c.devices.len(),
        c.budget_per_hour
    );
    let mut sym = ga;
    sym.planner = PipelinePlanner::Symmetric;
    let res = GeneticScheduler::new(&c, &m, sym).run();
    print!("{}", res.deployment.describe(&c));
    Ok(())
}
