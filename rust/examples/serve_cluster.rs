//! End-to-end serving driver (see rust/README.md): start the
//! threaded HexGen service with two asymmetric replicas of the real demo
//! model, replay a Poisson request trace through the router/batcher, and
//! report latency percentiles, throughput and SLO attainment.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example serve_cluster -- [--rate 4] [--requests 60]
//! ```

use std::time::{Duration, Instant};

use anyhow::Result;

use hexgen::coordinator::{
    collect_all, plan_from_strategy, BatchPolicy, FaultPolicy, GenRequest, HexGenService,
    RoutePolicy, ServiceConfig,
};
use hexgen::util::cli::Args;
use hexgen::util::rng::Xoshiro256pp;
use hexgen::util::stats::{fraction_within, Summary};

const PROMPTS: [&str; 8] = [
    "the quick brown fox jumps over the lazy dog",
    "in a hole in the ground there lived a hobbit",
    "it was the best of times, it was the worst of times",
    "call me ishmael. some years ago - never mind how long",
    "happy families are all alike; every unhappy family",
    "it is a truth universally acknowledged, that a single",
    "the sky above the port was the color of television",
    "we were somewhere around barstow on the edge of the desert",
];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rate = args.get_f64("rate", 4.0);
    let n_requests = args.get_usize("requests", 60);
    let max_new = args.get_usize("max-new", 8);
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // Two model replicas with *different asymmetric plans*, as HexGen's
    // scheduler would deploy on unequal hardware.
    let cfg = ServiceConfig {
        artifacts_dir: dir,
        backend: Default::default(),
        replicas: vec![
            plan_from_strategy(&[2, 1], &[4, 2])?, // TP2→TP1, 4+2 layers
            plan_from_strategy(&[1, 1], &[3, 3])?, // TP1 pipeline, 3+3
        ],
        batch: BatchPolicy { max_batch: 4, window: Duration::from_millis(15), continuous: true },
        route: RoutePolicy::LeastLoaded,
        speeds: None,
        prefill_speeds: None,
        roles: Vec::new(),
        adapt_speeds: true,
        max_new_tokens: max_new,
        stop_token: None,
        kv: Default::default(),
        spec: None,
        faults: FaultPolicy::default(),
    };
    println!("starting HexGen service: 2 replicas ([2,1] 4/2 and [1,1] 3/3)...");
    let t_start = Instant::now();
    let service = HexGenService::start(cfg)?;
    println!("service up in {:.1}s (compile + warm-up)\n", t_start.elapsed().as_secs_f64());

    // Poisson arrivals at `rate` req/s.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    println!("replaying {n_requests} requests at {rate} req/s (Poisson)...");
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let gap = rng.exponential(rate);
        std::thread::sleep(Duration::from_secs_f64(gap));
        let prompt = PROMPTS[i % PROMPTS.len()];
        handles.push(service.submit(GenRequest::new(prompt).with_max_new(max_new)));
    }
    let results = collect_all(handles, Duration::from_secs(600));
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::new();
    let mut per_replica = vec![0usize; service.replicas()];
    let mut batch_sizes = Vec::new();
    let mut failures = 0;
    let mut tokens_out = 0usize;
    for r in &results {
        match r {
            Ok(c) => {
                latencies.push(c.latency);
                per_replica[c.replica] += 1;
                batch_sizes.push(c.batch_size as f64);
                tokens_out += c.tokens.len();
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                failures += 1;
            }
        }
    }
    let s = Summary::from_samples(&latencies).expect("no successful requests");
    println!("\n== results ==");
    println!("requests     : {} ok, {failures} failed", latencies.len());
    println!("wall time    : {wall:.1}s");
    println!(
        "throughput   : {:.2} req/s, {:.1} tok/s",
        latencies.len() as f64 / wall,
        tokens_out as f64 / wall
    );
    println!(
        "latency      : p50 {:.0}ms  p90 {:.0}ms  p95 {:.0}ms  p99 {:.0}ms  max {:.0}ms",
        s.p50 * 1e3, s.p90 * 1e3, s.p95 * 1e3, s.p99 * 1e3, s.max * 1e3
    );
    let mean_batch = batch_sizes.iter().sum::<f64>() / batch_sizes.len() as f64;
    println!("mean batch   : {mean_batch:.2}");
    println!("per replica  : {per_replica:?}");
    for slo in [0.5, 1.0, 2.0, 4.0] {
        println!(
            "SLO {slo:>4.1}s    : {:.1}% attainment",
            fraction_within(&latencies, slo) * 100.0
        );
    }
    let comm = service.comm_stats();
    println!(
        "collectives  : {} all-reduces ({}), {} hand-offs ({})",
        comm.allreduce_ops,
        hexgen::util::fmt_bytes(comm.allreduce_bytes),
        comm.pp_sends,
        hexgen::util::fmt_bytes(comm.pp_bytes)
    );
    service.shutdown();
    Ok(())
}
