//! Compile-level smoke test of the PJRT path (`--features pjrt`): the
//! `ModelRuntime` backend and its `xla` surface must keep type-checking
//! even when the wired `xla` crate is the in-tree API stub.
#![cfg(feature = "pjrt")]

use std::path::Path;

use hexgen::runtime::{BackendKind, ExecutionBackend, ModelRuntime};

#[test]
fn pjrt_is_the_default_backend_kind() {
    assert_eq!(BackendKind::default(), BackendKind::Pjrt);
    assert_eq!(BackendKind::Pjrt.name(), "pjrt");
}

#[test]
fn missing_artifacts_error_cleanly() {
    // Whether backed by the stub or a real XLA runtime, loading from a
    // nonexistent artifacts directory must be an error, not a panic.
    assert!(ModelRuntime::load(Path::new("/nonexistent-hexgen-artifacts")).is_err());
}

#[test]
fn backend_trait_object_is_constructible() {
    // Type-level check that ModelRuntime satisfies the backend seam.
    fn assert_backend<T: ExecutionBackend>() {}
    assert_backend::<ModelRuntime>();
}
