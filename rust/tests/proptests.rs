//! Property-based tests over scheduler, cost model and simulator
//! invariants (in-house `util::prop` harness; no proptest offline).

use hexgen::cluster::{self, Cluster, DeviceId};
use hexgen::costmodel::{CostModel, InferenceTask, Phase};
use hexgen::model::ModelSpec;
use hexgen::parallelism::{Deployment, Pipeline, Stage};
use hexgen::scheduler::{optimal_pipeline, GroupPool};
use hexgen::simulator::{simulate, SimConfig, SloModel};
use hexgen::util::prop::{prop_assert, prop_check};
use hexgen::util::rng::Xoshiro256pp;
use hexgen::workload::{LengthDist, WorkloadSpec};

fn random_task(rng: &mut Xoshiro256pp) -> InferenceTask {
    InferenceTask::new(
        1 + rng.gen_range(8),
        8 + rng.gen_range(512),
        1 + rng.gen_range(256),
    )
}

fn random_subset(rng: &mut Xoshiro256pp, cluster: &Cluster, min: usize) -> Vec<DeviceId> {
    let n = cluster.devices.len();
    let k = min + rng.gen_range(n - min);
    rng.sample_indices(n, k.max(min))
}

#[test]
fn cost_model_properties() {
    let clusters = [cluster::heterogeneous_full_price(), cluster::case_study()];
    let m = ModelSpec::llama2_70b();
    prop_check(300, 0xC057, |rng| {
        let c = &clusters[rng.gen_range(clusters.len())];
        let cm = CostModel::new(c, &m);
        let t = random_task(rng);
        let devs = random_subset(rng, c, 1);
        let layers = 1 + rng.gen_range(m.layers);

        // costs are non-negative and finite
        let comp = cm.comp_cost(&devs, layers, &t, Phase::Both);
        let tp = cm.comm_tp_cost(&devs, layers, &t, Phase::Both);
        prop_assert(comp.is_finite() && comp > 0.0, format!("comp={comp}"))?;
        prop_assert(tp.is_finite() && tp >= 0.0, format!("tp={tp}"))?;

        // phase split sums to Both for comm; comp's Both uses s_out scans
        let tp_sum = cm.comm_tp_cost(&devs, layers, &t, Phase::Prefill)
            + cm.comm_tp_cost(&devs, layers, &t, Phase::Decode);
        prop_assert((tp_sum - tp).abs() <= 1e-9 * tp.max(1.0), "tp phases")?;

        // memory decreases (weakly) with TP degree
        let m1 = cm.mem_bytes(1, layers, &t);
        let m4 = cm.mem_bytes(4, layers, &t);
        prop_assert(m4 <= m1, format!("mem tp4 {m4} > tp1 {m1}"))?;

        // more layers -> more memory, more compute
        if layers + 1 <= m.layers {
            let comp2 = cm.comp_cost(&devs, layers + 1, &t, Phase::Both);
            prop_assert(comp2 > comp, "comp not monotone in layers")?;
        }
        Ok(())
    });
}

#[test]
fn dp_plans_are_valid_and_within_pool() {
    let m = ModelSpec::llama2_70b();
    let clusters = [
        cluster::heterogeneous_half_price(),
        cluster::heterogeneous_full_price(),
        cluster::case_study(),
    ];
    prop_check(40, 0xD9, |rng| {
        let c = &clusters[rng.gen_range(clusters.len())];
        let cm = CostModel::new(c, &m);
        let devs = random_subset(rng, c, 2);
        let t = random_task(rng);
        match optimal_pipeline(&cm, c, &devs, &t, 6, 8) {
            None => Ok(()), // infeasible subsets are fine
            Some(res) => {
                res.pipeline
                    .validate(&m)
                    .map_err(|e| format!("invalid plan: {e}"))?;
                // all devices drawn from the subset
                for d in res.pipeline.devices() {
                    prop_assert(devs.contains(&d), format!("foreign device {d}"))?;
                }
                // exact cost is reproducible
                let again = res.pipeline.cost(&cm, &t, Phase::Both).unwrap();
                prop_assert(
                    (again - res.exact_cost).abs() < 1e-9,
                    "cost not reproducible",
                )?;
                Ok(())
            }
        }
    });
}

#[test]
fn group_pool_binding_is_a_partition() {
    let c = cluster::heterogeneous_full_price();
    prop_check(100, 0xB14D, |rng| {
        let devs = random_subset(rng, &c, 1);
        let pool = GroupPool::new(&c, &devs);
        prop_assert(pool.total() == devs.len(), "pool size")?;
        // binding all of each type enumerates each device exactly once
        let mut seen = std::collections::BTreeSet::new();
        for k in 0..hexgen::parallelism::group::NUM_TYPES {
            let cap = pool.caps[k];
            if cap == 0 {
                continue;
            }
            for &d in pool.bind(k, 0, cap) {
                prop_assert(seen.insert(d), format!("device {d} bound twice"))?;
            }
        }
        prop_assert(seen.len() == devs.len(), "binding incomplete")?;
        Ok(())
    });
}

#[test]
fn simulator_conservation_and_monotonicity() {
    let c = cluster::homogeneous_a100();
    let m = ModelSpec::llama2_70b();
    let cm = CostModel::new(&c, &m);
    let slo = SloModel::new(&m);
    let deployment = Deployment {
        pipelines: vec![
            Pipeline { stages: vec![Stage { devices: (0..8).collect(), layers: 80 }] },
            Pipeline { stages: vec![Stage { devices: (8..16).collect(), layers: 80 }] },
        ],
    };
    prop_check(25, 0x51A7, |rng| {
        let rate = 0.2 + rng.next_f64() * 4.0;
        let n = 50 + rng.gen_range(100);
        let s_out = *rng.choose(&[32usize, 64, 128]).unwrap();
        let trace = WorkloadSpec {
            rate,
            num_requests: n,
            lengths: LengthDist::LmsysLike { s_out },
            seed: rng.next_u64(),
        }
        .generate();
        let out = simulate(&cm, &deployment, &trace, &SimConfig::default());

        // conservation: every request completes exactly once
        prop_assert(out.records.len() == n, "record count")?;
        for (r, req) in out.records.iter().zip(&trace) {
            prop_assert(
                r.completion >= req.arrival,
                "completion before arrival",
            )?;
            prop_assert(r.latency > 0.0, "non-positive latency")?;
        }
        // attainment monotone in SLO scale
        let mut prev = 0.0;
        for scale in [1.0, 2.0, 5.0, 10.0, 50.0] {
            let a = out.attainment(&slo, scale);
            prop_assert(a + 1e-12 >= prev, "attainment not monotone")?;
            prop_assert((0.0..=1.0).contains(&a), "attainment range")?;
            prev = a;
        }
        Ok(())
    });
}

#[test]
fn batch_timing_latency_at_least_period() {
    let c = cluster::heterogeneous_full_price();
    let m = ModelSpec::llama2_70b();
    let cm = CostModel::new(&c, &m);
    prop_check(100, 0xBA7C1, |rng| {
        let devs = random_subset(rng, &c, 2);
        let t = random_task(rng);
        let Some(res) = optimal_pipeline(&cm, &c, &devs, &t, 4, 8) else {
            return Ok(());
        };
        let stages: Vec<(Vec<usize>, usize)> = res
            .pipeline
            .stages
            .iter()
            .map(|s| (s.devices.clone(), s.layers))
            .collect();
        if let Some((lat, period)) =
            hexgen::simulator::batch_timing(&cm, &stages, &t, false)
        {
            prop_assert(lat >= period - 1e-12, format!("lat {lat} < period {period}"))?;
            prop_assert(lat.is_finite() && period > 0.0, "timing finite")?;
        }
        Ok(())
    });
}
