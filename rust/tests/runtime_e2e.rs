//! Integration tests over the real AOT artifacts: the Rust coordinator
//! must reproduce the fused JAX model's numerics when composing
//! asymmetric TP×PP stage executables with host-side collectives.
//!
//! Requires the `pjrt` feature (with a real `xla` crate wired in) and
//! `make artifacts` (skipped gracefully when absent). The
//! backend-agnostic equivalent over the checked-in fixture lives in
//! `reference_parity.rs`.
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use hexgen::coordinator::{plan_from_strategy, PipelineExecutor};
use hexgen::runtime::{tokenizer, InputArg, ModelRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("full_prefill_b1.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Greedy generation with the fused whole-model executables (the oracle).
fn fused_generate(rt: &ModelRuntime, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let info = &rt.manifest.model;
    assert_eq!(prompt.len(), info.prompt_len);
    let weight_names = rt.manifest.weight_order.clone();

    let mut inputs = vec![InputArg::I32(prompt, vec![1, info.prompt_len])];
    let weights: Vec<&hexgen::runtime::Tensor> = weight_names
        .iter()
        .map(|n| rt.weights.get(n).unwrap())
        .collect();
    for w in &weights {
        inputs.push(InputArg::F32(w));
    }
    let outs = rt.execute_t("full_prefill_b1", &inputs).unwrap();
    let (logits, mut kc, mut vc) = (outs[0].clone(), outs[1].clone(), outs[2].clone());
    let mut next = hexgen::coordinator::argmax_rows(&logits, info.vocab);
    let mut out = vec![next[0]];

    for step in 1..max_new {
        let pos = (info.prompt_len + step - 1) as i32;
        let tok = [next[0]];
        let mut inputs = vec![
            InputArg::I32(&tok, vec![1, 1]),
            InputArg::F32(&kc),
            InputArg::F32(&vc),
            InputArg::ScalarI32(pos),
        ];
        for w in &weights {
            inputs.push(InputArg::F32(w));
        }
        let outs = rt.execute_t("full_decode_b1", &inputs).unwrap();
        let logits = outs[0].clone();
        kc = outs[1].clone();
        vc = outs[2].clone();
        next = hexgen::coordinator::argmax_rows(&logits, info.vocab);
        out.push(next[0]);
    }
    out
}

#[test]
fn asymmetric_plans_match_fused_model() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = tokenizer::encode("the quick brown fox jumps over the lazy dog", 32);
    let max_new = 6;

    let rt = ModelRuntime::load(&dir).unwrap();
    let oracle = fused_generate(&rt, &prompt, max_new);
    assert_eq!(oracle.len(), max_new);

    // Every plan shape must reproduce the oracle token-for-token.
    for (tps, layers) in [
        (vec![1usize], vec![6usize]),          // single stage TP=1
        (vec![4], vec![6]),                    // single stage TP=4
        (vec![2, 1], vec![4, 2]),              // the §3.1-style asymmetric plan
        (vec![1, 2, 4], vec![2, 2, 2]),        // fully asymmetric 3-stage
        (vec![2, 2], vec![3, 3]),              // symmetric 2-stage
    ] {
        let plan = plan_from_strategy(&tps, &layers).unwrap();
        let exec = PipelineExecutor::new(&dir, plan).unwrap();
        let result = exec.generate(&[prompt.clone()], max_new).unwrap();
        assert_eq!(
            result.tokens[0], oracle,
            "plan {} diverged from fused model",
            exec.strategy_string()
        );
        // decode_steps counts true decode iterations; the first token is
        // argmaxed from the prefill logits and reported separately.
        assert_eq!(result.decode_steps, max_new - 1);
        assert_eq!(result.prefill_tokens, 1);
        assert!(result.prefill_seconds > 0.0 && result.decode_seconds > 0.0);
    }
}

#[test]
fn tp_collective_counts_match_plan() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = tokenizer::encode("hello world", 32);
    let plan = plan_from_strategy(&[2, 1], &[4, 2]).unwrap();
    let exec = PipelineExecutor::new(&dir, plan).unwrap();
    let res = exec.generate(&[prompt], 3).unwrap();
    // Prefill: stage0 has 4 layers at TP2 → 8 all-reduces; stage1 TP1 → 0.
    // Decode: 2 further steps × 8. Total 8 + 16 = 24.
    assert_eq!(res.comm.allreduce_ops, 24, "{:?}", res.comm);
    // One PP hand-off per token step (prefill + 2 decode steps).
    assert_eq!(res.comm.pp_sends, 3);
    assert!(res.comm.allreduce_bytes > 0.0 && res.comm.pp_bytes > 0.0);
}

#[test]
fn batch_bucket_padding_is_transparent() {
    let Some(dir) = artifacts_dir() else { return };
    let p1 = tokenizer::encode("first prompt", 32);
    let p2 = tokenizer::encode("second, rather different prompt", 32);
    let plan = plan_from_strategy(&[2], &[6]).unwrap();
    let exec = PipelineExecutor::new(&dir, plan).unwrap();

    // batch of 2 → bucket 4; results must equal per-request runs (b=1).
    let joint = exec.generate(&[p1.clone(), p2.clone()], 4).unwrap();
    assert_eq!(joint.bucket, 4);
    assert_eq!(joint.tokens.len(), 2);
    let solo1 = exec.generate(&[p1], 4).unwrap();
    let solo2 = exec.generate(&[p2], 4).unwrap();
    assert_eq!(joint.tokens[0], solo1.tokens[0]);
    assert_eq!(joint.tokens[1], solo2.tokens[0]);
}

#[test]
fn invalid_plans_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    // layer sum mismatch
    assert!(PipelineExecutor::new(&dir, plan_from_strategy(&[1], &[5]).unwrap()).is_err());
    // unsupported tp degree
    assert!(PipelineExecutor::new(&dir, plan_from_strategy(&[3], &[6]).unwrap()).is_err());
    // non-contiguous stages
    use hexgen::coordinator::StagePlan;
    let bad = vec![
        StagePlan { layer_start: 0, layer_count: 3, tp: 1 },
        StagePlan { layer_start: 4, layer_count: 3, tp: 1 },
    ];
    assert!(PipelineExecutor::new(&dir, bad).is_err());
}

#[test]
fn generation_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = tokenizer::encode("determinism check", 32);
    let plan = plan_from_strategy(&[2, 2], &[3, 3]).unwrap();
    let exec = PipelineExecutor::new(&dir, plan).unwrap();
    let a = exec.generate(&[prompt.clone()], 5).unwrap();
    let b = exec.generate(&[prompt], 5).unwrap();
    assert_eq!(a.tokens, b.tokens);
}
