//! End-to-end tests of the std-only HTTP/1.1 front-end over a real
//! socket: non-streaming completions (token parity with the blocking
//! `generate()`), SSE streaming (`Token` events strictly before `Done`),
//! and the observability endpoints.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use hexgen::coordinator::{
    plan_from_strategy, BatchPolicy, FaultPolicy, HexGenService, HttpServer, RoutePolicy,
    ServiceConfig,
};
use hexgen::runtime::BackendKind;
use hexgen::util::json::Json;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo")
}

/// One TP=2 replica on the fixture model + an HTTP front-end bound to an
/// ephemeral port.
fn start() -> (Arc<HexGenService>, HttpServer) {
    let cfg = ServiceConfig {
        artifacts_dir: fixture_dir(),
        backend: BackendKind::Reference,
        replicas: vec![plan_from_strategy(&[2], &[2]).unwrap()],
        batch: BatchPolicy { max_batch: 2, window: Duration::from_millis(5), continuous: true },
        route: RoutePolicy::LeastLoaded,
        speeds: None,
        prefill_speeds: None,
        roles: Vec::new(),
        adapt_speeds: true,
        max_new_tokens: 4,
        stop_token: None,
        kv: Default::default(),
        spec: None,
        faults: FaultPolicy::default(),
    };
    let service = Arc::new(HexGenService::start(cfg).unwrap());
    let server = HttpServer::serve(service.clone(), "127.0.0.1:0").unwrap();
    (service, server)
}

/// One raw HTTP/1.1 exchange; the server closes after each response, so
/// read-to-EOF returns the full response.
fn exchange(addr: SocketAddr, raw: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut out = String::new();
    stream.read_to_string(&mut out).unwrap();
    out
}

fn get(addr: SocketAddr, path: &str) -> String {
    exchange(addr, &format!("GET {path} HTTP/1.1\r\nHost: hexgen\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> String {
    exchange(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: hexgen\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn status_of(resp: &str) -> u16 {
    resp.split_whitespace().nth(1).unwrap().parse().unwrap()
}

fn body_json(resp: &str) -> Json {
    let body = resp.split("\r\n\r\n").nth(1).expect("response has a body");
    Json::parse(body).unwrap_or_else(|e| panic!("bad json body: {e}\n{body}"))
}

fn tokens_of(j: &Json) -> Vec<i64> {
    j.arr("tokens").unwrap().iter().map(|t| t.as_f64().unwrap() as i64).collect()
}

/// Extract `(event, data)` pairs from an SSE body.
fn sse_events(resp: &str) -> Vec<(String, Json)> {
    let body = resp.split("\r\n\r\n").nth(1).expect("response has a body");
    let mut out = Vec::new();
    let mut event = String::new();
    for line in body.lines() {
        if let Some(e) = line.strip_prefix("event: ") {
            event = e.trim().to_string();
        } else if let Some(d) = line.strip_prefix("data: ") {
            out.push((event.clone(), Json::parse(d.trim()).unwrap()));
        }
    }
    out
}

#[test]
fn health_metrics_and_plan_endpoints() {
    let (service, server) = start();
    let addr = server.addr();

    let health = get(addr, "/healthz");
    assert_eq!(status_of(&health), 200);
    let j = body_json(&health);
    assert_eq!(j.str("status").unwrap(), "ok");
    assert_eq!(j.usize("replicas").unwrap(), 1);

    // Serve one request so metrics have something to report.
    let resp = post(addr, "/v1/completions", r#"{"prompt": "metrics probe", "max_new": 3}"#);
    assert_eq!(status_of(&resp), 200);

    let metrics = get(addr, "/metrics");
    assert_eq!(status_of(&metrics), 200);
    let j = body_json(&metrics);
    assert_eq!(j.get("router").unwrap().arr("speeds").unwrap().len(), 1);
    assert_eq!(j.get("router").unwrap().arr("outstanding").unwrap().len(), 1);
    assert!(j.get("requests").unwrap().usize("completed").unwrap() >= 1);
    assert!(j.get("comm").unwrap().usize("allreduce_ops").unwrap() > 0, "TP=2 ran collectives");

    let plan = get(addr, "/v1/plan");
    assert_eq!(status_of(&plan), 200);
    let j = body_json(&plan);
    let replicas = j.arr("replicas").unwrap();
    assert_eq!(replicas.len(), 1);
    assert_eq!(replicas[0].str("strategy").unwrap(), "[2]");
    assert_eq!(replicas[0].arr("stages").unwrap()[0].usize("tp").unwrap(), 2);

    let missing = get(addr, "/nope");
    assert_eq!(status_of(&missing), 404);

    server.shutdown();
    drop(service);
}

#[test]
fn nonstreaming_completion_matches_blocking_generate() {
    let (service, server) = start();
    let addr = server.addr();

    // "parity" is 6 bytes, under the fixture's 8-token prompt_len: no
    // truncation expected.
    let reference = service.generate("parity", Some(4)).unwrap();
    let resp = post(addr, "/v1/completions", r#"{"prompt": "parity", "max_new": 4}"#);
    assert_eq!(status_of(&resp), 200);
    let j = body_json(&resp);
    let got: Vec<i64> = tokens_of(&j);
    let want: Vec<i64> = reference.tokens.iter().map(|&t| t as i64).collect();
    assert_eq!(got, want, "HTTP completion diverged from blocking generate()");
    assert_eq!(j.str("text").unwrap(), reference.text);
    assert!(!j.get("truncated").unwrap().as_bool().unwrap());
    assert_eq!(j.usize("prompt_tokens").unwrap(), 6);

    // Over-long prompts surface truncation in the HTTP response too.
    let long = "a prompt much longer than the fixture context window";
    let resp = post(
        addr,
        "/v1/completions",
        &format!(r#"{{"prompt": "{long}", "max_new": 2}}"#),
    );
    assert!(body_json(&resp).get("truncated").unwrap().as_bool().unwrap());

    server.shutdown();
    drop(service);
}

#[test]
fn streaming_sse_delivers_tokens_before_done() {
    let (service, server) = start();
    let addr = server.addr();

    let reference = service.generate("sse streaming prompt", Some(6)).unwrap();
    let resp = post(
        addr,
        "/v1/completions",
        r#"{"prompt": "sse streaming prompt", "max_new": 6, "stream": true}"#,
    );
    assert!(resp.contains("text/event-stream"), "SSE content type missing:\n{resp}");

    // Byte-order in the stream: the first token event strictly precedes
    // the terminal done event.
    let first_token = resp.find("event: token").expect("no token event in stream");
    let done = resp.find("event: done").expect("no done event in stream");
    assert!(first_token < done, "token events must stream before the terminal Done");

    let events = sse_events(&resp);
    assert_eq!(events.first().map(|(e, _)| e.as_str()), Some("queued"));
    assert_eq!(events.get(1).map(|(e, _)| e.as_str()), Some("admitted"));
    let streamed: Vec<i64> = events
        .iter()
        .filter(|(e, _)| e == "token")
        .map(|(_, d)| d.f64("token").unwrap() as i64)
        .collect();
    let want: Vec<i64> = reference.tokens.iter().map(|&t| t as i64).collect();
    assert_eq!(streamed, want, "streamed SSE tokens diverged from blocking generate()");
    let (last_event, last_data) = events.last().unwrap();
    assert_eq!(last_event, "done");
    assert_eq!(tokens_of(last_data), want);

    server.shutdown();
    drop(service);
}

#[test]
fn streaming_text_deltas_reassemble_to_completion_text() {
    let (service, server) = start();
    let addr = server.addr();

    // The fixture's greedy continuation of this prompt contains bytes
    // ≥ 0x80 (golden tokens include 136/230/180), so this exercises the
    // worker's incremental UTF-8 buffering over a real SSE stream: the
    // concatenation of every token event's `text` must equal the
    // terminal completion text exactly — no spurious replacement chars
    // mid-stream, and the final token flushes any buffered bytes.
    let resp = post(
        addr,
        "/v1/completions",
        r#"{"prompt": "hexgen parity", "max_new": 6, "stream": true}"#,
    );
    let events = sse_events(&resp);
    let deltas: String = events
        .iter()
        .filter(|(e, _)| e == "token")
        .map(|(_, d)| d.str("text").unwrap().to_string())
        .collect();
    let (last_event, last_data) = events.last().unwrap();
    assert_eq!(last_event, "done");
    assert_eq!(
        deltas,
        last_data.str("text").unwrap(),
        "concatenated token text_deltas must reassemble the completion text"
    );

    server.shutdown();
    drop(service);
}

#[test]
fn malformed_requests_get_typed_errors() {
    let (service, server) = start();
    let addr = server.addr();

    let resp = post(addr, "/v1/completions", "{not json");
    assert_eq!(status_of(&resp), 400);
    let resp = post(addr, "/v1/completions", r#"{"max_new": 4}"#);
    assert_eq!(status_of(&resp), 400);
    assert!(body_json(&resp).str("error").unwrap().contains("prompt"));
    let resp = post(addr, "/v1/completions", r#"{"prompt": "x", "max_new": 0}"#);
    assert_eq!(status_of(&resp), 400, "max_new=0 maps InvalidRequest to 400");
    let resp = post(addr, "/v1/completions", r#"{"prompt": "x", "stream": "yes"}"#);
    assert_eq!(status_of(&resp), 400);

    // A huge declared Content-Length must be rejected up front (413),
    // not allocated.
    let resp = exchange(
        addr,
        "POST /v1/completions HTTP/1.1\r\nHost: hexgen\r\nContent-Length: 99999999999\r\n\r\n",
    );
    assert_eq!(status_of(&resp), 413);

    server.shutdown();
    drop(service);
}
