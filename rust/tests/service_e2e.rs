//! End-to-end tests of the threaded serving front-end (router + batcher +
//! per-replica workers) over the pure-Rust reference backend and the
//! checked-in fixture model — runs in plain `cargo test` with zero
//! native dependencies.

use std::path::PathBuf;
use std::time::Duration;

use hexgen::coordinator::{
    collect_all, plan_from_strategy, BatchPolicy, HexGenService, RoutePolicy, ServiceConfig,
};
use hexgen::runtime::BackendKind;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo")
}

/// Two replicas with different asymmetric plans over the 2-layer fixture
/// model (tp degrees {1, 2}, batch buckets {1, 2}).
fn two_replica_config(dir: PathBuf) -> ServiceConfig {
    ServiceConfig {
        artifacts_dir: dir,
        backend: BackendKind::Reference,
        replicas: vec![
            plan_from_strategy(&[2], &[2]).unwrap(),    // single stage, TP=2
            plan_from_strategy(&[1, 1], &[1, 1]).unwrap(), // TP=1 pipeline
        ],
        batch: BatchPolicy { max_batch: 2, window: Duration::from_millis(10) },
        route: RoutePolicy::LeastLoaded,
        max_new_tokens: 4,
    }
}

#[test]
fn service_serves_batched_requests() {
    let service = HexGenService::start(two_replica_config(fixture_dir())).unwrap();
    assert_eq!(service.replicas(), 2);

    let prompts = [
        "the quick brown fox",
        "hello heterogeneous world",
        "tensor model parallelism",
        "pipeline parallel stage",
        "llama seventy billion",
        "scheduling via genetic algorithm",
    ];
    let rxs: Vec<_> = prompts.iter().map(|p| service.submit(p, Some(4))).collect();
    let results = collect_all(rxs, Duration::from_secs(120));

    let mut replicas_used = std::collections::BTreeSet::new();
    for r in &results {
        let c = r.as_ref().expect("generation failed");
        assert_eq!(c.tokens.len(), 4);
        assert!(c.latency > 0.0);
        assert!(c.latency >= c.queued);
        assert!(c.batch_size >= 1 && c.batch_size <= 2);
        replicas_used.insert(c.replica);
    }
    // 6 concurrent requests over 2 replicas: both should see traffic.
    assert_eq!(replicas_used.len(), 2, "router never used one replica");

    let comm = service.comm_stats();
    assert!(comm.allreduce_ops > 0, "TP collectives should have run");
    assert!(comm.pp_sends > 0, "PP hand-offs should have run");
    service.shutdown();
}

#[test]
fn same_prompt_same_output_across_replicas() {
    // Two replicas with different plans must agree on greedy outputs.
    let service = HexGenService::start(two_replica_config(fixture_dir())).unwrap();
    let a = service.generate("consistency probe", Some(4)).unwrap();
    // Try to reach the other replica by submitting repeatedly.
    let mut other = None;
    for _ in 0..8 {
        let c = service.generate("consistency probe", Some(4)).unwrap();
        if c.replica != a.replica {
            other = Some(c);
            break;
        }
    }
    if let Some(b) = other {
        assert_eq!(a.tokens, b.tokens, "replicas disagree on greedy decode");
    }
    service.shutdown();
}

#[test]
fn startup_fails_cleanly_on_bad_plan() {
    let cfg = ServiceConfig {
        artifacts_dir: fixture_dir(),
        backend: BackendKind::Reference,
        replicas: vec![plan_from_strategy(&[4], &[2]).unwrap()], // tp=4 unsupported
        batch: BatchPolicy::default(),
        route: RoutePolicy::RoundRobin,
        max_new_tokens: 2,
    };
    assert!(HexGenService::start(cfg).is_err());
}

#[test]
fn oversized_batch_rejected_not_hung() {
    // max_batch above the largest bucket: the batch cannot be padded to
    // any bucket, so requests fail with an error instead of hanging.
    let mut cfg = two_replica_config(fixture_dir());
    cfg.batch = BatchPolicy { max_batch: 4, window: Duration::from_millis(30) };
    let service = HexGenService::start(cfg).unwrap();
    let rxs: Vec<_> = (0..4).map(|_| service.submit("overflow probe", Some(2))).collect();
    let results = collect_all(rxs, Duration::from_secs(60));
    for r in &results {
        match r {
            Ok(c) => assert_eq!(c.tokens.len(), 2),
            Err(e) => assert!(e.contains("bucket"), "unexpected error: {e}"),
        }
    }
    service.shutdown();
}
