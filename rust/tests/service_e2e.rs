//! End-to-end tests of the threaded serving front-end (router + batcher +
//! per-replica workers over real PJRT pipelines).

use std::path::PathBuf;
use std::time::Duration;

use hexgen::coordinator::{
    collect_all, plan_from_strategy, BatchPolicy, HexGenService, RoutePolicy, ServiceConfig,
};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn two_replica_config(dir: PathBuf) -> ServiceConfig {
    ServiceConfig {
        artifacts_dir: dir,
        replicas: vec![
            plan_from_strategy(&[2, 1], &[4, 2]).unwrap(), // asymmetric
            plan_from_strategy(&[1, 1], &[3, 3]).unwrap(), // TP=1 pipeline
        ],
        batch: BatchPolicy { max_batch: 4, window: Duration::from_millis(10) },
        route: RoutePolicy::LeastLoaded,
        max_new_tokens: 4,
    }
}

#[test]
fn service_serves_batched_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let service = HexGenService::start(two_replica_config(dir)).unwrap();
    assert_eq!(service.replicas(), 2);

    let prompts = [
        "the quick brown fox",
        "hello heterogeneous world",
        "tensor model parallelism",
        "pipeline parallel stage",
        "llama seventy billion",
        "scheduling via genetic algorithm",
    ];
    let rxs: Vec<_> = prompts.iter().map(|p| service.submit(p, Some(4))).collect();
    let results = collect_all(rxs, Duration::from_secs(120));

    let mut replicas_used = std::collections::BTreeSet::new();
    for r in &results {
        let c = r.as_ref().expect("generation failed");
        assert_eq!(c.tokens.len(), 4);
        assert!(c.latency > 0.0);
        assert!(c.latency >= c.queued);
        assert!(c.batch_size >= 1 && c.batch_size <= 4);
        replicas_used.insert(c.replica);
    }
    // 6 concurrent requests over 2 replicas: both should see traffic.
    assert_eq!(replicas_used.len(), 2, "router never used one replica");

    let comm = service.comm_stats();
    assert!(comm.allreduce_ops > 0, "TP collectives should have run");
    assert!(comm.pp_sends > 0, "PP hand-offs should have run");
    service.shutdown();
}

#[test]
fn same_prompt_same_output_across_replicas() {
    let Some(dir) = artifacts_dir() else { return };
    // Two replicas with different plans must agree on greedy outputs.
    let service = HexGenService::start(two_replica_config(dir)).unwrap();
    let a = service.generate("consistency probe", Some(5)).unwrap();
    // Try to reach the other replica by submitting repeatedly.
    let mut other = None;
    for _ in 0..8 {
        let c = service.generate("consistency probe", Some(5)).unwrap();
        if c.replica != a.replica {
            other = Some(c);
            break;
        }
    }
    if let Some(b) = other {
        assert_eq!(a.tokens, b.tokens, "replicas disagree on greedy decode");
    }
    service.shutdown();
}

#[test]
fn startup_fails_cleanly_on_bad_plan() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServiceConfig {
        artifacts_dir: dir,
        replicas: vec![plan_from_strategy(&[3], &[6]).unwrap()], // tp=3 unsupported
        batch: BatchPolicy::default(),
        route: RoutePolicy::RoundRobin,
        max_new_tokens: 2,
    };
    assert!(HexGenService::start(cfg).is_err());
}
