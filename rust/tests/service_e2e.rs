//! End-to-end tests of the threaded serving front-end (router + admission
//! loop + per-replica workers) over the pure-Rust reference backend and
//! the checked-in fixture model — runs in plain `cargo test` with zero
//! native dependencies. The workers run continuous (iteration-level)
//! batching: requests are admitted into KV-cache slots at decode-step
//! boundaries and each row stops at its own `max_new`.
//!
//! The public surface under test is the request-lifecycle API: a
//! submitted [`GenRequest`] is observed through a [`RequestHandle`]
//! streaming `Queued → Admitted → Token… → Done/Failed` events, with
//! typed [`ServiceError`]s and cancellation.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use hexgen::coordinator::{
    collect_all, plan_from_strategy, BatchPolicy, BreakerPolicy, FaultPolicy, GenRequest,
    HexGenService, HttpServer, KvPolicy, ReplicaHealth, RequestEvent, RoutePolicy, ServiceConfig,
    ServiceError,
};
use hexgen::parallelism::PhaseRole;
use hexgen::runtime::{BackendKind, FaultKind, FaultOp, FaultPlan, FaultSpec};
use hexgen::util::json::Json;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo")
}

/// The fixture's golden greedy decode: `(prompt, expected tokens)`.
fn golden() -> (String, Vec<i32>) {
    let text = std::fs::read_to_string(fixture_dir().join("golden.json")).unwrap();
    let g = Json::parse(&text).unwrap();
    let prompt = g.str("prompt").unwrap().to_string();
    let want: Vec<i32> =
        g.arr("greedy_tokens").unwrap().iter().map(|x| x.as_usize().unwrap() as i32).collect();
    (prompt, want)
}

/// Two replicas with different asymmetric plans over the 2-layer fixture
/// model (tp degrees {1, 2}, batch buckets {1, 2}).
fn two_replica_config(dir: PathBuf) -> ServiceConfig {
    ServiceConfig {
        artifacts_dir: dir,
        backend: BackendKind::Reference,
        replicas: vec![
            plan_from_strategy(&[2], &[2]).unwrap(),    // single stage, TP=2
            plan_from_strategy(&[1, 1], &[1, 1]).unwrap(), // TP=1 pipeline
        ],
        batch: BatchPolicy { max_batch: 2, window: Duration::from_millis(10), continuous: true },
        route: RoutePolicy::LeastLoaded,
        speeds: None,
        prefill_speeds: None,
        roles: Vec::new(),
        adapt_speeds: true,
        max_new_tokens: 4,
        stop_token: None,
        kv: KvPolicy::default(),
        spec: None,
        faults: FaultPolicy::default(),
    }
}

/// One replica (single TP=2 stage) with a generous co-batch window so
/// near-simultaneous submissions land in one admission batch.
fn one_replica_config(dir: PathBuf, window: Duration) -> ServiceConfig {
    ServiceConfig {
        artifacts_dir: dir,
        backend: BackendKind::Reference,
        replicas: vec![plan_from_strategy(&[2], &[2]).unwrap()],
        batch: BatchPolicy { max_batch: 2, window, continuous: true },
        route: RoutePolicy::RoundRobin,
        speeds: None,
        prefill_speeds: None,
        roles: Vec::new(),
        adapt_speeds: true,
        max_new_tokens: 4,
        stop_token: None,
        kv: KvPolicy::default(),
        spec: None,
        faults: FaultPolicy::default(),
    }
}

fn req(prompt: &str, max_new: usize) -> GenRequest {
    GenRequest::new(prompt).with_max_new(max_new)
}

#[test]
fn service_serves_batched_requests() {
    let service = HexGenService::start(two_replica_config(fixture_dir())).unwrap();
    assert_eq!(service.replicas(), 2);

    let prompts = [
        "the quick brown fox",
        "hello heterogeneous world",
        "tensor model parallelism",
        "pipeline parallel stage",
        "llama seventy billion",
        "scheduling via genetic algorithm",
    ];
    let handles: Vec<_> = prompts.iter().map(|p| service.submit(req(p, 4))).collect();
    let results = collect_all(handles, Duration::from_secs(120));

    let mut replicas_used = std::collections::BTreeSet::new();
    for r in &results {
        let c = r.as_ref().expect("generation failed");
        assert_eq!(c.tokens.len(), 4);
        assert!(c.latency > 0.0);
        assert!(c.latency >= c.queued);
        assert!(c.batch_size >= 1 && c.batch_size <= 2);
        assert_eq!(c.decode_steps, c.tokens.len() - 1);
        assert!(c.prompt_tokens > 0);
        replicas_used.insert(c.replica);
    }
    // 6 concurrent requests over 2 replicas: both should see traffic.
    assert_eq!(replicas_used.len(), 2, "router never used one replica");
    // Request ids are unique.
    let mut ids: Vec<_> = results.iter().map(|r| r.as_ref().unwrap().id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), prompts.len(), "request ids must be unique");

    let comm = service.comm_stats();
    assert!(comm.allreduce_ops > 0, "TP collectives should have run");
    assert!(comm.pp_sends > 0, "PP hand-offs should have run");

    let stats = service.stats();
    assert_eq!(stats.submitted, 6);
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.failed + stats.cancelled, 0);
    assert_eq!(stats.tokens_out, 24);
    // Paged-KV stats: pool capacity posts at startup; each of the six
    // distinct prompts missed the prefix cache once; and every block
    // drains once the batch retires. Workers publish at step boundaries,
    // so poll briefly rather than asserting instantaneously.
    assert!(stats.kv_blocks_total > 0, "no KV pool capacity reported");
    let t0 = Instant::now();
    loop {
        let s = service.stats();
        if s.prefix_cache_misses >= 6 && s.kv_blocks_used == 0 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "kv stats never drained: {s:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    service.shutdown();
}

#[test]
fn exhausted_block_pool_defers_admission_instead_of_failing() {
    // A one-block pool can hold exactly one in-flight row (the fixture's
    // whole context fits one block). Two concurrent requests therefore
    // cannot co-batch: the second must wait in the queue for the first
    // to retire and release its block — and then complete normally.
    // Nothing fails, nothing over-commits.
    let mut cfg = one_replica_config(fixture_dir(), Duration::from_millis(20));
    cfg.kv = KvPolicy { block_tokens: None, pool_blocks: Some(1) };
    let service = HexGenService::start(cfg).unwrap();
    assert_eq!(service.stats().kv_blocks_total, 1);

    let h_a = service.submit(req("block budget a", 4));
    let h_b = service.submit(req("block budget b", 4));
    let deadline = Instant::now() + Duration::from_secs(120);
    let a = h_a.wait_deadline(deadline).unwrap();
    let b = h_b.wait_deadline(deadline).unwrap();
    assert_eq!(a.tokens.len(), 4);
    assert_eq!(b.tokens.len(), 4);
    // Both slots were free, but the block budget admitted one at a time.
    assert_eq!(a.batch_size, 1, "block-gated rows must not co-batch");
    assert_eq!(b.batch_size, 1, "block-gated rows must not co-batch");

    let stats = service.stats();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed + stats.cancelled, 0);
    service.shutdown();
}

#[test]
fn lifecycle_events_stream_in_order_with_token_parity() {
    // One request through an idle service: the event stream must be
    // Queued, Admitted, Token{0..n}, Done — with the streamed tokens
    // exactly equal to the completion's tokens (streaming parity).
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(5))).unwrap();
    let handle = service.submit(req("lifecycle probe", 5));
    let mut events = Vec::new();
    loop {
        let ev = handle.next_event().unwrap();
        let terminal = ev.is_terminal();
        events.push(ev);
        if terminal {
            break;
        }
    }
    assert!(matches!(events[0], RequestEvent::Queued), "{events:?}");
    assert!(
        matches!(events[1], RequestEvent::Admitted { batch_size: 1, .. }),
        "{events:?}"
    );
    let streamed: Vec<i32> = events
        .iter()
        .filter_map(|e| match e {
            RequestEvent::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    let indexes: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            RequestEvent::Token { index, .. } => Some(*index),
            _ => None,
        })
        .collect();
    assert_eq!(indexes, (0..5usize).collect::<Vec<_>>(), "token indexes must be contiguous");
    let RequestEvent::Done(c) = events.last().unwrap() else {
        panic!("expected Done terminal, got {:?}", events.last());
    };
    assert_eq!(streamed, c.tokens, "streamed tokens must match the completion");
    service.shutdown();
}

#[test]
fn same_prompt_same_output_across_replicas() {
    // Two replicas with different plans must agree on greedy outputs.
    let service = HexGenService::start(two_replica_config(fixture_dir())).unwrap();
    let a = service.generate("consistency probe", Some(4)).unwrap();
    // Try to reach the other replica by submitting repeatedly.
    let mut other = None;
    for _ in 0..8 {
        let c = service.generate("consistency probe", Some(4)).unwrap();
        if c.replica != a.replica {
            other = Some(c);
            break;
        }
    }
    if let Some(b) = other {
        assert_eq!(a.tokens, b.tokens, "replicas disagree on greedy decode");
    }
    service.shutdown();
}

#[test]
fn startup_fails_cleanly_on_bad_plan() {
    let cfg = ServiceConfig {
        artifacts_dir: fixture_dir(),
        backend: BackendKind::Reference,
        replicas: vec![plan_from_strategy(&[4], &[2]).unwrap()], // tp=4 unsupported
        batch: BatchPolicy::default(),
        route: RoutePolicy::RoundRobin,
        speeds: None,
        prefill_speeds: None,
        roles: Vec::new(),
        adapt_speeds: true,
        max_new_tokens: 2,
        stop_token: None,
        kv: KvPolicy::default(),
        spec: None,
        faults: FaultPolicy::default(),
    };
    assert!(HexGenService::start(cfg).is_err());
}

#[test]
fn startup_rejects_mismatched_speed_seeds() {
    let mut cfg = two_replica_config(fixture_dir());
    cfg.speeds = Some(vec![1.0]); // 1 seed for 2 replicas
    assert!(HexGenService::start(cfg).is_err());
    let mut cfg = two_replica_config(fixture_dir());
    cfg.speeds = Some(vec![1.0, 0.0]); // non-positive seed
    assert!(HexGenService::start(cfg).is_err());
}

#[test]
fn overcommitted_queue_drains_through_slot_reuse() {
    // max_batch above the largest bucket: the session runs at the largest
    // bucket (2 slots) and the backlog drains through continuous
    // admission instead of failing or hanging.
    let mut cfg = two_replica_config(fixture_dir());
    cfg.batch = BatchPolicy { max_batch: 4, window: Duration::from_millis(30), continuous: true };
    let service = HexGenService::start(cfg).unwrap();
    let handles: Vec<_> = (0..4).map(|_| service.submit(req("overflow probe", 2))).collect();
    let results = collect_all(handles, Duration::from_secs(60));
    for r in &results {
        let c = r.as_ref().expect("request failed");
        assert_eq!(c.tokens.len(), 2);
        assert!(c.batch_size <= 2, "cohort cannot exceed the slot count");
    }
    service.shutdown();
}

#[test]
fn mixed_max_new_each_row_gets_exactly_its_own_length() {
    // A 2-token request co-batched with a 7-token request must receive
    // exactly 2 tokens (the old static path gave every row the batch-wide
    // max). The wide idle window makes the co-batching deterministic.
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_secs(2))).unwrap();
    let h_small = service.submit(req("short request", 2));
    let h_large = service.submit(req("long request please", 7));
    let deadline = Instant::now() + Duration::from_secs(120);
    let small = h_small.wait_deadline(deadline).unwrap();
    let large = h_large.wait_deadline(deadline).unwrap();
    assert_eq!(small.tokens.len(), 2, "small row must stop at its own max_new");
    assert_eq!(large.tokens.len(), 7);
    // Both were admitted in one cohort, so the small row really did stop
    // early while its neighbour kept decoding.
    assert_eq!(small.batch_size, 2, "requests were not co-batched");
    assert_eq!(large.batch_size, 2);
    assert_eq!(small.decode_steps, 1);
    assert_eq!(large.decode_steps, 6);
    service.shutdown();
}

#[test]
fn burst_with_staggered_limits_all_exact() {
    // More requests than slots, every one with a different max_new
    // (including max_new=1, which finishes at prefill): continuous slot
    // reuse must deliver each row exactly its requested length.
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(5)))
            .unwrap();
    let limits: Vec<usize> = vec![1, 2, 3, 4, 5, 6];
    let handles: Vec<_> = limits
        .iter()
        .map(|&n| service.submit(req(&format!("burst request {n}"), n)))
        .collect();
    let results = collect_all(handles, Duration::from_secs(120));
    for (r, &n) in results.iter().zip(&limits) {
        let c = r.as_ref().expect("request failed");
        assert_eq!(c.tokens.len(), n, "row asked for {n} tokens");
    }
    service.shutdown();
}

#[test]
fn continuous_batching_preserves_greedy_parity() {
    // Serving the golden prompt through the continuous-batching service —
    // co-batched with unrelated traffic of different lengths — must
    // reproduce the ref.py golden greedy tokens exactly.
    let (prompt, want) = golden();

    let service = HexGenService::start(two_replica_config(fixture_dir())).unwrap();
    let mut golden_handles = Vec::new();
    let mut noise_handles = Vec::new();
    for i in 0..4 {
        golden_handles.push(service.submit(req(&prompt, want.len())));
        noise_handles.push(service.submit(req(&format!("noise traffic {i}"), i + 1)));
    }
    for r in collect_all(golden_handles, Duration::from_secs(120)) {
        let c = r.expect("golden request failed");
        assert_eq!(c.tokens, want, "continuous batching diverged from golden greedy tokens");
    }
    for r in collect_all(noise_handles, Duration::from_secs(120)) {
        r.expect("noise request failed");
    }
    service.shutdown();
}

#[test]
fn invalid_max_new_rejected_without_failing_neighbours() {
    // A max_new=0 request is rejected at submit with a typed error; a
    // valid request sent in the same window must be unaffected.
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(20)))
            .unwrap();
    let h_bad = service.submit(req("zero tokens please", 0));
    let h_good = service.submit(req("valid neighbour", 3));
    match h_bad.wait() {
        Err(ServiceError::InvalidRequest(msg)) => assert!(msg.contains("max_new"), "{msg}"),
        other => panic!("max_new=0 must be InvalidRequest, got {other:?}"),
    }
    let good = h_good.wait_deadline(Instant::now() + Duration::from_secs(120)).unwrap();
    assert_eq!(good.tokens.len(), 3);
    service.shutdown();
}

#[test]
fn prompt_truncation_is_reported() {
    // The fixture model's prompt_len is 8; a 34-byte prompt must be
    // flagged as truncated instead of silently losing its oldest tokens.
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(5))).unwrap();
    let prompt_len = service.manifest().model.prompt_len;
    let long_prompt = "this prompt is longer than the context";
    assert!(long_prompt.len() > prompt_len);
    let c = service.generate(long_prompt, Some(2)).unwrap();
    assert!(c.truncated, "over-long prompt must report truncation");
    assert_eq!(c.prompt_tokens, prompt_len, "in-context token count caps at prompt_len");

    let c = service.generate("tiny", Some(2)).unwrap();
    assert!(!c.truncated);
    assert_eq!(c.prompt_tokens, 4);
    service.shutdown();
}

#[test]
fn cancelling_queued_request_frees_it_and_neighbours_complete() {
    // Two slots, four long requests: C and D start queued. Cancelling C
    // right away must terminate it with Cancelled (it never runs), while
    // A, B and D all complete at their full lengths through the slots
    // that cancellation + retirement free up.
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(20)))
            .unwrap();
    let h_a = service.submit(req("request a", 8));
    let h_b = service.submit(req("request b", 8));
    let h_c = service.submit(req("request c", 8));
    let h_d = service.submit(req("request d", 3));
    h_c.cancel();
    let deadline = Instant::now() + Duration::from_secs(120);
    assert_eq!(h_a.wait_deadline(deadline).unwrap().tokens.len(), 8);
    assert_eq!(h_b.wait_deadline(deadline).unwrap().tokens.len(), 8);
    assert_eq!(h_c.wait_deadline(deadline), Err(ServiceError::Cancelled));
    assert_eq!(h_d.wait_deadline(deadline).unwrap().tokens.len(), 3);
    let stats = service.stats();
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.completed, 3);
    service.shutdown();
}

#[test]
fn cancel_mid_decode_frees_the_slot_for_queued_work() {
    // Streaming + cancellation: receive a Token event for an in-flight
    // request, cancel it, and observe Failed(Cancelled) — proof the token
    // was delivered while decode was still running. The freed slot must
    // then serve a follow-up request. The fixture decodes fast, so a
    // single attempt can race the request to completion; any Cancelled
    // outcome within the attempts proves the path.
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(2))).unwrap();
    let mut cancelled_mid_decode = false;
    for _ in 0..10 {
        let handle = service.submit(req("cancel me mid flight", 8));
        // Wait for the first streamed token (request is in a slot now).
        loop {
            match handle.next_event().unwrap() {
                RequestEvent::Token { .. } => break,
                ev if ev.is_terminal() => panic!("terminal before first token: {ev:?}"),
                _ => {}
            }
        }
        handle.cancel();
        let outcome = handle.wait();
        match outcome {
            Err(ServiceError::Cancelled) => {
                cancelled_mid_decode = true;
                break;
            }
            Ok(c) => assert_eq!(c.tokens.len(), 8, "uncancelled run must still be exact"),
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(
        cancelled_mid_decode,
        "10 attempts never cancelled mid-decode — cancellation path is broken"
    );
    // The freed slot must admit and serve new work.
    let c = service.generate("after cancellation", Some(4)).unwrap();
    assert_eq!(c.tokens.len(), 4);
    // Cancellation released the router's load count: nothing outstanding.
    // (The worker sends the terminal event just before releasing the
    // count, so poll briefly instead of asserting instantaneously.)
    let t0 = Instant::now();
    while !service.router_snapshot().iter().all(|&(o, _)| o == 0) {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "router load leaked after cancellation: {:?}",
            service.router_snapshot()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    service.shutdown();
}

#[test]
fn dropping_a_handle_cancels_the_request() {
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(2))).unwrap();
    for _ in 0..4 {
        let handle = service.submit(req("dropped request", 8));
        drop(handle); // no terminal event observed -> cancels
    }
    // The service keeps serving and the dropped requests release their
    // router load counts (poll briefly: cancellation lands at the
    // worker's next sweep).
    let c = service.generate("survivor", Some(4)).unwrap();
    assert_eq!(c.tokens.len(), 4);
    let t0 = Instant::now();
    loop {
        if service.router_snapshot().iter().all(|&(o, _)| o == 0) {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "dropped handles never released the router: {:?}",
            service.router_snapshot()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    service.shutdown();
}

#[test]
fn unequal_speeds_skew_traffic_toward_fast_replica() {
    // Seeded speeds must skew live LeastLoaded routing toward the fast
    // replica. (The proportional 4:1 equilibrium is pinned by the router
    // unit test `speed_skews_traffic_proportionally`; here the ratio is
    // chosen so the outcome is invariant under any completion timing:
    // routing cost is (outstanding+1)/speed, and with 12 requests the
    // fast replica's cost never exceeds 13/100 while an idle slow
    // replica already costs 1/1 — so every pick is the fast replica, no
    // matter how the burst interleaves with retirements.)
    let mut cfg = two_replica_config(fixture_dir());
    cfg.speeds = Some(vec![100.0, 1.0]);
    cfg.adapt_speeds = false; // pin the seeds: this test is about them
    let service = HexGenService::start(cfg).unwrap();
    assert_eq!(service.router_speeds(), vec![100.0, 1.0]);

    let handles: Vec<_> =
        (0..12).map(|i| service.submit(req(&format!("skew probe {i}"), 4))).collect();
    let results = collect_all(handles, Duration::from_secs(120));
    let mut counts = [0usize; 2];
    for r in &results {
        counts[r.as_ref().expect("request failed").replica] += 1;
    }
    assert_eq!(counts, [12, 0], "all traffic must skew to the 100x replica");
    service.shutdown();
}

#[test]
fn adaptive_speeds_reflect_measured_throughput() {
    // With adapt_speeds on, serving traffic folds each replica's
    // measured decode rate into the router: effective speeds leave the
    // uniform 1.0 seeds and become real tokens/s figures.
    let service = HexGenService::start(two_replica_config(fixture_dir())).unwrap();
    let handles: Vec<_> =
        (0..6).map(|i| service.submit(req(&format!("adapt probe {i}"), 6))).collect();
    for r in collect_all(handles, Duration::from_secs(120)) {
        r.expect("request failed");
    }
    let speeds = service.router_speeds();
    assert_eq!(speeds.len(), 2);
    // Both replicas served traffic, so both report measured rates —
    // strictly positive and (being real token rates on this fixture)
    // far above the 1.0 seed scale.
    assert!(speeds.iter().all(|&s| s > 0.0), "{speeds:?}");
    assert!(speeds.iter().any(|&s| s != 1.0), "speeds never adapted: {speeds:?}");
    service.shutdown();
}

#[test]
fn scheduler_plan_lowers_and_serves_end_to_end() {
    // The plan→serve loop in-process: a llama2-70b-shaped scheduler plan
    // (as `hexgen schedule --emit-plan` writes) lowers onto the 2-layer
    // fixture manifest and boots the live service, with the plan's Eq. 2
    // cost estimates seeding the router speeds.
    use hexgen::coordinator::lower_plan;
    use hexgen::parallelism::{DeploymentPlan, PlanStage, ReplicaPlan};
    use hexgen::runtime::Manifest;

    let dir = fixture_dir();
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let plan = DeploymentPlan {
        cluster: "case-study".into(),
        model_name: "llama2-70b".into(),
        model_layers: 80,
        fitness: Some(0.9),
        replicas: vec![
            ReplicaPlan {
                stages: vec![
                    PlanStage { tp: 4, layers: 48, devices: vec![0, 1, 2, 3] },
                    PlanStage { tp: 2, layers: 20, devices: vec![4, 5] },
                    PlanStage { tp: 2, layers: 12, devices: vec![6, 7] },
                ],
                cost_estimate: Some(0.5),
                ..Default::default()
            },
            ReplicaPlan {
                stages: vec![PlanStage { tp: 1, layers: 80, devices: vec![8] }],
                cost_estimate: Some(2.0),
                ..Default::default()
            },
        ],
    };
    let lowered = lower_plan(&plan, &manifest).unwrap();
    assert_eq!(lowered.replicas.len(), 2);
    for p in &lowered.replicas {
        assert_eq!(p.iter().map(|s| s.layer_count).sum::<usize>(), manifest.model.layers);
        for s in p {
            assert!(manifest.tp_degrees.contains(&s.tp), "tp {} not compiled", s.tp);
        }
    }
    // 80 layers / tp 4 cannot serve verbatim on the fixture: the report
    // must say what was adjusted.
    assert!(!lowered.adjustments.is_empty());
    // plan costs 0.5s vs 2.0s → the first replica routes 4× faster
    assert!((lowered.speeds[0] / lowered.speeds[1] - 4.0).abs() < 1e-9, "{:?}", lowered.speeds);

    let service = HexGenService::start(ServiceConfig {
        artifacts_dir: dir,
        backend: BackendKind::Reference,
        replicas: lowered.replicas,
        batch: BatchPolicy { max_batch: 2, window: Duration::from_millis(5), continuous: true },
        route: RoutePolicy::LeastLoaded,
        speeds: Some(lowered.speeds),
        prefill_speeds: Some(lowered.prefill_speeds),
        roles: lowered.roles,
        adapt_speeds: true,
        max_new_tokens: 4,
        stop_token: None,
        kv: KvPolicy::default(),
        spec: None,
        faults: FaultPolicy::default(),
    })
    .unwrap();
    let c = service.generate("plan served prompt", Some(4)).unwrap();
    assert_eq!(c.tokens.len(), 4);
    service.shutdown();
}

#[test]
fn disaggregated_roles_serve_with_golden_parity_and_kv_transfer() {
    // The tentpole end-to-end: a mixed-role plan (one prefill-only, one
    // decode-only replica) serves the golden prompt with greedy-token
    // parity against the hybrid path, and the KV hand-off is metered.
    let (prompt, want) = golden();
    assert!(want.len() >= 2, "golden must decode past the first token");

    // Hybrid baseline: the fused path reproduces the golden tokens.
    let hybrid = HexGenService::start(two_replica_config(fixture_dir())).unwrap();
    let base = hybrid.generate(&prompt, Some(want.len())).unwrap();
    assert_eq!(base.tokens, want, "hybrid baseline diverged from golden");
    hybrid.shutdown();

    // Same replicas, disaggregated: prefill on the TP=2 stage, decode on
    // the TP=1 pipeline, KV segments crossing between them.
    let mut cfg = two_replica_config(fixture_dir());
    cfg.roles = vec![PhaseRole::Prefill, PhaseRole::Decode];
    let service = HexGenService::start(cfg).unwrap();
    let handles: Vec<_> = (0..3).map(|_| service.submit(req(&prompt, want.len()))).collect();
    for r in collect_all(handles, Duration::from_secs(120)) {
        let c = r.expect("disaggregated request failed");
        assert_eq!(c.tokens, want, "disaggregated serving diverged from golden greedy tokens");
        assert_eq!(c.replica, 1, "decode (and delivery) must happen on the decode-only replica");
    }
    let comm = service.comm_stats();
    assert!(comm.kv_transfers >= 3, "every request must ship one KV segment: {comm:?}");
    assert!(comm.kv_transfer_bytes > 0.0, "KV hand-off bytes must be metered: {comm:?}");
    let stats = service.stats();
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.failed + stats.cancelled, 0);
    service.shutdown();
}

#[test]
fn startup_rejects_unservable_role_mixes() {
    let mut cfg = two_replica_config(fixture_dir());
    cfg.roles = vec![PhaseRole::Prefill]; // length mismatch
    assert!(HexGenService::start(cfg).is_err());

    let mut cfg = two_replica_config(fixture_dir());
    cfg.roles = vec![PhaseRole::Prefill, PhaseRole::Prefill]; // no decode partner
    assert!(HexGenService::start(cfg).is_err());

    let mut cfg = two_replica_config(fixture_dir());
    cfg.roles = vec![PhaseRole::Decode, PhaseRole::Decode]; // no entry point
    assert!(HexGenService::start(cfg).is_err());

    // ...but an explicit all-hybrid role vector is fine.
    let mut cfg = two_replica_config(fixture_dir());
    cfg.roles = vec![PhaseRole::Hybrid, PhaseRole::Hybrid];
    let service = HexGenService::start(cfg).unwrap();
    let c = service.generate("explicit hybrid roles", Some(3)).unwrap();
    assert_eq!(c.tokens.len(), 3);
    service.shutdown();
}

#[test]
fn http_surfaces_phase_roles_and_kv_transfers() {
    use std::io::{Read as _, Write as _};

    fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        write!(conn, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        resp
    }
    fn body(resp: &str) -> &str {
        resp.split("\r\n\r\n").nth(1).expect("response has no body")
    }

    let mut cfg = two_replica_config(fixture_dir());
    cfg.roles = vec![PhaseRole::Prefill, PhaseRole::Decode];
    let service = std::sync::Arc::new(HexGenService::start(cfg).unwrap());
    let c = service.generate("metrics probe", Some(4)).unwrap();
    assert_eq!(c.tokens.len(), 4);

    let server = HttpServer::serve(service.clone(), "127.0.0.1:0").unwrap();
    // /metrics: the hand-off shows up under comm.
    let resp = http_get(server.addr(), "/metrics");
    let j = Json::parse(body(&resp)).unwrap();
    let comm = j.get("comm").unwrap();
    assert!(comm.get("kv_transfer_bytes").unwrap().as_f64().unwrap() > 0.0, "{resp}");
    assert!(comm.get("kv_transfers_total").unwrap().as_usize().unwrap() >= 1, "{resp}");
    // /v1/plan: per-replica phase roles and both speed views.
    let resp = http_get(server.addr(), "/v1/plan");
    let j = Json::parse(body(&resp)).unwrap();
    let replicas = j.arr("replicas").unwrap();
    assert_eq!(replicas[0].str("phase_role").unwrap(), "prefill", "{resp}");
    assert_eq!(replicas[1].str("phase_role").unwrap(), "decode", "{resp}");
    assert_eq!(j.arr("prefill_speeds").unwrap().len(), 2, "{resp}");
    server.shutdown();
}

#[test]
fn shared_prefix_probe_skips_prefill_compute() {
    // A full-prefix cache hit with a memoized first token admits without
    // a prefill forward pass. Prefix entries live only while their
    // blocks do, so the probe must overlap the anchor: submit the same
    // prompt while the anchor is still decoding (its prompt blocks are
    // live and its first token is memoized). The fixture decodes fast,
    // so a single attempt can race the anchor to retirement; any skip
    // within the attempts proves the path — and greedy parity must hold
    // on every attempt, skipped or computed.
    let service =
        HexGenService::start(one_replica_config(fixture_dir(), Duration::from_millis(2))).unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut skipped = false;
    for _ in 0..10 {
        let anchor = service.submit(req("memoized prefix probe", 8));
        // Wait for the anchor's first token: prefill is done, the memo
        // is set, and the prompt blocks stay live while it decodes.
        loop {
            match anchor.next_event().unwrap() {
                RequestEvent::Token { .. } => break,
                ev if ev.is_terminal() => panic!("terminal before first token: {ev:?}"),
                _ => {}
            }
        }
        let probe = service.submit(req("memoized prefix probe", 4));
        let probe = probe.wait_deadline(deadline).unwrap();
        let anchor = anchor.wait_deadline(deadline).unwrap();
        assert_eq!(anchor.tokens.len(), 8);
        assert_eq!(
            probe.tokens,
            anchor.tokens[..4],
            "shared-prefix probe must reproduce the anchor's greedy tokens"
        );
        // Stats publish at step boundaries: poll briefly per attempt.
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(500) {
            if service.stats().prefill_skips > 0 {
                skipped = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        if skipped {
            break;
        }
    }
    assert!(skipped, "10 overlapping probes never skipped prefill: {:?}", service.stats());
    service.shutdown();
}

#[test]
fn static_mode_still_serves() {
    // The run-to-completion baseline (continuous = false) must stay a
    // working configuration — it is what benches/batching.rs compares
    // against — and per-row max_new holds there too.
    let mut cfg = one_replica_config(fixture_dir(), Duration::from_secs(2));
    cfg.batch.continuous = false;
    let service = HexGenService::start(cfg).unwrap();
    let h_a = service.submit(req("static mode a", 2));
    let h_b = service.submit(req("static mode b", 5));
    let deadline = Instant::now() + Duration::from_secs(120);
    let a = h_a.wait_deadline(deadline).unwrap();
    let b = h_b.wait_deadline(deadline).unwrap();
    assert_eq!(a.tokens.len(), 2);
    assert_eq!(b.tokens.len(), 5);
    service.shutdown();
}

// ---------------------------------------------------------------- chaos

/// A trigger-less fault-spec template for the chaos suite: callers fill
/// in exactly one trigger (`nth`, `after`, or `probability`) via struct
/// update syntax.
fn chaos_spec(replica: Option<usize>, op: FaultOp, kind: FaultKind) -> FaultSpec {
    FaultSpec {
        replica,
        op,
        nth: None,
        after: None,
        until: None,
        probability: None,
        kind,
        message: "chaos".to_string(),
    }
}

#[test]
fn chaos_mid_decode_fault_fails_over_with_golden_parity() {
    // A replica faulting mid-decode must not corrupt the stream: the
    // request emits Retrying, fails over to the healthy replica, replays
    // the tokens it already streamed without re-emitting them, and the
    // completed output is byte-identical to an undisturbed greedy run
    // (the fixture's golden tokens), with contiguous stream indexes.
    let (prompt, want) = golden();
    assert!(want.len() >= 2, "golden must decode past the first token");

    let mut cfg = two_replica_config(fixture_dir());
    cfg.speeds = Some(vec![100.0, 1.0]); // pin the first pick to replica 0
    cfg.adapt_speeds = false;
    cfg.faults.plan = Some(FaultPlan {
        seed: 0,
        faults: vec![FaultSpec {
            nth: Some(1),
            ..chaos_spec(Some(0), FaultOp::Decode, FaultKind::Error)
        }],
    });
    let service = HexGenService::start(cfg).unwrap();

    let handle = service.submit(req(&prompt, want.len()));
    let mut events = Vec::new();
    loop {
        let ev = handle.next_event().unwrap();
        let terminal = ev.is_terminal();
        events.push(ev);
        if terminal {
            break;
        }
    }
    assert!(
        events
            .iter()
            .any(|e| matches!(e, RequestEvent::Retrying { replica: 0, attempt: 1 })),
        "{events:?}"
    );
    let streamed: Vec<i32> = events
        .iter()
        .filter_map(|e| match e {
            RequestEvent::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    let indexes: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            RequestEvent::Token { index, .. } => Some(*index),
            _ => None,
        })
        .collect();
    assert_eq!(
        indexes,
        (0..want.len()).collect::<Vec<_>>(),
        "token indexes must stay contiguous across the failover"
    );
    let RequestEvent::Done(c) = events.last().unwrap() else {
        panic!("expected Done terminal, got {:?}", events.last());
    };
    assert_eq!(c.tokens, want, "failover diverged from the undisturbed greedy run");
    assert_eq!(streamed, c.tokens, "streamed tokens must match the completion");
    assert_eq!(c.replica, 1, "delivery must come from the failover replica");

    let stats = service.stats();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.retries, 1);
    assert_eq!(stats.failovers, 1);
    assert_eq!(stats.requests_lost, 0);
    service.shutdown();
}

#[test]
fn chaos_retry_budget_exhausts_to_replica_failed() {
    // A replica that faults on every decode call: the request burns its
    // full retry budget (exactly max_retries Retrying events, i.e.
    // max_retries + 1 attempts) and then fails typed — no hang, no
    // panic. The breaker is set loose so the sole replica stays
    // routable throughout; what runs out is the per-request budget.
    let mut cfg = one_replica_config(fixture_dir(), Duration::from_millis(2));
    cfg.faults = FaultPolicy {
        plan: Some(FaultPlan {
            seed: 0,
            faults: vec![FaultSpec {
                after: Some(0),
                ..chaos_spec(Some(0), FaultOp::Decode, FaultKind::Error)
            }],
        }),
        max_retries: 2,
        retry_backoff: Duration::from_millis(2),
        breaker: BreakerPolicy { consecutive_faults: 100, ..BreakerPolicy::default() },
    };
    let service = HexGenService::start(cfg).unwrap();

    let handle = service.submit(req("doomed request", 4));
    let mut retrying = 0u32;
    let outcome = loop {
        match handle.next_event().unwrap() {
            RequestEvent::Retrying { replica: 0, attempt } => {
                retrying += 1;
                assert_eq!(attempt, retrying, "attempts must count up from 1");
            }
            RequestEvent::Failed(e) => break Err(e),
            RequestEvent::Done(c) => break Ok(c),
            _ => {}
        }
    };
    match outcome {
        Err(ServiceError::ReplicaFailed { replica: 0, message }) => {
            assert!(message.contains("injected fault"), "{message}");
        }
        other => panic!("expected ReplicaFailed, got {other:?}"),
    }
    assert_eq!(retrying, 2, "exactly max_retries Retrying events, then failure");
    let stats = service.stats();
    assert_eq!(stats.retries, 2);
    assert_eq!(stats.requests_lost, 1);
    assert_eq!(stats.failed, 1);
    service.shutdown();
}

#[test]
fn chaos_breaker_quarantines_then_recovers_through_half_open_probe() {
    // The router circuit breaker end-to-end: a one-strike policy
    // quarantines the faulting replica, traffic drains to the healthy
    // one, the quarantine lapses into half-open, and a successful
    // canary closes the breaker again.
    let mut cfg = two_replica_config(fixture_dir());
    cfg.speeds = Some(vec![100.0, 1.0]); // pin the first pick to replica 0
    cfg.adapt_speeds = false;
    cfg.faults = FaultPolicy {
        plan: Some(FaultPlan {
            seed: 0,
            faults: vec![FaultSpec {
                nth: Some(1),
                ..chaos_spec(Some(0), FaultOp::Decode, FaultKind::Error)
            }],
        }),
        max_retries: 2,
        retry_backoff: Duration::from_millis(5),
        breaker: BreakerPolicy {
            consecutive_faults: 1,
            quarantine: Duration::from_secs(1),
            probe_timeout: Duration::from_secs(60),
        },
    };
    let service = HexGenService::start(cfg).unwrap();

    // The first request trips the one-strike breaker on replica 0 and
    // completes on replica 1.
    let c = service.generate("breaker probe", Some(4)).unwrap();
    assert_eq!(c.tokens.len(), 4);
    assert_eq!(c.replica, 1, "failover must deliver from the healthy replica");
    assert_eq!(
        service.router_health()[0],
        ReplicaHealth::Quarantined,
        "one fault must quarantine under the one-strike policy"
    );

    // While quarantined, traffic keeps landing on replica 1 even though
    // replica 0 is seeded 100x faster.
    let c = service.generate("during quarantine", Some(2)).unwrap();
    assert_eq!(c.replica, 1, "quarantined replica must not be routed to");

    // The quarantine lapses into half-open...
    std::thread::sleep(Duration::from_millis(1200));
    assert_eq!(service.router_health()[0], ReplicaHealth::HalfOpen);

    // ...and a successful canary closes the breaker. The canary rides
    // normal traffic, so generate until replica 0 serves again (its
    // nth:1 fault is already consumed, so the probe succeeds).
    let t0 = Instant::now();
    loop {
        let c = service.generate("canary traffic", Some(2)).unwrap();
        if c.replica == 0 && service.router_health()[0] == ReplicaHealth::Healthy {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "breaker never closed: {:?}",
            service.router_health()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    service.shutdown();
}

#[test]
fn chaos_deadline_expiry_frees_kv_blocks() {
    // A stalling replica (every decode call sleeps) against a short
    // request deadline: the decode-step boundary notices the lapsed
    // deadline, fails the request typed, and returns every KV block to
    // the pool — a deadline is not a lost request.
    let mut cfg = one_replica_config(fixture_dir(), Duration::from_millis(2));
    cfg.faults.plan = Some(FaultPlan {
        seed: 0,
        faults: vec![FaultSpec {
            after: Some(0),
            ..chaos_spec(Some(0), FaultOp::Decode, FaultKind::Stall { ms: 60 })
        }],
    });
    let service = HexGenService::start(cfg).unwrap();
    assert!(service.stats().kv_blocks_total > 0);

    let handle = service.submit(req("slow boat", 8).with_deadline_ms(150));
    let outcome = handle.wait_deadline(Instant::now() + Duration::from_secs(60));
    assert_eq!(outcome, Err(ServiceError::DeadlineExceeded));

    // Stats and the pool gauge publish at step boundaries: poll briefly.
    let t0 = Instant::now();
    loop {
        let s = service.stats();
        if s.kv_blocks_used == 0 && s.deadline_expired == 1 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "blocks never freed: {s:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(service.stats().requests_lost, 0, "a deadline expiry is not a lost request");
    service.shutdown();
}

#[test]
fn chaos_seeded_fault_storm_loses_no_requests_and_drains_the_pool() {
    // A seeded storm of random faults — errors and stalls on any call,
    // plus a one-shot decode panic per replica — over block-starved
    // pools (one block per replica, so admission serializes and every
    // retry re-acquires blocks): every request still completes, nothing
    // is silently lost, and the pools drain back to fully free. The
    // `until` bound ends the storm after each replica's first 300
    // backend calls, so late retries always find calm weather, and the
    // fixed seed makes the fire schedule reproducible.
    let mut cfg = two_replica_config(fixture_dir());
    cfg.kv = KvPolicy { block_tokens: None, pool_blocks: Some(1) };
    cfg.batch = BatchPolicy { max_batch: 2, window: Duration::from_millis(2), continuous: true };
    cfg.faults = FaultPolicy {
        plan: Some(FaultPlan {
            seed: 0xC0FFEE,
            faults: vec![
                FaultSpec {
                    probability: Some(0.01),
                    until: Some(300),
                    ..chaos_spec(None, FaultOp::Any, FaultKind::Error)
                },
                FaultSpec {
                    probability: Some(0.02),
                    until: Some(300),
                    ..chaos_spec(None, FaultOp::Decode, FaultKind::Stall { ms: 2 })
                },
                FaultSpec { nth: Some(7), ..chaos_spec(None, FaultOp::Decode, FaultKind::Panic) },
            ],
        }),
        max_retries: 8,
        retry_backoff: Duration::from_millis(2),
        breaker: BreakerPolicy {
            consecutive_faults: 10,
            quarantine: Duration::from_millis(100),
            probe_timeout: Duration::from_secs(60),
        },
    };
    let service = HexGenService::start(cfg).unwrap();

    let handles: Vec<_> =
        (0..24).map(|i| service.submit(req(&format!("storm {i}"), 3))).collect();
    let results = collect_all(handles, Duration::from_secs(120));
    for r in &results {
        let c = r.as_ref().expect("storm request lost");
        assert_eq!(c.tokens.len(), 3, "survivors must still be exact");
    }
    let stats = service.stats();
    assert_eq!(stats.completed, 24);
    assert_eq!(stats.requests_lost, 0, "{stats:?}");
    assert_eq!(stats.failed + stats.cancelled, 0, "{stats:?}");
    // Every block returns to the pool once the storm clears (workers
    // publish at step boundaries, so poll briefly).
    let t0 = Instant::now();
    loop {
        let s = service.stats();
        if s.kv_blocks_used == 0 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "pool never drained: {s:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    service.shutdown();
}
