//! Parity tests: the pure-Rust [`ReferenceBackend`] must reproduce the
//! golden values emitted by `python/compile/make_ref_fixture.py` (which
//! runs the `python/compile/kernels/ref.py` oracles on the checked-in
//! 2-layer fixture model), and every asymmetric plan shape must agree
//! with them token-for-token.

use std::path::PathBuf;

use hexgen::coordinator::{add_residual, plan_from_strategy, PipelineExecutor};
use hexgen::runtime::{
    load_backend, tokenizer, BackendKind, ExecutionBackend, FunctionalBackend, InputArg,
    KvPolicy, ReferenceBackend, Tensor, WeightStore,
};
use hexgen::util::json::Json;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo")
}

/// The 1-layer draft companion model for the speculative tests
/// (`make_ref_fixture.py --draft`).
fn draft_dir() -> PathBuf {
    fixture_dir().join("draft")
}

/// Executor over `dir` — the hot path (in-place caches, threaded TP
/// shards, bucket down-shift) or the seed-pinned functional baseline.
fn exec_at(functional: bool, dir: &PathBuf, tps: &[usize], layers: &[usize]) -> PipelineExecutor {
    let be: Box<dyn ExecutionBackend> = if functional {
        Box::new(FunctionalBackend::load(dir).unwrap())
    } else {
        Box::new(ReferenceBackend::load(dir).unwrap())
    };
    PipelineExecutor::with_backend(be, plan_from_strategy(tps, layers).unwrap()).unwrap()
}

fn exec_with(functional: bool, tps: &[usize], layers: &[usize]) -> PipelineExecutor {
    exec_at(functional, &fixture_dir(), tps, layers)
}

fn golden() -> Json {
    let text = std::fs::read_to_string(fixture_dir().join("golden.json")).unwrap();
    Json::parse(&text).unwrap()
}

fn draft_golden() -> Json {
    let text = std::fs::read_to_string(draft_dir().join("golden.json")).unwrap();
    Json::parse(&text).unwrap()
}

fn golden_tokens(g: &Json, key: &str) -> Vec<i32> {
    g.arr(key)
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap() as i32)
        .collect()
}

/// Compose prefill manually through the stage artifacts at TP=1 and
/// return the logits (what the fused JAX model would produce).
fn manual_prefill_logits(be: &dyn ExecutionBackend, tokens: &[i32]) -> Tensor {
    let m = be.manifest().model.clone();
    assert_eq!(tokens.len(), m.prompt_len);
    let mut x = be
        .execute(
            "embed_prefill_b1",
            &[InputArg::I32(tokens, vec![1, m.prompt_len]), InputArg::Weight("embed")],
        )
        .unwrap()
        .remove(0);
    for layer in 0..m.layers {
        let ln1 = format!("layers.{layer}.ln1");
        let wq = WeightStore::shard_name(layer, "wq", 1, 0);
        let wk = WeightStore::shard_name(layer, "wk", 1, 0);
        let wv = WeightStore::shard_name(layer, "wv", 1, 0);
        let wo = WeightStore::shard_name(layer, "wo", 1, 0);
        let mut outs = be
            .execute(
                "attn_prefill_tp1_b1",
                &[
                    InputArg::F32(&x),
                    InputArg::Weight(&ln1),
                    InputArg::Weight(&wq),
                    InputArg::Weight(&wk),
                    InputArg::Weight(&wv),
                    InputArg::Weight(&wo),
                ],
            )
            .unwrap();
        let partial = outs.remove(0);
        add_residual(&mut x, &partial);
        let ln2 = format!("layers.{layer}.ln2");
        let w1 = WeightStore::shard_name(layer, "w1", 1, 0);
        let w2 = WeightStore::shard_name(layer, "w2", 1, 0);
        let mlp = be
            .execute(
                "mlp_prefill_tp1_b1",
                &[
                    InputArg::F32(&x),
                    InputArg::Weight(&ln2),
                    InputArg::Weight(&w1),
                    InputArg::Weight(&w2),
                ],
            )
            .unwrap()
            .remove(0);
        add_residual(&mut x, &mlp);
    }
    be.execute(
        "lm_head_prefill_b1",
        &[InputArg::F32(&x), InputArg::Weight("final_ln"), InputArg::Weight("lm_head")],
    )
    .unwrap()
    .remove(0)
}

#[test]
fn prefill_logits_match_python_golden_values() {
    let g = golden();
    let be = ReferenceBackend::load(&fixture_dir()).unwrap();
    let prompt_tokens = golden_tokens(&g, "prompt_tokens");

    // The Rust tokenizer must agree with the fixture's encoding.
    let encoded = tokenizer::encode(g.str("prompt").unwrap(), prompt_tokens.len());
    assert_eq!(encoded, prompt_tokens, "tokenizer drifted from fixture");

    let logits = manual_prefill_logits(&be, &prompt_tokens);
    let want = g.arr("prefill_logits").unwrap();
    assert_eq!(logits.dims, vec![1, want.len()]);
    let mut max_err = 0f64;
    for (got, w) in logits.data.iter().zip(want) {
        let err = (*got as f64 - w.as_f64().unwrap()).abs();
        max_err = max_err.max(err);
    }
    assert!(max_err < 1e-3, "logits diverged from ref.py golden values: max_err={max_err}");
}

#[test]
fn every_plan_shape_reproduces_golden_greedy_tokens() {
    let g = golden();
    let prompt_tokens = golden_tokens(&g, "prompt_tokens");
    let want = golden_tokens(&g, "greedy_tokens");

    // Asymmetric TP×PP shapes over the 2-layer model: all must agree
    // with the fused ref.py oracle token-for-token.
    for (tps, layers) in [
        (vec![1usize], vec![2usize]), // single stage TP=1
        (vec![2], vec![2]),           // single stage TP=2
        (vec![1, 1], vec![1, 1]),     // 2-stage TP=1 pipeline
        (vec![2, 1], vec![1, 1]),     // asymmetric: TP=2 then TP=1
    ] {
        let be = load_backend(BackendKind::Reference, &fixture_dir()).unwrap();
        let plan = plan_from_strategy(&tps, &layers).unwrap();
        let exec = PipelineExecutor::with_backend(be, plan).unwrap();
        let result = exec.generate(&[prompt_tokens.clone()], want.len()).unwrap();
        assert_eq!(
            result.tokens[0],
            want,
            "plan {} diverged from ref.py golden tokens",
            exec.strategy_string()
        );
        // One token from prefill, the rest from true decode iterations.
        assert_eq!(result.decode_steps, want.len() - 1);
        assert_eq!(result.prefill_tokens, 1);
    }
}

#[test]
fn tp_collective_counts_match_plan() {
    let be = load_backend(BackendKind::Reference, &fixture_dir()).unwrap();
    let prompt = tokenizer::encode("hello", be.manifest().model.prompt_len);
    let plan = plan_from_strategy(&[2, 1], &[1, 1]).unwrap();
    let exec = PipelineExecutor::with_backend(be, plan).unwrap();
    let res = exec.generate(&[prompt], 3).unwrap();
    // Stage 0 has 1 layer at TP=2 → 2 all-reduces per token step; stage 1
    // at TP=1 contributes none. 3 token steps (prefill + 2 decode) → 6.
    assert_eq!(res.comm.allreduce_ops, 6, "{:?}", res.comm);
    // One PP hand-off per token step.
    assert_eq!(res.comm.pp_sends, 3);
    assert!(res.comm.allreduce_bytes > 0.0 && res.comm.pp_bytes > 0.0);
    assert!(exec.backend().exec_count() > 0);
}

#[test]
fn batch_bucket_padding_is_transparent() {
    let dir = fixture_dir();
    let be = load_backend(BackendKind::Reference, &dir).unwrap();
    let prompt_len = be.manifest().model.prompt_len;
    let p1 = tokenizer::encode("first", prompt_len);
    let p2 = tokenizer::encode("second!", prompt_len);
    let exec =
        PipelineExecutor::with_backend(be, plan_from_strategy(&[2], &[2]).unwrap()).unwrap();

    // batch of 2 → bucket 2; results must equal per-request runs (b=1).
    let joint = exec.generate(&[p1.clone(), p2.clone()], 4).unwrap();
    assert_eq!(joint.bucket, 2);
    assert_eq!(joint.tokens.len(), 2);
    let solo1 = exec.generate(&[p1], 4).unwrap();
    let solo2 = exec.generate(&[p2], 4).unwrap();
    assert_eq!(joint.tokens[0], solo1.tokens[0]);
    assert_eq!(joint.tokens[1], solo2.tokens[0]);
}

#[test]
fn invalid_plans_rejected() {
    let dir = fixture_dir();
    // layer sum mismatch
    assert!(PipelineExecutor::with_backend(
        load_backend(BackendKind::Reference, &dir).unwrap(),
        plan_from_strategy(&[1], &[1]).unwrap()
    )
    .is_err());
    // unsupported tp degree
    assert!(PipelineExecutor::with_backend(
        load_backend(BackendKind::Reference, &dir).unwrap(),
        plan_from_strategy(&[4], &[2]).unwrap()
    )
    .is_err());
    // non-contiguous stages
    use hexgen::coordinator::StagePlan;
    let bad = vec![
        StagePlan { layer_start: 0, layer_count: 1, tp: 1 },
        StagePlan { layer_start: 2, layer_count: 1, tp: 1 },
    ];
    assert!(PipelineExecutor::with_backend(
        load_backend(BackendKind::Reference, &dir).unwrap(),
        bad
    )
    .is_err());
}

#[test]
fn hot_path_generate_matches_functional_and_golden() {
    // The rebuilt decode hot path (in-place KV caches, threaded TP
    // shards, tiled matmul) must stay bit-identical to the seed's
    // functional path — both pinned to the ref.py golden tokens.
    let g = golden();
    let prompt = golden_tokens(&g, "prompt_tokens");
    let want = golden_tokens(&g, "greedy_tokens");
    for (tps, layers) in [
        (vec![1usize], vec![2usize]),
        (vec![2], vec![2]),
        (vec![2, 1], vec![1, 1]),
    ] {
        let hot = exec_with(false, &tps, &layers);
        let seed = exec_with(true, &tps, &layers);
        let a = hot.generate(&[prompt.clone()], want.len()).unwrap();
        let b = seed.generate(&[prompt.clone()], want.len()).unwrap();
        assert_eq!(
            a.tokens[0],
            want,
            "in-place hot path diverged from golden at {}",
            hot.strategy_string()
        );
        assert_eq!(b.tokens[0], want, "functional baseline diverged from golden");
    }
}

#[test]
fn threaded_staggered_admission_and_cancel_match_functional_path() {
    // Drive an identical admission/step/cancel/readmit schedule over the
    // hot path (threaded tp=2 shards, in-place caches, down-shifted
    // single-row steps) and the serial functional baseline; every step
    // outcome must agree exactly.
    fn drive(exec: &PipelineExecutor) -> Vec<(usize, Vec<i32>)> {
        let prompt_len = exec.manifest().model.prompt_len;
        let pa = tokenizer::encode("doomed row", prompt_len);
        let pb = tokenizer::encode("survivor", prompt_len);
        let pc = tokenizer::encode("late join", prompt_len);
        let mut session = exec.new_session(2).unwrap();
        let mut events: Vec<(usize, Vec<i32>)> = Vec::new();
        let mut record = |tag: usize, toks: Vec<i32>| events.push((tag, toks));
        let out = session
            .prefill_into_slots(vec![
                (0, hexgen::coordinator::SlotRequest { prompt: pa, max_new: 8, stop: None }),
                (1, hexgen::coordinator::SlotRequest { prompt: pb, max_new: 8, stop: None }),
            ])
            .unwrap();
        record(100, out.tokens.iter().map(|&(_, t)| t).collect());
        for _ in 0..2 {
            let step = session.decode_step().unwrap();
            record(101, step.tokens.iter().map(|&(_, t)| t).collect());
        }
        record(102, session.cancel_slot(0).unwrap().unwrap());
        // Survivor alone: the hot path down-shifts this step to bucket 1.
        let step = session.decode_step().unwrap();
        record(101, step.tokens.iter().map(|&(_, t)| t).collect());
        let out = session
            .prefill_into_slots(vec![(
                0,
                hexgen::coordinator::SlotRequest { prompt: pc, max_new: 4, stop: None },
            )])
            .unwrap();
        record(100, out.tokens.iter().map(|&(_, t)| t).collect());
        while session.active() > 0 {
            for (slot, toks) in session.decode_step().unwrap().finished {
                events.push((slot, toks));
            }
        }
        events
    }
    let hot = exec_with(false, &[2], &[2]);
    assert!(hot.backend().sync_view().is_some(), "hot path must expose threaded shards");
    let seed = exec_with(true, &[2], &[2]);
    assert!(seed.backend().sync_view().is_none(), "baseline must stay serial");
    assert_eq!(drive(&hot), drive(&seed), "hot decode path diverged from the functional path");
}

#[test]
fn bucket_downshift_tracks_live_rows_when_draining() {
    // Mixed max_new drains the batch mid-flight: once row 0 retires, the
    // hot path shapes each step to bucket 1. Tokens must match the solo
    // runs bit-for-bit and the per-step AllReduce traffic must shrink
    // with the live rows (the honest Eq. 2 decode-cost signal).
    use hexgen::coordinator::SlotRequest;
    let exec = exec_with(false, &[2], &[2]);
    let prompt_len = exec.manifest().model.prompt_len;
    let p1 = tokenizer::encode("short", prompt_len);
    let p2 = tokenizer::encode("longer request", prompt_len);
    let solo1 = exec.generate(&[p1.clone()], 2).unwrap().tokens[0].clone();
    let solo2 = exec.generate(&[p2.clone()], 6).unwrap().tokens[0].clone();

    let mut session = exec.new_session(2).unwrap();
    session
        .prefill_into_slots(vec![
            (0, SlotRequest { prompt: p1, max_new: 2, stop: None }),
            (1, SlotRequest { prompt: p2, max_new: 6, stop: None }),
        ])
        .unwrap();
    session.take_comm();
    let mut finished = std::collections::BTreeMap::new();
    // Step 1 runs with both rows live (full bucket 2) and retires row 0.
    let out = session.decode_step().unwrap();
    assert_eq!(out.finished.len(), 1, "row 0 retires at its max_new");
    let full_bytes = session.take_comm().allreduce_bytes;
    for (slot, toks) in out.finished {
        finished.insert(slot, toks);
    }
    // Step 2 has one live row: the step down-shifts to bucket 1, halving
    // the reduced activation bytes.
    let out = session.decode_step().unwrap();
    let compact_bytes = session.take_comm().allreduce_bytes;
    assert!(
        compact_bytes * 1.9 < full_bytes,
        "down-shifted step must move ~half the bytes: {compact_bytes} vs {full_bytes}"
    );
    for (slot, toks) in out.finished {
        finished.insert(slot, toks);
    }
    while session.active() > 0 {
        for (slot, toks) in session.decode_step().unwrap().finished {
            finished.insert(slot, toks);
        }
    }
    assert_eq!(finished[&0], solo1, "drained row diverged from its solo run");
    assert_eq!(finished[&1], solo2, "surviving row perturbed by the bucket down-shift");
}

#[test]
fn staggered_admission_matches_solo_runs() {
    // The continuous-batching core claim: a request admitted into an
    // in-flight batch at a decode-step boundary decodes token-for-token
    // as if it ran alone, and each row stops at its own max_new.
    use hexgen::coordinator::SlotRequest;
    let dir = fixture_dir();
    let exec = PipelineExecutor::with_backend(
        load_backend(BackendKind::Reference, &dir).unwrap(),
        plan_from_strategy(&[2, 1], &[1, 1]).unwrap(),
    )
    .unwrap();
    let prompt_len = exec.manifest().model.prompt_len;
    let pa = tokenizer::encode("first long request", prompt_len);
    let pb = tokenizer::encode("late joiner", prompt_len);
    let solo_a = exec.generate(&[pa.clone()], 8).unwrap().tokens[0].clone();
    let solo_b = exec.generate(&[pb.clone()], 3).unwrap().tokens[0].clone();

    let mut session = exec.new_session(2).unwrap();
    assert_eq!(session.bucket(), 2);
    assert_eq!(session.free_slots(), vec![0, 1]);
    let out = session
        .prefill_into_slots(vec![(0, SlotRequest { prompt: pa, max_new: 8, stop: None })])
        .unwrap();
    assert!(out.finished.is_empty());
    assert_eq!(out.tokens.len(), 1, "prefill reports the admitted row's first token");
    assert_eq!(session.active(), 1);

    // Three decode steps with A alone, then admit B mid-flight. Every
    // step must report A's new token even though nothing finished.
    for _ in 0..3 {
        let step = session.decode_step().unwrap();
        assert!(step.finished.is_empty());
        assert_eq!(step.tokens.len(), 1, "in-flight rows stream one token per step");
        assert_eq!(step.tokens[0].0, 0);
    }
    let out = session
        .prefill_into_slots(vec![(1, SlotRequest { prompt: pb, max_new: 3, stop: None })])
        .unwrap();
    assert!(out.finished.is_empty());
    assert_eq!(session.active(), 2);

    let mut done = std::collections::BTreeMap::new();
    while session.active() > 0 {
        for (slot, toks) in session.decode_step().unwrap().finished {
            done.insert(slot, toks);
        }
    }
    // B (admitted at step 3, max_new 3) retired while A was still
    // decoding; both match their solo greedy runs exactly.
    assert_eq!(done[&1].len(), 3);
    assert_eq!(done[&0].len(), 8);
    assert_eq!(done[&0], solo_a, "in-flight row perturbed by admission");
    assert_eq!(done[&1], solo_b, "late-admitted row diverged from solo run");
    // A needed 7 decode iterations; B's 2 rode along within them.
    assert_eq!(session.decode_steps(), 7);
}

#[test]
fn cancel_slot_frees_mid_decode_and_readmits() {
    // Deterministic session-level cancellation: cancel a row mid-decode,
    // admit a queued request into the freed slot, and verify the
    // survivor and the newcomer both match their solo greedy runs.
    use hexgen::coordinator::SlotRequest;
    let dir = fixture_dir();
    let exec = PipelineExecutor::with_backend(
        load_backend(BackendKind::Reference, &dir).unwrap(),
        plan_from_strategy(&[1], &[2]).unwrap(),
    )
    .unwrap();
    let prompt_len = exec.manifest().model.prompt_len;
    // Distinct once left-truncated to the 8-token prompt_len ("doomed
    // request"-style pairs would collapse to the same " request" tail).
    let pa = tokenizer::encode("doomed row", prompt_len);
    let pb = tokenizer::encode("survivor", prompt_len);
    let pc = tokenizer::encode("late join", prompt_len);
    let solo_b = exec.generate(&[pb.clone()], 8).unwrap().tokens[0].clone();
    let solo_c = exec.generate(&[pc.clone()], 4).unwrap().tokens[0].clone();

    let mut session = exec.new_session(2).unwrap();
    session
        .prefill_into_slots(vec![
            (0, SlotRequest { prompt: pa, max_new: 8, stop: None }),
            (1, SlotRequest { prompt: pb, max_new: 8, stop: None }),
        ])
        .unwrap();
    for _ in 0..2 {
        session.decode_step().unwrap();
    }
    assert_eq!(session.active(), 2);

    // Cancel A at the step boundary: prefill token + 2 decode tokens so
    // far, slot 0 freed for admission. The cancel releases only A's KV
    // blocks — the cancel→readmit parity below pins that this is enough.
    let partial = session.cancel_slot(0).unwrap().expect("active row must cancel");
    assert_eq!(partial.len(), 3, "partial tokens generated before cancellation");
    assert_eq!(session.active(), 1);
    assert_eq!(session.free_slots(), vec![0]);
    assert!(session.cancel_slot(0).unwrap().is_none(), "double-cancel is a no-op");

    // Let the survivor decode on with the slot idle before readmitting
    // (the freed slot must stay clean across intervening steps).
    for _ in 0..2 {
        session.decode_step().unwrap();
    }

    // The freed slot serves a new request; B is unperturbed.
    session
        .prefill_into_slots(vec![(0, SlotRequest { prompt: pc, max_new: 4, stop: None })])
        .unwrap();
    let mut done = std::collections::BTreeMap::new();
    while session.active() > 0 {
        for (slot, toks) in session.decode_step().unwrap().finished {
            done.insert(slot, toks);
        }
    }
    assert_eq!(done[&0], solo_c, "readmitted row diverged from its solo run");
    assert_eq!(done[&1], solo_b, "surviving row perturbed by cancellation");
}

#[test]
fn per_row_max_new_truncates_each_row() {
    let dir = fixture_dir();
    let exec = PipelineExecutor::with_backend(
        load_backend(BackendKind::Reference, &dir).unwrap(),
        plan_from_strategy(&[2], &[2]).unwrap(),
    )
    .unwrap();
    let prompt_len = exec.manifest().model.prompt_len;
    let p1 = tokenizer::encode("short", prompt_len);
    let p2 = tokenizer::encode("longer request", prompt_len);
    let r = exec.generate_with_limits(&[p1.clone(), p2.clone()], &[2, 6]).unwrap();
    assert_eq!(r.tokens[0].len(), 2, "row 0 must stop at its own max_new");
    assert_eq!(r.tokens[1].len(), 6);
    assert_eq!(r.decode_steps, 5, "batch decodes to the longest row only");
    assert_eq!(r.prefill_tokens, 2);
    // Both rows match their solo runs despite the mixed limits.
    assert_eq!(r.tokens[0], exec.generate(&[p1], 2).unwrap().tokens[0]);
    assert_eq!(r.tokens[1], exec.generate(&[p2], 6).unwrap().tokens[0]);
}

#[test]
fn stop_token_retires_row_early() {
    use hexgen::coordinator::SlotRequest;
    let g = golden();
    let prompt = golden_tokens(&g, "prompt_tokens");
    let want = golden_tokens(&g, "greedy_tokens");
    // The golden greedy sequence emits `want[2]` at its third step; with
    // that as the stop token the row must retire right there.
    let dir = fixture_dir();
    let exec = PipelineExecutor::with_backend(
        load_backend(BackendKind::Reference, &dir).unwrap(),
        plan_from_strategy(&[1], &[2]).unwrap(),
    )
    .unwrap();
    let mut session = exec.new_session(1).unwrap();
    let out = session
        .prefill_into_slots(vec![(
            0,
            SlotRequest { prompt, max_new: want.len(), stop: Some(want[2]) },
        )])
        .unwrap();
    assert!(out.finished.is_empty());
    let mut got = None;
    while session.active() > 0 {
        for (_, toks) in session.decode_step().unwrap().finished {
            got = Some(toks);
        }
    }
    assert_eq!(got.unwrap(), want[..3].to_vec());
    assert_eq!(session.decode_steps(), 2);
}

#[test]
fn paged_backing_matches_golden_at_odd_block_sizes() {
    // Paged KV backing is a storage change, not a numeric one: decoding
    // over 3-, 5-, and 16-token blocks (misaligned and aligned with the
    // 8-token fixture prompt) must reproduce the golden greedy tokens
    // bit-for-bit, and the drained session must return every block.
    use hexgen::coordinator::SlotRequest;
    let g = golden();
    let prompt = golden_tokens(&g, "prompt_tokens");
    let want = golden_tokens(&g, "greedy_tokens");
    for bt in [3usize, 5, 16] {
        let exec = exec_with(false, &[2], &[2]);
        let mut session = exec
            .new_session_with(2, KvPolicy { block_tokens: Some(bt), pool_blocks: None })
            .unwrap();
        assert_eq!(session.block_tokens(), bt);
        session
            .prefill_into_slots(vec![(
                0,
                SlotRequest { prompt: prompt.clone(), max_new: want.len(), stop: None },
            )])
            .unwrap();
        let mut got = None;
        while session.active() > 0 {
            for (_, toks) in session.decode_step().unwrap().finished {
                got = Some(toks);
            }
        }
        assert_eq!(got.unwrap(), want, "paged decode at block_tokens={bt} diverged from golden");
        assert!(session.kv_pool_fully_free(), "pool leaked blocks at block_tokens={bt}");
    }
}

#[test]
fn shared_prefix_cow_staggered_rows_match_solo_runs() {
    // Prefix sharing across staggered admissions: a late row with the
    // same prompt reuses the in-flight row's prompt blocks (refcounted,
    // zero copies at admission) and copy-on-writes the shared partial
    // tail at its first own append. Both rows must still match their
    // solo greedy runs exactly.
    use hexgen::coordinator::SlotRequest;
    let exec = exec_with(false, &[2], &[2]);
    let prompt_len = exec.manifest().model.prompt_len;
    let p = tokenizer::encode("shared prefix", prompt_len);
    let solo8 = exec.generate(&[p.clone()], 8).unwrap().tokens[0].clone();
    let solo4 = exec.generate(&[p.clone()], 4).unwrap().tokens[0].clone();

    // block_tokens=5 splits the 8-token prompt into one full shared
    // chunk and one partial tail chunk.
    let mut session =
        exec.new_session_with(2, KvPolicy { block_tokens: Some(5), pool_blocks: None }).unwrap();
    session
        .prefill_into_slots(vec![(0, SlotRequest { prompt: p.clone(), max_new: 8, stop: None })])
        .unwrap();
    for _ in 0..2 {
        session.decode_step().unwrap();
    }
    assert_eq!(session.prefix_cache_hits(), 0);
    session
        .prefill_into_slots(vec![(1, SlotRequest { prompt: p.clone(), max_new: 4, stop: None })])
        .unwrap();
    assert_eq!(session.prefix_cache_hits(), 2, "late same-prompt row must hit both chunks");
    // Both rows resident, yet the prompt occupies one set of blocks: the
    // late row added no physical blocks at admission.
    assert_eq!(session.kv_blocks_used(), 2, "shared prompt must not duplicate blocks");

    let mut done = std::collections::BTreeMap::new();
    while session.active() > 0 {
        for (slot, toks) in session.decode_step().unwrap().finished {
            done.insert(slot, toks);
        }
    }
    assert_eq!(done[&0], solo8, "in-flight row perturbed by prefix sharing");
    assert_eq!(done[&1], solo4, "shared-prefix row diverged from its solo run");
    assert!(session.kv_pool_fully_free(), "retired rows must return every shared block");
}

#[test]
fn block_pool_drains_to_fully_free_on_every_exit_path() {
    // The leak invariant: retirement (including max_new=1 insta-finish
    // at prefill), cancellation, and readmission into a freed slot all
    // return their blocks and reservations — the pool is fully free
    // whenever the session is drained.
    use hexgen::coordinator::SlotRequest;
    let exec = exec_with(false, &[2], &[2]);
    let prompt_len = exec.manifest().model.prompt_len;
    let pa = tokenizer::encode("retire path", prompt_len);
    let pb = tokenizer::encode("cancel path", prompt_len);
    let mut session =
        exec.new_session_with(2, KvPolicy { block_tokens: Some(3), pool_blocks: None }).unwrap();
    assert!(session.kv_pool_fully_free());

    session
        .prefill_into_slots(vec![
            (0, SlotRequest { prompt: pa.clone(), max_new: 3, stop: None }),
            (1, SlotRequest { prompt: pb.clone(), max_new: 1, stop: None }),
        ])
        .unwrap();
    while session.active() > 0 {
        session.decode_step().unwrap();
    }
    assert!(session.kv_pool_fully_free(), "retired rows leaked blocks");

    // Cancel mid-decode, readmit into the freed slot (sharing the live
    // neighbour's identical prompt), and drain.
    session
        .prefill_into_slots(vec![
            (0, SlotRequest { prompt: pa.clone(), max_new: 8, stop: None }),
            (1, SlotRequest { prompt: pb.clone(), max_new: 8, stop: None }),
        ])
        .unwrap();
    session.decode_step().unwrap();
    session.cancel_slot(0).unwrap().unwrap();
    session
        .prefill_into_slots(vec![(0, SlotRequest { prompt: pb.clone(), max_new: 2, stop: None })])
        .unwrap();
    assert!(session.prefix_cache_hits() > 0, "readmitted prompt must share live blocks");
    while session.active() > 0 {
        session.decode_step().unwrap();
    }
    assert_eq!(session.kv_blocks_used(), 0);
    assert!(session.kv_blocks_peak() > 0);
    assert!(session.kv_pool_fully_free(), "cancel/readmit leaked blocks or reservations");
}

#[test]
fn draft_fixture_reproduces_its_golden_greedy_tokens() {
    // The 1-layer draft model is a real artifacts directory of its own:
    // solo greedy decode over it must match the ref.py golden stream,
    // on both the hot path and the functional baseline.
    let g = draft_golden();
    let prompt = golden_tokens(&g, "prompt_tokens");
    let want = golden_tokens(&g, "greedy_tokens");
    for functional in [false, true] {
        let exec = exec_at(functional, &draft_dir(), &[1], &[1]);
        let got = exec.generate(&[prompt.clone()], want.len()).unwrap();
        assert_eq!(got.tokens[0], want, "draft model diverged from its golden (functional={functional})");
    }
}

/// Drive one speculative golden case end to end and pin it three ways:
/// the emitted stream must be token-identical to the target's plain
/// greedy stream, the per-round (proposed, accepted) pattern must match
/// the fixture's simulation exactly, and both sessions' block pools must
/// drain to fully free.
fn run_spec_case(
    target_exec: &PipelineExecutor,
    draft_exec: &PipelineExecutor,
    kv: KvPolicy,
    case: &Json,
) {
    use hexgen::coordinator::{SlotRequest, SpeculativeSession};
    let k = case.usize("k").unwrap();
    let max_new = case.usize("max_new").unwrap();
    let want = golden_tokens(case, "target_tokens");
    let prompt_len = target_exec.manifest().model.prompt_len;
    let prompt = tokenizer::encode(case.str("prompt").unwrap(), prompt_len);

    let mut spec = SpeculativeSession::new(
        target_exec.new_session_with(1, kv).unwrap(),
        draft_exec.new_session_with(1, kv).unwrap(),
        k,
    )
    .unwrap();
    let out = spec.admit(vec![(0, SlotRequest { prompt, max_new, stop: None })]).unwrap();
    let mut got: Vec<i32> = out.tokens.iter().map(|&(_, t)| t).collect();
    let mut finished = None;
    let mut rounds: Vec<(u64, u64)> = Vec::new();
    let mut prev = spec.stats();
    while spec.active() > 0 {
        let out = spec.spec_round().unwrap();
        let st = spec.stats();
        rounds.push((st.proposed - prev.proposed, st.accepted - prev.accepted));
        prev = st;
        got.extend(out.tokens.iter().map(|&(_, t)| t));
        for (_, toks) in out.finished {
            finished = Some(toks);
        }
    }
    let tag = format!("prompt {:?} k={k}", case.str("prompt").unwrap());
    // The parity contract: speculative output is token-identical to the
    // target decoding alone, for this acceptance pattern.
    assert_eq!(got, want, "speculative stream diverged from plain greedy ({tag})");
    assert_eq!(finished.expect("row must retire"), want, "retired row tokens ({tag})");
    let want_rounds: Vec<(u64, u64)> = case
        .arr("rounds")
        .unwrap()
        .iter()
        .map(|r| (r.usize("k_eff").unwrap() as u64, r.usize("m").unwrap() as u64))
        .collect();
    assert_eq!(rounds, want_rounds, "acceptance pattern diverged from fixture ({tag})");
    assert_eq!(prev.rounds as usize, want_rounds.len(), "round count ({tag})");
    assert_eq!(prev.proposed, case.usize("proposed").unwrap() as u64, "{tag}");
    assert_eq!(prev.accepted, case.usize("accepted").unwrap() as u64, "{tag}");
    assert!(spec.target().kv_pool_fully_free(), "target pool leaked blocks ({tag})");
    assert!(spec.draft().kv_pool_fully_free(), "draft pool leaked blocks ({tag})");
}

#[test]
fn speculative_decode_matches_plain_greedy_for_every_golden_acceptance_pattern() {
    // The fixture's cases cover full accepts (m == k_eff), partial
    // accepts, and zero accepts (asserted at generation time), so every
    // rollback shape runs here. Three executor configurations: the hot
    // reference path, the same over a TP=2→TP=1 pipeline with an odd
    // block size (rollbacks cross block boundaries), and the functional
    // baseline (which verifies through the default
    // `execute_attn_score_inplace` adapter rather than the reference
    // backend's batched kernel).
    let g = draft_golden();
    let cases = g.arr("spec_cases").unwrap();
    assert!(!cases.is_empty());
    let configs: [(bool, Vec<usize>, Vec<usize>, KvPolicy); 3] = [
        (false, vec![1], vec![2], KvPolicy::default()),
        (false, vec![2, 1], vec![1, 1], KvPolicy { block_tokens: Some(3), pool_blocks: None }),
        (true, vec![1], vec![2], KvPolicy::default()),
    ];
    for (functional, tps, layers, kv) in configs {
        let target_exec = exec_at(functional, &fixture_dir(), &tps, &layers);
        let draft_exec = exec_at(functional, &draft_dir(), &[1], &[1]);
        for case in cases {
            run_spec_case(&target_exec, &draft_exec, kv, case);
        }
    }
}

#[test]
fn speculative_stop_token_retires_mid_round() {
    // A stop token inside an accepted run must end the row right there —
    // same contract as plain decode (`stop_token_retires_row_early`),
    // through the speculative commit path.
    use hexgen::coordinator::{SlotRequest, SpeculativeSession};
    let g = golden();
    let prompt = golden_tokens(&g, "prompt_tokens");
    let want = golden_tokens(&g, "greedy_tokens");
    let target_exec = exec_with(false, &[1], &[2]);
    let draft_exec = exec_at(false, &draft_dir(), &[1], &[1]);
    let mut spec = SpeculativeSession::new(
        target_exec.new_session(1).unwrap(),
        draft_exec.new_session(1).unwrap(),
        3,
    )
    .unwrap();
    let out = spec
        .admit(vec![(
            0,
            SlotRequest { prompt, max_new: want.len(), stop: Some(want[2]) },
        )])
        .unwrap();
    let mut got: Vec<i32> = out.tokens.iter().map(|&(_, t)| t).collect();
    let mut finished = None;
    while spec.active() > 0 {
        let out = spec.spec_round().unwrap();
        got.extend(out.tokens.iter().map(|&(_, t)| t));
        for (_, toks) in out.finished {
            finished = Some(toks);
        }
    }
    assert_eq!(got, want[..3].to_vec(), "stop token must truncate the accepted run");
    assert_eq!(finished.unwrap(), want[..3].to_vec());
    assert!(spec.target().kv_pool_fully_free() && spec.draft().kv_pool_fully_free());
}

#[test]
fn randomized_rollback_interleaving_matches_solo_and_drains_pool() {
    // Fuzz the rollback machinery the way a speculation driver abuses
    // it: interleave plain decode steps, verify-then-truncate rounds
    // that write junk KV entries and roll them all back (committing only
    // the target's own greedy token, so parity is provable), random
    // cancellations, and staggered admissions with shared-prefix COW
    // rows — over an odd block size so truncations cross block
    // boundaries. Every completed request must match its solo greedy
    // run, and the drained pool must be fully free.
    use hexgen::coordinator::SlotRequest;
    use hexgen::util::rng::Xoshiro256pp;
    let exec = exec_with(false, &[2], &[2]);
    let prompt_len = exec.manifest().model.prompt_len;
    let reqs: [(&str, usize); 6] = [
        ("shared prefix", 8),
        ("shared prefix", 6),
        ("rollback torture", 7),
        ("late join", 5),
        ("shared prefix", 4),
        ("final row", 6),
    ];
    let solo: Vec<Vec<i32>> = reqs
        .iter()
        .map(|(p, n)| {
            exec.generate(&[tokenizer::encode(p, prompt_len)], *n).unwrap().tokens[0].clone()
        })
        .collect();

    let mut session = exec
        .new_session_with(2, KvPolicy { block_tokens: Some(3), pool_blocks: None })
        .unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(0xB10C);
    let mut next_req = 0usize;
    let mut owner: [Option<usize>; 2] = [None, None];
    let mut done: Vec<Option<Vec<i32>>> = vec![None; reqs.len()];
    let mut cancels = 0usize;
    loop {
        for slot in 0..2 {
            if owner[slot].is_none() && next_req < reqs.len() {
                let (p, n) = reqs[next_req];
                owner[slot] = Some(next_req);
                next_req += 1;
                let out = session
                    .prefill_into_slots(vec![(
                        slot,
                        SlotRequest { prompt: tokenizer::encode(p, prompt_len), max_new: n, stop: None },
                    )])
                    .unwrap();
                for (s, toks) in out.finished {
                    done[owner[s].take().expect("finished slot must be owned")] = Some(toks);
                }
            }
        }
        if session.active() == 0 {
            break;
        }
        match rng.gen_range(4) {
            // Verify-then-rollback round on every active row: feed the
            // pending token plus up to 3 junk tokens (clamped to the
            // row's reservation), truncate every junk entry back out,
            // and commit only the target's own greedy token — exactly
            // one plain decode step's worth of progress.
            0 => {
                for slot in 0..2 {
                    let Some(v) = session.slot_view(slot) else { continue };
                    let j_max = v.max_new.saturating_sub(v.generated + 1).min(3);
                    let j = rng.gen_range(j_max + 1);
                    let mut feed = vec![v.next];
                    for _ in 0..j {
                        feed.push(rng.gen_range(256) as i32);
                    }
                    let scored = session.verify_step(slot, &feed).unwrap();
                    session.truncate_rows(slot, v.pos + 1).unwrap();
                    if let Some(toks) =
                        session.commit_tokens(slot, v.generated, &scored[..1]).unwrap()
                    {
                        done[owner[slot].take().expect("slot must be owned")] = Some(toks);
                    }
                }
            }
            // Rare cancellation: the request just disappears (no parity
            // entry), its blocks must still come back.
            1 if cancels < 2 && rng.gen_bool(0.3) => {
                let slot = rng.gen_range(2);
                if session.slot_view(slot).is_some() {
                    session.cancel_slot(slot).unwrap().expect("active row must cancel");
                    owner[slot] = None;
                    cancels += 1;
                }
            }
            // Plain batched decode step.
            _ => {
                for (s, toks) in session.decode_step().unwrap().finished {
                    done[owner[s].take().expect("finished slot must be owned")] = Some(toks);
                }
            }
        }
    }
    let mut completed = 0usize;
    for (i, d) in done.iter().enumerate() {
        if let Some(toks) = d {
            assert_eq!(toks, &solo[i], "request {i} ({:?}) diverged from its solo run", reqs[i].0);
            completed += 1;
        }
    }
    assert!(completed >= reqs.len() - 2, "only {completed} requests completed");
    assert_eq!(session.kv_blocks_used(), 0);
    assert!(session.kv_pool_fully_free(), "rollback interleaving leaked blocks or reservations");
}

#[test]
fn generation_is_deterministic() {
    let dir = fixture_dir();
    let be = load_backend(BackendKind::Reference, &dir).unwrap();
    let prompt = tokenizer::encode("determinism", be.manifest().model.prompt_len);
    let exec =
        PipelineExecutor::with_backend(be, plan_from_strategy(&[1, 1], &[1, 1]).unwrap()).unwrap();
    let a = exec.generate(&[prompt.clone()], 5).unwrap();
    let b = exec.generate(&[prompt], 5).unwrap();
    assert_eq!(a.tokens, b.tokens);
}
