//! Cross-module integration: scheduler → deployment → simulator, on the
//! paper's cluster presets. These are the structural claims behind
//! Figures 2–7 at reduced scale (full scale runs live in `hexgen figureN`).

use hexgen::cluster;
use hexgen::costmodel::{CostModel, InferenceTask, Phase};
use hexgen::model::ModelSpec;
use hexgen::scheduler::{
    swarm_deployment, GaConfig, GeneticScheduler, MutationMode, PipelinePlanner,
};
use hexgen::simulator::{simulate, SimConfig, SloModel};
use hexgen::workload::{LengthDist, WorkloadSpec};

fn quick_ga(seed: u64) -> GaConfig {
    GaConfig {
        population: 8,
        iterations: 12,
        patience: 8,
        seed,
        fitness_requests: 80,
        fitness_rate: 0.75,
        ..GaConfig::default()
    }
}

fn trace(rate: f64, n: usize, s_out: usize, seed: u64) -> Vec<hexgen::workload::Request> {
    WorkloadSpec { rate, num_requests: n, lengths: LengthDist::LmsysLike { s_out }, seed }
        .generate()
}

#[test]
fn hexgen_full_price_beats_symmetric_ablation() {
    let c = cluster::heterogeneous_full_price();
    let m = ModelSpec::llama2_70b();
    let asym = GeneticScheduler::new(&c, &m, quick_ga(11)).run();
    let mut sym_cfg = quick_ga(11);
    sym_cfg.planner = PipelinePlanner::Symmetric;
    let sym = GeneticScheduler::new(&c, &m, sym_cfg).run();

    assert!(!asym.deployment.pipelines.is_empty());
    assert!(!sym.deployment.pipelines.is_empty());
    // §5.2: asymmetric support should never hurt, usually helps.
    assert!(
        asym.fitness >= sym.fitness - 0.05,
        "asym {} vs sym {}",
        asym.fitness,
        sym.fitness
    );
}

#[test]
fn scheduled_deployment_beats_swarm_baseline_half_price() {
    let c = cluster::heterogeneous_half_price();
    let m = ModelSpec::llama2_70b();
    let cm = CostModel::new(&c, &m);
    let slo = SloModel::new(&m);

    let hex = GeneticScheduler::new(&c, &m, quick_ga(13)).run();
    let petals = swarm_deployment(&c, &m, 13);
    assert!(!petals.pipelines.is_empty());

    let t = trace(1.0, 150, 32, 99);
    let cfg = SimConfig::default();
    let hex_att = simulate(&cm, &hex.deployment, &t, &cfg).attainment(&slo, 5.0);
    let petals_att = simulate(&cm, &petals, &t, &cfg).attainment(&slo, 5.0);
    // Figure 3: HexGen dominates swarm chains.
    assert!(
        hex_att > petals_att,
        "hexgen {hex_att} vs petals {petals_att}"
    );
}

#[test]
fn rescheduling_after_gpu_loss_recovers_most_attainment() {
    // Figure 4: 4 GPUs leave; re-running the search finds a new feasible
    // allocation whose attainment is close to the original.
    let m = ModelSpec::llama2_70b();
    let before = {
        let c = cluster::heterogeneous_half_price();
        GeneticScheduler::new(&c, &m, quick_ga(17)).run()
    };
    let mut c2 = cluster::heterogeneous_half_price();
    c2.take_offline(&[24, 25, 26, 27]); // 4 Nevada A5000s leave
    let after = GeneticScheduler::new(&c2, &m, quick_ga(17)).run();

    assert!(!after.deployment.pipelines.is_empty());
    after.deployment.validate(&c2, &m).unwrap();
    assert!(
        after.fitness >= before.fitness * 0.6,
        "before {} after {}",
        before.fitness,
        after.fitness
    );
}

#[test]
fn guided_search_converges_at_least_as_high_as_random() {
    // Figure 6's claim at reduced scale.
    let c = cluster::heterogeneous_half_price();
    let m = ModelSpec::llama2_70b();
    let guided = GeneticScheduler::new(&c, &m, quick_ga(19)).run();
    let mut rnd_cfg = quick_ga(19);
    rnd_cfg.mutation = MutationMode::Random;
    let random = GeneticScheduler::new(&c, &m, rnd_cfg).run();
    assert!(
        guided.fitness >= random.fitness - 0.02,
        "guided {} vs random {}",
        guided.fitness,
        random.fitness
    );
    // Both improve over (or match) their shared initialization.
    assert!(guided.fitness >= guided.init_fitness - 1e-9);
}

#[test]
fn deployments_respect_memory_constraints() {
    let c = cluster::heterogeneous_full_price();
    let m = ModelSpec::llama2_70b();
    let cm = CostModel::new(&c, &m);
    let res = GeneticScheduler::new(&c, &m, quick_ga(23)).run();
    let task = InferenceTask::new(4, 256, 64);
    // every stage of every pipeline must fit its devices at batch 4
    res.deployment.validate(&c, &m).unwrap();
    for p in &res.deployment.pipelines {
        let stages: Vec<(Vec<usize>, usize)> =
            p.stages.iter().map(|s| (s.devices.clone(), s.layers)).collect();
        assert!(
            cm.pipeline_cost(&stages, &InferenceTask::new(1, 64, 32), Phase::Both)
                .is_some(),
            "pipeline infeasible at b=1"
        );
        // larger batches may legitimately OOM; just ensure evaluation is
        // well-defined (Some or None, no panic)
        let _ = cm.pipeline_cost(&stages, &task, Phase::Both);
    }
}

#[test]
fn full_price_deployment_has_many_replicas() {
    // Appendix F: 58 heterogeneous GPUs host many more replicas than the
    // 16-A100 homogeneous pool (12 vs 4 in the paper).
    let c = cluster::heterogeneous_full_price();
    let m = ModelSpec::llama2_70b();
    let mut cfg = quick_ga(29);
    cfg.iterations = 20;
    let res = GeneticScheduler::new(&c, &m, cfg).run();
    assert!(
        res.deployment.num_replicas() >= 5,
        "expected many replicas, got {}",
        res.deployment.num_replicas()
    );
}
