//! Plan serialization round-trip and lowering, pinned to a golden file:
//! `Deployment` → plan JSON → `Vec<StagePlan>` is the contract that lets
//! `hexgen schedule --emit-plan` feed `hexgen serve --plan`.

use std::path::PathBuf;

use hexgen::cluster;
use hexgen::coordinator::{lower_plan, StagePlan};
use hexgen::model::ModelSpec;
use hexgen::parallelism::{Deployment, DeploymentPlan, Pipeline, PlanStage, ReplicaPlan, Stage};
use hexgen::runtime::Manifest;
use hexgen::util::json::Json;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/plan_golden.json")
}

fn fixture_manifest() -> Manifest {
    Manifest::load(
        &PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo/manifest.json"),
    )
    .unwrap()
}

/// The deployment the golden file serializes: a TP=8 replica and an
/// 8-stage PP=8 chain on the homogeneous 16×A100 pool.
fn golden_plan() -> DeploymentPlan {
    DeploymentPlan {
        cluster: "homogeneous-a100".into(),
        model_name: "llama2-70b".into(),
        model_layers: 80,
        fitness: Some(0.875),
        replicas: vec![
            ReplicaPlan {
                stages: vec![PlanStage { tp: 8, layers: 80, devices: (0..8).collect() }],
                cost_estimate: Some(0.5),
            },
            ReplicaPlan {
                stages: (0..8)
                    .map(|i| PlanStage { tp: 1, layers: 10, devices: vec![8 + i] })
                    .collect(),
                cost_estimate: Some(2.0),
            },
        ],
    }
}

#[test]
fn golden_file_parses_to_the_expected_plan() {
    let plan = DeploymentPlan::load(&golden_path()).unwrap();
    assert_eq!(plan, golden_plan());
}

#[test]
fn serialization_matches_the_golden_file() {
    // What this build writes is (JSON-value-)identical to the checked-in
    // golden file — the schema cannot drift silently.
    let text = std::fs::read_to_string(golden_path()).unwrap();
    assert_eq!(golden_plan().to_json(), Json::parse(&text).unwrap());
}

#[test]
fn golden_plan_lowers_onto_the_fixture_manifest() {
    let plan = DeploymentPlan::load(&golden_path()).unwrap();
    let lowered = lower_plan(&plan, &fixture_manifest()).unwrap();
    // replica 0: TP=8 clamps to the largest compiled degree (2); the 80
    // layers rescale onto the fixture's 2.
    assert_eq!(lowered.replicas[0], vec![StagePlan { layer_start: 0, layer_count: 2, tp: 2 }]);
    // replica 1: the 8-stage chain merges down to one stage per fixture
    // layer, keeping TP=1.
    assert_eq!(
        lowered.replicas[1],
        vec![
            StagePlan { layer_start: 0, layer_count: 1, tp: 1 },
            StagePlan { layer_start: 1, layer_count: 1, tp: 1 },
        ]
    );
    // cost estimates 0.5s vs 2.0s → normalized speeds 1.6 / 0.4.
    assert!((lowered.speeds[0] - 1.6).abs() < 1e-12, "{:?}", lowered.speeds);
    assert!((lowered.speeds[1] - 0.4).abs() < 1e-12, "{:?}", lowered.speeds);
    // every clamp is reported
    assert!(lowered.adjustments.iter().any(|a| a.contains("tp 8 -> 2")), "{:?}", lowered.adjustments);
    assert!(lowered.adjustments.iter().any(|a| a.contains("merged 8 stages into 2")));
}

#[test]
fn full_cycle_from_scheduler_deployment() {
    // Deployment → plan → JSON → plan → Deployment is the identity, and
    // the captured Eq. 2 cost estimates are usable routing weights.
    let c = cluster::case_study();
    let m = ModelSpec::llama2_70b();
    let d = Deployment {
        pipelines: vec![Pipeline {
            stages: vec![
                Stage { devices: vec![0, 1, 2, 3], layers: 48 },
                Stage { devices: vec![4, 5], layers: 20 },
                Stage { devices: vec![6, 7], layers: 12 },
            ],
        }],
    };
    let plan = DeploymentPlan::from_deployment(&d, &c, &m, Some(0.75));
    let text = plan.to_json().to_pretty();
    let back = DeploymentPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, plan);
    assert_eq!(back.deployment(), d);
    assert!(back.replicas[0].cost_estimate.unwrap() > 0.0);
    // and it still lowers onto the fixture
    let lowered = lower_plan(&back, &fixture_manifest()).unwrap();
    assert_eq!(lowered.replicas.len(), 1);
    assert_eq!(lowered.replicas[0].iter().map(|s| s.layer_count).sum::<usize>(), 2);
}

#[test]
fn rejects_layer_sums_not_matching_the_model() {
    let text = std::fs::read_to_string(golden_path()).unwrap();
    // corrupt one stage's layer count: 80 → 79 total
    let bad = text.replacen("\"layers\": 10,", "\"layers\": 9,", 1);
    assert_ne!(bad, text, "corruption failed to apply");
    let err = DeploymentPlan::from_json(&Json::parse(&bad).unwrap()).unwrap_err().to_string();
    assert!(err.contains("layer sum"), "{err}");
}

#[test]
fn rejects_tampered_structure() {
    let plan = golden_plan();

    let mut dup = plan.clone();
    dup.replicas[1].stages[0].devices = vec![0]; // device 0 already bound
    assert!(DeploymentPlan::from_json(&dup.to_json()).is_err());

    let mut bad_tp = plan.clone();
    bad_tp.replicas[0].stages[0].tp = 4; // 4 != 8 bound devices
    assert!(DeploymentPlan::from_json(&bad_tp.to_json()).is_err());

    let mut future = plan.to_json();
    future.set("version", Json::from(99u64));
    assert!(DeploymentPlan::from_json(&future).is_err());
}
