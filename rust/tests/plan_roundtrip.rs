//! Plan serialization round-trip and lowering, pinned to a golden file:
//! `Deployment` → plan JSON → `Vec<StagePlan>` is the contract that lets
//! `hexgen schedule --emit-plan` feed `hexgen serve --plan`.

use std::path::PathBuf;

use hexgen::cluster;
use hexgen::coordinator::{lower_plan, StagePlan};
use hexgen::model::ModelSpec;
use hexgen::parallelism::{
    Deployment, DeploymentPlan, PhaseRole, Pipeline, PlanStage, ReplicaPlan, Stage,
};
use hexgen::runtime::Manifest;
use hexgen::util::json::Json;

/// The v1-schema golden: pins the migration path (pre-disaggregation
/// plans must keep loading, as all-hybrid).
fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/plan_golden.json")
}

/// The v2-schema golden: pins what this build writes (phase roles,
/// per-phase costs, KV budgets).
fn golden_v2_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/plan_golden_v2.json")
}

fn fixture_manifest() -> Manifest {
    Manifest::load(
        &PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo/manifest.json"),
    )
    .unwrap()
}

/// The deployment the v1 golden file serializes: a TP=8 replica and an
/// 8-stage PP=8 chain on the homogeneous 16×A100 pool. Phase fields
/// take their defaults — a v1 document cannot carry them.
fn golden_plan() -> DeploymentPlan {
    DeploymentPlan {
        cluster: "homogeneous-a100".into(),
        model_name: "llama2-70b".into(),
        model_layers: 80,
        fitness: Some(0.875),
        replicas: vec![
            ReplicaPlan {
                stages: vec![PlanStage { tp: 8, layers: 80, devices: (0..8).collect() }],
                cost_estimate: Some(0.5),
                ..Default::default()
            },
            ReplicaPlan {
                stages: (0..8)
                    .map(|i| PlanStage { tp: 1, layers: 10, devices: vec![8 + i] })
                    .collect(),
                cost_estimate: Some(2.0),
                ..Default::default()
            },
        ],
    }
}

/// The same pool serialized in the v2 schema: a prefill-only TP=8
/// replica handing KV segments to a decode-only PP=8 chain, with
/// per-phase Eq. 2 costs and a KV block budget.
fn golden_plan_v2() -> DeploymentPlan {
    DeploymentPlan {
        cluster: "homogeneous-a100".into(),
        model_name: "llama2-70b".into(),
        model_layers: 80,
        fitness: Some(0.875),
        replicas: vec![
            ReplicaPlan {
                stages: vec![PlanStage { tp: 8, layers: 80, devices: (0..8).collect() }],
                cost_estimate: Some(0.5),
                phase_role: PhaseRole::Prefill,
                prefill_cost: Some(0.1),
                decode_cost: Some(0.4),
                kv_block_budget: Some(256),
            },
            ReplicaPlan {
                stages: (0..8)
                    .map(|i| PlanStage { tp: 1, layers: 10, devices: vec![8 + i] })
                    .collect(),
                cost_estimate: Some(2.0),
                phase_role: PhaseRole::Decode,
                prefill_cost: Some(0.4),
                decode_cost: Some(0.8),
                kv_block_budget: None,
            },
        ],
    }
}

#[test]
fn v1_golden_file_migrates_to_all_hybrid() {
    // The pre-disaggregation golden keeps loading: every replica comes
    // back hybrid with per-phase costs unset.
    let plan = DeploymentPlan::load(&golden_path()).unwrap();
    assert_eq!(plan, golden_plan());
    for r in &plan.replicas {
        assert_eq!(r.phase_role, PhaseRole::Hybrid);
        assert_eq!(r.prefill_cost, None);
        assert_eq!(r.decode_cost, None);
        assert_eq!(r.kv_block_budget, None);
    }
}

#[test]
fn v2_golden_file_parses_to_the_expected_plan() {
    let plan = DeploymentPlan::load(&golden_v2_path()).unwrap();
    assert_eq!(plan, golden_plan_v2());
}

#[test]
fn serialization_matches_the_v2_golden_file() {
    // What this build writes is (JSON-value-)identical to the checked-in
    // v2 golden file — the schema cannot drift silently.
    let text = std::fs::read_to_string(golden_v2_path()).unwrap();
    assert_eq!(golden_plan_v2().to_json(), Json::parse(&text).unwrap());
}

#[test]
fn golden_plan_lowers_onto_the_fixture_manifest() {
    let plan = DeploymentPlan::load(&golden_path()).unwrap();
    let lowered = lower_plan(&plan, &fixture_manifest()).unwrap();
    // replica 0: TP=8 clamps to the largest compiled degree (2); the 80
    // layers rescale onto the fixture's 2.
    assert_eq!(lowered.replicas[0], vec![StagePlan { layer_start: 0, layer_count: 2, tp: 2 }]);
    // replica 1: the 8-stage chain merges down to one stage per fixture
    // layer, keeping TP=1.
    assert_eq!(
        lowered.replicas[1],
        vec![
            StagePlan { layer_start: 0, layer_count: 1, tp: 1 },
            StagePlan { layer_start: 1, layer_count: 1, tp: 1 },
        ]
    );
    // cost estimates 0.5s vs 2.0s → normalized speeds 1.6 / 0.4.
    assert!((lowered.speeds[0] - 1.6).abs() < 1e-12, "{:?}", lowered.speeds);
    assert!((lowered.speeds[1] - 0.4).abs() < 1e-12, "{:?}", lowered.speeds);
    // a v1 plan lowers as all-hybrid, with both phases priced from the
    // fused estimate
    assert_eq!(lowered.roles, vec![PhaseRole::Hybrid, PhaseRole::Hybrid]);
    assert_eq!(lowered.prefill_speeds, lowered.speeds);
    // every clamp is reported
    assert!(lowered.adjustments.iter().any(|a| a.contains("tp 8 -> 2")), "{:?}", lowered.adjustments);
    assert!(lowered.adjustments.iter().any(|a| a.contains("merged 8 stages into 2")));
}

#[test]
fn v2_golden_lowers_with_roles_and_per_phase_speeds() {
    let plan = DeploymentPlan::load(&golden_v2_path()).unwrap();
    let lowered = lower_plan(&plan, &fixture_manifest()).unwrap();
    assert_eq!(lowered.roles, vec![PhaseRole::Prefill, PhaseRole::Decode]);
    // decode costs 0.4s vs 0.8s → 1/cost [2.5, 1.25], mean 1.875 → [4/3, 2/3]
    assert!((lowered.speeds[0] - 4.0 / 3.0).abs() < 1e-12, "{:?}", lowered.speeds);
    assert!((lowered.speeds[1] - 2.0 / 3.0).abs() < 1e-12, "{:?}", lowered.speeds);
    // prefill costs 0.1s vs 0.4s → 1/cost [10, 2.5], mean 6.25 → [1.6, 0.4]
    assert!((lowered.prefill_speeds[0] - 1.6).abs() < 1e-12, "{:?}", lowered.prefill_speeds);
    assert!((lowered.prefill_speeds[1] - 0.4).abs() < 1e-12, "{:?}", lowered.prefill_speeds);
}

#[test]
fn full_cycle_from_scheduler_deployment() {
    // Deployment → plan → JSON → plan → Deployment is the identity, and
    // the captured Eq. 2 cost estimates are usable routing weights.
    let c = cluster::case_study();
    let m = ModelSpec::llama2_70b();
    let d = Deployment {
        pipelines: vec![Pipeline {
            stages: vec![
                Stage { devices: vec![0, 1, 2, 3], layers: 48 },
                Stage { devices: vec![4, 5], layers: 20 },
                Stage { devices: vec![6, 7], layers: 12 },
            ],
        }],
    };
    let plan = DeploymentPlan::from_deployment(&d, &c, &m, Some(0.75));
    let text = plan.to_json().to_pretty();
    let back = DeploymentPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, plan);
    assert_eq!(back.deployment(), d);
    assert!(back.replicas[0].cost_estimate.unwrap() > 0.0);
    // and it still lowers onto the fixture
    let lowered = lower_plan(&back, &fixture_manifest()).unwrap();
    assert_eq!(lowered.replicas.len(), 1);
    assert_eq!(lowered.replicas[0].iter().map(|s| s.layer_count).sum::<usize>(), 2);
}

#[test]
fn rejects_layer_sums_not_matching_the_model() {
    let text = std::fs::read_to_string(golden_path()).unwrap();
    // corrupt one stage's layer count: 80 → 79 total
    let bad = text.replacen("\"layers\": 10,", "\"layers\": 9,", 1);
    assert_ne!(bad, text, "corruption failed to apply");
    let err = DeploymentPlan::from_json(&Json::parse(&bad).unwrap()).unwrap_err().to_string();
    assert!(err.contains("layer sum"), "{err}");
}

#[test]
fn rejects_tampered_structure() {
    let plan = golden_plan();

    let mut dup = plan.clone();
    dup.replicas[1].stages[0].devices = vec![0]; // device 0 already bound
    assert!(DeploymentPlan::from_json(&dup.to_json()).is_err());

    let mut bad_tp = plan.clone();
    bad_tp.replicas[0].stages[0].tp = 4; // 4 != 8 bound devices
    assert!(DeploymentPlan::from_json(&bad_tp.to_json()).is_err());

    let mut future = plan.to_json();
    future.set("version", Json::from(99u64));
    assert!(DeploymentPlan::from_json(&future).is_err());
}
