//! Foundation substrates: PRNG, JSON, statistics, CLI parsing, logging and
//! a mini property-test harness. These exist because the offline build has
//! no `rand`/`serde`/`clap`/`proptest`; everything above this module is
//! paper logic.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;

/// Format seconds human-readably (`1.234s`, `12.3ms`, `456us`).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.1}us", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0123), "12.30ms");
        assert_eq!(fmt_duration(45e-6), "45.0us");
        assert_eq!(fmt_duration(120e-9), "120ns");
    }

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(512.0), "512.00B");
        assert_eq!(fmt_bytes(2048.0), "2.00KiB");
        assert_eq!(fmt_bytes(140e9), "130.39GiB");
    }
}
