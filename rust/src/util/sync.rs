//! Rank-ordered synchronization primitives for the serving path.
//!
//! HexGen's premise is serving over unreliable nodes, so worker panics
//! are steady-state events, not edge cases. [`OrderedMutex`] wraps
//! `std::sync::Mutex` with the two policies the serving path needs and
//! the raw type cannot enforce:
//!
//! * **Poison recovery.** A thread that panics while holding a std
//!   mutex poisons it; every later `.lock().unwrap()` then panics too,
//!   cascading one worker failure into unrelated handler threads (the
//!   `/healthz` outage mode). [`OrderedMutex::lock`] never fails: a
//!   poisoned acquisition logs a warning and recovers the inner value.
//!   The state guarded on this path — routing EWMAs, comm-stat
//!   accumulators — is internally consistent after every write, so
//!   recovery is always sound here.
//! * **Deadlock prevention by lock ranking.** Every mutex carries a
//!   static rank from the project lock-order table ([`locks`]). A
//!   thread may only acquire a lock whose rank is **strictly greater**
//!   than every rank it already holds; debug builds maintain a
//!   per-thread held-rank stack and panic on violation (including
//!   re-entrant acquisition — a guaranteed self-deadlock). Release
//!   builds compile the bookkeeping out; the ordering is validated by
//!   the debug test suite and, lexically, by `cargo xtask lint`'s
//!   `lock-order` rule.
//!
//! [`OrderedCondvar`] is the matching condition variable: it parks on
//! an [`OrderedMutexGuard`] and applies the same poison-recovery policy
//! on wake.

use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// The project lock-order table. Locks must be acquired in strictly
/// ascending rank order; a gap of 10 between entries leaves room to
/// slot future locks between existing ones.
///
/// | rank | lock                   | held while …                          |
/// |------|------------------------|---------------------------------------|
/// | 10   | `Router::speeds`       | leaf: nothing else is acquired        |
/// | 20   | `HexGenService::comm_rx`    | folding stats into `comm_total`  |
/// | 30   | `HexGenService::comm_total` | leaf (acquired under `comm_rx`)  |
///
/// Keep this table in sync with `xtask/src/rules.rs` (`LOCK_RANKS`),
/// which enforces the same order lexically.
pub mod locks {
    /// Router per-replica speed state (EWMAs + seeds).
    pub const ROUTER_SPEEDS: u16 = 10;
    /// Service-side receiver of worker comm-stat messages.
    pub const COMM_RX: u16 = 20;
    /// Accumulated comm totals; only ever taken under [`COMM_RX`].
    pub const COMM_TOTAL: u16 = 30;
}

#[cfg(debug_assertions)]
mod held {
    use std::cell::RefCell;

    thread_local! {
        /// Ranks (and names, for diagnostics) of the locks this thread
        /// currently holds, in acquisition order.
        static HELD: RefCell<Vec<(u16, &'static str)>> = RefCell::new(Vec::new());
    }

    pub fn acquire(rank: u16, name: &'static str) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(&(top_rank, top_name)) = held.iter().max_by_key(|&&(r, _)| r) {
                assert!(
                    rank > top_rank,
                    "lock order violation: acquiring {name} (rank {rank}) while holding \
                     {top_name} (rank {top_rank}); see util::sync::locks"
                );
            }
            held.push((rank, name));
        });
    }

    pub fn release(rank: u16, name: &'static str) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(i) = held.iter().rposition(|&(r, n)| r == rank && n == name) {
                held.remove(i);
            }
        });
    }
}

/// A mutex carrying a static rank from the project lock-order table
/// ([`locks`]). See the module docs for the acquisition and poison
/// policies.
#[derive(Debug)]
pub struct OrderedMutex<T> {
    rank: u16,
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// Wrap `value` under the given rank. `name` identifies the lock in
    /// ordering panics and poison-recovery warnings.
    pub const fn new(rank: u16, name: &'static str, value: T) -> OrderedMutex<T> {
        OrderedMutex { rank, name, inner: Mutex::new(value) }
    }

    /// Acquire the lock. Never fails: a poisoned mutex (some thread
    /// panicked while holding it) is recovered with a warning instead
    /// of propagating the poison. Debug builds panic if this
    /// acquisition violates the lock order.
    pub fn lock(&self) -> OrderedMutexGuard<'_, T> {
        #[cfg(debug_assertions)]
        held::acquire(self.rank, self.name);
        let guard = self.inner.lock().unwrap_or_else(|poisoned| {
            crate::log_warn!(
                "recovering poisoned lock {} (a thread panicked while holding it)",
                self.name
            );
            poisoned.into_inner()
        });
        OrderedMutexGuard { guard: Some(guard), rank: self.rank, name: self.name }
    }

    pub fn rank(&self) -> u16 {
        self.rank
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Guard for an [`OrderedMutex`]; pops the lock's rank from the
/// per-thread held stack on drop (debug builds).
pub struct OrderedMutexGuard<'a, T> {
    /// `None` only transiently while parked inside [`OrderedCondvar`];
    /// every guard observable outside this module holds `Some`.
    guard: Option<MutexGuard<'a, T>>,
    rank: u16,
    name: &'static str,
}

impl<T> Deref for OrderedMutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.guard.as_deref().expect("guard present outside condvar wait")
    }
}

impl<T> DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_deref_mut().expect("guard present outside condvar wait")
    }
}

impl<T> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        if self.guard.is_some() {
            held::release(self.rank, self.name);
        }
    }
}

/// Condition variable over [`OrderedMutex`] guards. While a thread is
/// parked its lock's rank stays on the held stack — the thread is
/// blocked and cannot acquire elsewhere, and this keeps the push/pop
/// pairing exact across the release-and-reacquire inside `wait`.
#[derive(Debug, Default)]
pub struct OrderedCondvar {
    inner: Condvar,
}

impl OrderedCondvar {
    pub const fn new() -> OrderedCondvar {
        OrderedCondvar { inner: Condvar::new() }
    }

    /// Atomically release the guard and park until notified (or
    /// spuriously woken); reacquires before returning, recovering
    /// poison like [`OrderedMutex::lock`].
    pub fn wait<'a, T>(&self, mut guard: OrderedMutexGuard<'a, T>) -> OrderedMutexGuard<'a, T> {
        if let Some(inner) = guard.guard.take() {
            let inner = self.inner.wait(inner).unwrap_or_else(PoisonError::into_inner);
            guard.guard = Some(inner);
        }
        guard
    }

    /// Like [`Self::wait`] with an upper bound; the `bool` is true when
    /// the wait timed out rather than being notified.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: OrderedMutexGuard<'a, T>,
        dur: Duration,
    ) -> (OrderedMutexGuard<'a, T>, bool) {
        let mut timed_out = false;
        if let Some(inner) = guard.guard.take() {
            let (inner, result) = match self.inner.wait_timeout(inner, dur) {
                Ok((g, r)) => (g, r),
                Err(poisoned) => poisoned.into_inner(),
            };
            timed_out = result.timed_out();
            guard.guard = Some(inner);
        }
        (guard, timed_out)
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_round_trips_data() {
        let m = OrderedMutex::new(locks::ROUTER_SPEEDS, "test.roundtrip", 1u32);
        assert_eq!(m.rank(), locks::ROUTER_SPEEDS);
        assert_eq!(m.name(), "test.roundtrip");
        *m.lock() += 41;
        assert_eq!(*m.lock(), 42);
    }

    #[test]
    fn poisoned_lock_recovers_with_data_intact() {
        let m = Arc::new(OrderedMutex::new(locks::COMM_TOTAL, "test.poison", 41u32));
        let m2 = m.clone();
        let panicked = std::thread::spawn(move || {
            let mut g = m2.lock();
            *g = 42;
            panic!("deliberate panic while holding the lock");
        })
        .join();
        assert!(panicked.is_err(), "the helper thread must have panicked");
        // The raw mutex is now poisoned; lock() must recover it with the
        // last written value intact, and stay usable afterwards.
        assert_eq!(*m.lock(), 42);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 43);
    }

    #[test]
    fn ascending_acquisition_is_allowed() {
        let rx = OrderedMutex::new(locks::COMM_RX, "test.asc.lo", 1u32);
        let total = OrderedMutex::new(locks::COMM_TOTAL, "test.asc.hi", 2u32);
        let a = rx.lock();
        let b = total.lock();
        assert_eq!(*a + *b, 3);
    }

    #[test]
    fn out_of_order_release_keeps_bookkeeping_consistent() {
        let lo = OrderedMutex::new(locks::COMM_RX, "test.rel.lo", ());
        let hi = OrderedMutex::new(locks::COMM_TOTAL, "test.rel.hi", ());
        let a = lo.lock();
        let b = hi.lock();
        drop(a); // release the lower rank first
        drop(b);
        // Both fully released: re-acquiring the low rank must not trip
        // over stale held-stack entries.
        let _again = lo.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock order violation")]
    fn rank_inversion_panics_in_debug() {
        let hi = OrderedMutex::new(locks::COMM_TOTAL, "test.inv.hi", ());
        let lo = OrderedMutex::new(locks::COMM_RX, "test.inv.lo", ());
        let _hi = hi.lock();
        let _lo = lo.lock(); // descending rank: deadlock potential
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock order violation")]
    fn reentrant_acquisition_panics_in_debug() {
        let m = OrderedMutex::new(locks::ROUTER_SPEEDS, "test.reentrant", ());
        let _a = m.lock();
        let _b = m.lock(); // same rank on the same thread: self-deadlock
    }

    #[test]
    fn condvar_wakes_waiter() {
        let m = Arc::new(OrderedMutex::new(locks::ROUTER_SPEEDS, "test.cv", false));
        let cv = Arc::new(OrderedCondvar::new());
        let (m2, cv2) = (m.clone(), cv.clone());
        let waiter = std::thread::spawn(move || {
            let mut g = m2.lock();
            while !*g {
                g = cv2.wait(g);
            }
            *g
        });
        std::thread::sleep(Duration::from_millis(10));
        *m.lock() = true;
        cv.notify_one();
        assert!(waiter.join().expect("waiter thread"));
    }

    #[test]
    fn condvar_wait_timeout_reports_timeout() {
        let m = OrderedMutex::new(locks::ROUTER_SPEEDS, "test.cv.timeout", ());
        let cv = OrderedCondvar::new();
        let mut g = m.lock();
        // Spurious wakeups return early with `timed_out == false`; keep
        // waiting until the timeout genuinely fires.
        loop {
            let (guard, timed_out) = cv.wait_timeout(g, Duration::from_millis(5));
            if timed_out {
                break;
            }
            g = guard;
        }
    }
}
