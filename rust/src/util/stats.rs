//! Latency/throughput statistics: percentiles, histograms, online moments.

/// Summary statistics over a set of samples (latencies in seconds, etc.).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary from unsorted samples. Returns `None` when empty.
    pub fn from_samples(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            max: xs[n - 1],
            p50: percentile_sorted(&xs, 0.50),
            p90: percentile_sorted(&xs, 0.90),
            p95: percentile_sorted(&xs, 0.95),
            p99: percentile_sorted(&xs, 0.99),
        })
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of unsorted samples.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&xs, q)
}

/// Fraction of samples `<= bound` — the SLO attainment primitive.
pub fn fraction_within(samples: &[f64], bound: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().filter(|&&x| x <= bound).count() as f64 / samples.len() as f64
}

/// Simple fixed-bucket histogram for report output.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<usize>,
    pub underflow: usize,
    pub overflow: usize,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Histogram {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[idx.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> usize {
        self.buckets.iter().sum::<usize>() + self.underflow + self.overflow
    }

    /// Render as ASCII bars for terminal reports.
    pub fn render(&self, width: usize) -> String {
        let max = self.buckets.iter().copied().max().unwrap_or(1).max(1);
        let n = self.buckets.len();
        let mut out = String::new();
        for (i, &c) in self.buckets.iter().enumerate() {
            let b_lo = self.lo + (self.hi - self.lo) * i as f64 / n as f64;
            let b_hi = self.lo + (self.hi - self.lo) * (i + 1) as f64 / n as f64;
            let bar = "#".repeat(c * width / max);
            out.push_str(&format!("{b_lo:>9.3}-{b_hi:<9.3} |{bar:<width$}| {c}\n"));
        }
        out
    }
}

/// Online mean/variance accumulator (Welford) for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::from_samples(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn fraction_within_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(fraction_within(&xs, 2.5), 0.5);
        assert_eq!(fraction_within(&xs, 0.0), 0.0);
        assert_eq!(fraction_within(&xs, 100.0), 1.0);
        assert_eq!(fraction_within(&[], 1.0), 0.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.9, -1.0, 10.0, 11.0] {
            h.add(x);
        }
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 2);
        assert_eq!(h.buckets[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.total(), 7);
        assert!(h.render(20).lines().count() == 10);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.37).collect();
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.add(x);
        }
        let s = Summary::from_samples(&xs).unwrap();
        assert!((o.mean() - s.mean).abs() < 1e-9);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
        assert_eq!(o.count(), s.count);
    }
}
