//! Minimal leveled logger writing to stderr.
//!
//! Controlled by `HEXGEN_LOG` (`error|warn|info|debug|trace`, default `info`)
//! or programmatically via [`set_level`]. Not a `log`-crate facade: the
//! offline crate set has `log` but no `env_logger`, and this keeps the
//! dependency surface minimal.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static START: Lazy<Instant> = Lazy::new(Instant::now);

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let lvl = std::env::var("HEXGEN_LOG")
            .map(|s| Level::from_str(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True when `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

/// Core log entry point; prefer the macros.
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        let t = START.elapsed().as_secs_f64();
        eprintln!("[{t:>9.3}s {} {module}] {msg}", level.name());
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn level_from_str() {
        assert_eq!(Level::from_str("error"), Level::Error);
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
