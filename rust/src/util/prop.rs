//! Mini property-based testing harness (no `proptest` in the offline set).
//!
//! Usage:
//! ```ignore
//! prop_check(256, 0xC0FFEE, |rng| {
//!     let n = rng.gen_range_in(1, 50);
//!     let plan = random_plan(rng, n);
//!     prop_assert(plan.is_valid(), format!("invalid plan: {plan:?}"))
//! });
//! ```
//!
//! Each case gets a forked RNG; on failure the harness reports the case
//! index and the sub-seed so the exact case can be replayed with
//! [`prop_replay`]. No shrinking — cases are kept small by construction.

use super::rng::Xoshiro256pp;

/// Result of one property case.
pub type PropResult = Result<(), String>;

/// Assert helper returning `PropResult`.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats are within `tol`.
pub fn prop_close(a: f64, b: f64, tol: f64, context: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{context}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of `property`; panic with diagnostics on the
/// first failure.
pub fn prop_check<F>(cases: usize, seed: u64, mut property: F)
where
    F: FnMut(&mut Xoshiro256pp) -> PropResult,
{
    let mut master = Xoshiro256pp::seed_from_u64(seed);
    for case in 0..cases {
        let sub_seed = master.next_u64();
        let mut rng = Xoshiro256pp::seed_from_u64(sub_seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (sub_seed={sub_seed:#x}): {msg}\n\
                 replay with prop_replay({sub_seed:#x}, property)"
            );
        }
    }
}

/// Replay a single failing case by sub-seed.
pub fn prop_replay<F>(sub_seed: u64, mut property: F)
where
    F: FnMut(&mut Xoshiro256pp) -> PropResult,
{
    let mut rng = Xoshiro256pp::seed_from_u64(sub_seed);
    if let Err(msg) = property(&mut rng) {
        panic!("replayed case (sub_seed={sub_seed:#x}) failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(50, 1, |rng| {
            count += 1;
            let x = rng.next_f64();
            prop_assert((0.0..1.0).contains(&x), "f64 out of range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(50, 2, |rng| {
            let x = rng.gen_range(10);
            prop_assert(x < 5, format!("x={x}"))
        });
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0 + 1e-12, 1e-9, "eq").is_ok());
        assert!(prop_close(1.0, 1.1, 1e-9, "neq").is_err());
        // relative tolerance scales with magnitude
        assert!(prop_close(1e12, 1e12 + 1.0, 1e-9, "big").is_ok());
    }
}
