//! Minimal JSON parser and writer.
//!
//! The offline crate set has no `serde`, so HexGen carries its own JSON
//! implementation. It covers the full JSON grammar (objects, arrays,
//! strings with escapes incl. `\uXXXX`, numbers, booleans, null) and is
//! used for cluster/config files, the AOT artifact manifest, and
//! experiment result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error.
#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Access(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Access(msg) => write!(f, "json access error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors -------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Insert into an object (panics if not an object — builder use only).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ----- typed accessors ----------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| JsonError::Access(format!("missing key '{key}'"))),
            _ => Err(JsonError::Access(format!(
                "get('{key}') on non-object"
            ))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(JsonError::Access(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64, JsonError> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(JsonError::Access(format!("expected u64, got {x}")));
        }
        Ok(x as u64)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Access(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Access(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(JsonError::Access(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::Access(format!("expected object, got {self:?}"))),
        }
    }

    /// Convenience: `j.get("a")?.as_f64()?` → `j.f64("a")?`.
    pub fn f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)?.as_f64()
    }
    pub fn usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)?.as_usize()
    }
    pub fn str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)?.as_str()
    }
    pub fn arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)?.as_arr()
    }

    // ----- parsing -------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- writing ---------------------------------------------------------
    //
    // Compact serialization is `Display` (so `to_string()` comes from the
    // blanket `ToString` impl rather than shadowing it).

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        f.write_str(&out)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..(n * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn keyword(&mut self, kw: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{08}'),
                        Some(b'f') => s.push('\u{0C}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.arr("a").unwrap().len(), 3);
        assert_eq!(j.str("c").unwrap(), "x");
        assert_eq!(
            j.arr("a").unwrap()[2].get("b").unwrap(),
            &Json::Null
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" \\ A é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" \\ A é");
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse(r#""héllo 世界""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true,"y":null},"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn access_errors_are_reported() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.get("missing").is_err());
        assert!(j.get("a").unwrap().as_str().is_err());
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("n", Json::from(4usize))
            .set("s", Json::from("x"))
            .set("v", Json::from(vec![1.0, 2.0]));
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(round.usize("n").unwrap(), 4);
        assert_eq!(round.arr("v").unwrap().len(), 2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
        assert_eq!(Json::obj().to_string(), "{}");
    }
}
