//! Tiny command-line argument parser (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Typed accessors with defaults keep the experiment entry points terse.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// Comma-separated list of numbers: `--rates 0.5,1,2`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad number '{x}'"))
                })
                .collect(),
        }
    }

    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{x}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("serve --verbose --rate 2.5 trace.json");
        assert_eq!(a.positional, vec!["serve", "trace.json"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
    }

    #[test]
    fn key_equals_value() {
        let a = parse("--out=result.json --n=5");
        assert_eq!(a.get_str("out", ""), "result.json");
        assert_eq!(a.get_usize("n", 0), 5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--rate 1.0 --dry-run");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_f64("rate", 0.0), 1.0);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_str("mode", "sim"), "sim");
        assert_eq!(a.get_f64_list("rates", &[1.0, 2.0]), vec![1.0, 2.0]);
    }

    #[test]
    fn lists_parse() {
        let a = parse("--rates 0.5,1,2 --lens 32,64");
        assert_eq!(a.get_f64_list("rates", &[]), vec![0.5, 1.0, 2.0]);
        assert_eq!(a.get_usize_list("lens", &[]), vec![32, 64]);
    }

    #[test]
    #[should_panic]
    fn bad_number_panics() {
        let a = parse("--rate abc");
        a.get_f64("rate", 0.0);
    }
}
