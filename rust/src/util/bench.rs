//! Minimal benchmark harness (`cargo bench` targets use this; the offline
//! crate set has no criterion). Criterion-like reporting: warm-up, fixed
//! wall-time budget, mean/p50/min/max per iteration.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10}/iter  (p50 {:>10}, min {:>10}, max {:>10}, n={})",
            self.name,
            fmt(self.mean),
            fmt(self.p50),
            fmt(self.min),
            fmt(self.max),
            self.iters
        );
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

fn fmt(d: Duration) -> String {
    crate::util::fmt_duration(d.as_secs_f64())
}

/// Run `f` repeatedly for ~`budget` (after `warmup` iterations), timing
/// each call. Use `std::hint::black_box` inside `f` for inputs/outputs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        min: samples[0],
        max: samples[samples.len() - 1],
    };
    res.report();
    res
}

/// Group header for bench output.
pub fn group(title: &str) {
    println!("\n── {title} ──");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.min <= r.p50 && r.p50 <= r.max);
        assert!(r.mean >= r.min && r.mean <= r.max);
    }
}
