//! Deterministic pseudo-random number generation and distributions.
//!
//! The offline crate set has no `rand`, so HexGen carries its own PRNG:
//! [`Xoshiro256pp`] (xoshiro256++ by Blackman & Vigna) seeded through
//! SplitMix64, plus the handful of distributions the scheduler, workload
//! generator and simulator need (uniform, exponential, Poisson, normal,
//! log-normal, shuffles and weighted choice).
//!
//! All experiment entry points take explicit seeds so every figure and
//! table in the paper reproduction is bit-deterministic.

/// SplitMix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256pp { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection method (unbiased).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let low = m as u64;
            if low >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: only retry when low < n && low < threshold.
            let threshold = n.wrapping_neg() % n;
            if low >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn gen_range_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.gen_range(hi - lo)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`). Inter-arrival times
    /// of the Poisson request process in §5.1.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.next_f64_open().ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small lambda,
    /// normal approximation above 30 to stay O(1)).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal(lambda, lambda.sqrt());
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn standard_normal(&mut self) -> f64 {
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / std-dev.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.standard_normal()
    }

    /// Log-normal: exp(N(mu, sigma)). Used for prompt-length sampling.
    #[inline]
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_range(xs.len())])
        }
    }

    /// Weighted index choice; weights must be non-negative, not all zero.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all weights zero");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.gen_range_in(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork an independent stream (for per-thread / per-replica RNGs).
    pub fn fork(&mut self) -> Self {
        Xoshiro256pp::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_unbiased_cover() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.gen_range(7)] += 1;
        }
        for c in counts {
            // each bucket should get ~10000; allow +-10%
            assert!((9000..11000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let lambda = 4.0;
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(lambda)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Xoshiro256pp::seed_from_u64(13);
        for lambda in [0.5, 3.0, 50.0] {
            let n = 50_000;
            let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Xoshiro256pp::seed_from_u64(23);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::seed_from_u64(29);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 8);
        assert!(t.iter().all(|&i| i < 20));
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Xoshiro256pp::seed_from_u64(31);
        let mut b = a.fork();
        let mut c = a.fork();
        let bc_same = (0..64).filter(|_| b.next_u64() == c.next_u64()).count();
        assert_eq!(bc_same, 0);
    }
}
