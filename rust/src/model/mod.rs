//! Model specifications: the transformer shapes the cost model (Table 1)
//! and the runtime need.

/// Compute precision of the served model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp16,
    Fp32,
}

impl Precision {
    /// `B_type` in the paper: bytes per element.
    pub fn bytes(self) -> f64 {
        match self {
            Precision::Fp16 => 2.0,
            Precision::Fp32 => 4.0,
        }
    }
}

/// Architecture description of the model to serve.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Total transformer layers `L`.
    pub layers: usize,
    /// Hidden dimension `H`.
    pub hidden: usize,
    /// Attention heads (must divide `hidden`).
    pub heads: usize,
    /// Vocabulary size (runtime only; the cost model ignores the LM head).
    pub vocab: usize,
    pub precision: Precision,
}

impl ModelSpec {
    /// LLAMA-2 (70B) as modeled by the paper: L=80, H=8192, FP16.
    ///
    /// Note: the paper's cost model (§2, Table 1) uses the *simplified*
    /// transformer with 12H² parameters/layer (MHA, 4H MLP); it does not
    /// model Llama's GQA or gated MLP. We reproduce the paper's model.
    pub fn llama2_70b() -> ModelSpec {
        ModelSpec {
            name: "llama2-70b".into(),
            layers: 80,
            hidden: 8192,
            heads: 64,
            vocab: 32000,
            precision: Precision::Fp16,
        }
    }

    /// The small demo model actually AOT-compiled and served on CPU PJRT.
    /// Must match `python/compile/model.py::DemoConfig`.
    pub fn demo() -> ModelSpec {
        ModelSpec {
            name: "demo-6l-128h".into(),
            layers: 6,
            hidden: 128,
            heads: 4,
            vocab: 256,
            precision: Precision::Fp32,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "llama2-70b" => Some(ModelSpec::llama2_70b()),
            "demo" | "demo-6l-128h" => Some(ModelSpec::demo()),
            _ => None,
        }
    }

    /// `B_type` bytes.
    pub fn btype(&self) -> f64 {
        self.precision.bytes()
    }

    /// Parameters per transformer layer: 12·H² (4 attention H×H matrices +
    /// H×4H + 4H×H MLP), per paper Appendix B.
    pub fn params_per_layer(&self) -> f64 {
        12.0 * (self.hidden as f64) * (self.hidden as f64)
    }

    /// Total parameter count (transformer trunk only, as the paper counts).
    pub fn total_params(&self) -> f64 {
        self.params_per_layer() * self.layers as f64
    }

    /// Bytes to store all parameters at serving precision.
    pub fn param_bytes(&self) -> f64 {
        self.total_params() * self.btype()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama70b_shapes() {
        let m = ModelSpec::llama2_70b();
        // 12·8192²·80 ≈ 64.4e9 params — the paper's simplified 70B-class model
        assert!((m.total_params() - 64.4e9).abs() < 1e9);
        // FP16 weights ≈ 129 GB
        assert!((m.param_bytes() - 128.8e9).abs() < 2e9);
        assert_eq!(m.hidden % m.heads, 0);
    }

    #[test]
    fn demo_is_small() {
        let m = ModelSpec::demo();
        assert!(m.param_bytes() < 10e6);
        assert_eq!(m.hidden % m.heads, 0);
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Fp16.bytes(), 2.0);
        assert_eq!(Precision::Fp32.bytes(), 4.0);
    }

    #[test]
    fn by_name_lookup() {
        assert!(ModelSpec::by_name("llama2-70b").is_some());
        assert!(ModelSpec::by_name("demo").is_some());
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }
}
