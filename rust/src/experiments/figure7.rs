//! Figure 7 — §5.4 ablation bars on the half-price cluster: the k-means
//! initial allocation (no evolution) vs random-mutation evolution vs
//! HexGen's guided search.

use anyhow::Result;

use crate::cluster;
use crate::model::ModelSpec;
use crate::scheduler::{GeneticScheduler, MutationMode};
use crate::simulator::SloModel;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{maybe_dump, render_table, run_point, ExpConfig, System};

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let m = ModelSpec::llama2_70b();
    let slo = SloModel::new(&m);
    let s_out = 32;
    let cluster = cluster::heterogeneous_half_price();

    println!("Figure 7 — random init vs random mutation vs HexGen (half-price)\n");

    let mut ga_cfg = cfg.ga(71);
    ga_cfg.s_out = s_out;
    let guided = GeneticScheduler::new(&cluster, &m, ga_cfg.clone()).run();
    let mut rnd_cfg = ga_cfg.clone();
    rnd_cfg.mutation = MutationMode::Random;
    let random = GeneticScheduler::new(&cluster, &m, rnd_cfg).run();

    // "random init" = the k-means initial individual without evolution:
    // its fitness is recorded by the GA as init_fitness; rebuild its
    // deployment by running a 0-iteration search.
    let mut init_cfg = ga_cfg.clone();
    init_cfg.iterations = 0;
    let init = GeneticScheduler::new(&cluster, &m, init_cfg).run();

    // Evaluate under enough load that policy differences show: attainment
    // @scale5 across rising request rates (low rates saturate all three).
    let eval_rates = [1.0, 2.0, 4.0, 8.0];
    let eval = |name: &str, deployment: &crate::parallelism::Deployment| -> Vec<f64> {
        let sys = System {
            name: name.into(),
            cluster: cluster.clone(),
            deployment: deployment.clone(),
            sim: Default::default(),
            ga: None,
        };
        eval_rates
            .iter()
            .map(|&r| {
                run_point(&sys, &m, r, s_out, cfg.requests, cfg.seed ^ 0x7A)
                    .attainment(&slo, 5.0)
            })
            .collect()
    };

    let mut rows = Vec::new();
    let mut data = Json::obj();
    for (name, res) in [
        ("random-init (k-means only)", &init),
        ("random-mutation", &random),
        ("hexgen (guided)", &guided),
    ] {
        let atts = eval(name, &res.deployment);
        let mut row = vec![name.to_string(), format!("{}", res.deployment.num_replicas())];
        row.extend(atts.iter().map(|a| format!("{a:.3}")));
        rows.push(row);
        data.set(name, Json::from(atts));
    }
    println!(
        "{}",
        render_table(
            &["policy", "replicas", "att@rate1", "att@rate2", "att@rate4", "att@rate8"],
            &rows
        )
    );
    println!("paper shape: init ≤ random-mutation ≤ hexgen");
    maybe_dump(&cfg, "figure7", data)?;
    Ok(())
}
