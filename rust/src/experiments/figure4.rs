//! Figure 4 — §5.3 dynamic GPU pools: 4 GPUs leave the half-price
//! cluster; HexGen re-runs the (local) search and serves on the new
//! allocation. The paper reports re-search in <30 s and a small
//! attainment gap; we additionally compare against Petals on the same
//! degraded pool.

use anyhow::Result;

use crate::cluster;
use crate::model::ModelSpec;
use crate::simulator::SloModel;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{
    hexgen_system, maybe_dump, petals_system, render_series, render_table, run_point,
    ExpConfig, SLO_SCALES,
};

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let m = ModelSpec::llama2_70b();
    let slo = SloModel::new(&m);
    let s_out = args.get_usize("s-out", 32);
    let rate = args.get_f64("rate", 1.0);

    println!("Figure 4 — HexGen under GPU churn (4 GPUs offline)\n");

    let before = hexgen_system("hexgen-30gpu", cluster::heterogeneous_half_price(), &m, cfg.ga(41));

    // 4 Nevada A5000s leave; re-run the search on the degraded pool.
    let mut degraded = cluster::heterogeneous_half_price();
    degraded.take_offline(&[24, 25, 26, 27]);
    let t0 = std::time::Instant::now();
    let after = hexgen_system("hexgen-26gpu", degraded.clone(), &m, cfg.ga(41));
    let research_time = t0.elapsed().as_secs_f64();
    let petals = petals_system("petals-26gpu", degraded, &m, cfg.seed ^ 41);

    for s in [&before, &after, &petals] {
        println!(
            "  {:<14} {}",
            s.name,
            super::common::deployment_summary(&s.cluster, &s.deployment)
        );
    }
    println!("\nre-search wall time: {research_time:.1}s (paper: <30s)\n");

    let mut data = Json::obj();
    let mut rows = Vec::new();
    for sys in [&before, &after, &petals] {
        let out = run_point(sys, &m, rate, s_out, cfg.requests, cfg.seed ^ 0xF40);
        let ys: Vec<f64> = SLO_SCALES.iter().map(|&sc| out.attainment(&slo, sc)).collect();
        rows.push(vec![sys.name.clone(), render_series(&SLO_SCALES, &ys)]);
        data.set(&format!("att/{}", sys.name), Json::from(ys.clone()));
    }
    println!("attainment vs SLO scale (rate {rate}, s_out {s_out}):");
    println!("{}", render_table(&["system", "scale:attainment"], &rows));

    let att = |sys: &super::common::System, scale: f64| {
        run_point(sys, &m, rate, s_out, cfg.requests, cfg.seed ^ 0xF41).attainment(&slo, scale)
    };
    let a_before = att(&before, 5.0);
    let a_after = att(&after, 5.0);
    let a_petals = att(&petals, 5.0);
    println!(
        "attainment @scale5: before {a_before:.3}, after churn {a_after:.3} (gap {:.3}), petals {a_petals:.3}",
        a_before - a_after
    );
    println!("paper-shape checks: small gap after churn; degraded HexGen still beats Petals");
    data.set("research-seconds", Json::from(research_time));
    data.set("gap", Json::from(a_before - a_after));
    maybe_dump(&cfg, "figure4", data)?;
    Ok(())
}
