//! Table 3 — Appendix B performance alignment: the analytic cost model's
//! prefill/decode estimates vs benchmarked execution, for TP8 / TP4+PP2 /
//! TP2+PP4 / PP8 on 8×A100 at 256/32 and 512/64.
//!
//! Two blocks:
//! 1. our Eq. 4–6 estimates against the paper's published benchmark
//!    column (their testbed; batch size fitted once, since the paper does
//!    not state it) — the *shape* (which config wins each phase) is the
//!    reproduction target;
//! 2. real wall-clock of the demo model on this host's CPU-PJRT pipeline
//!    across the same plan shapes — evidence the runtime's relative
//!    ordering matches the model's.

use anyhow::Result;

use crate::cluster;
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::model::ModelSpec;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{maybe_dump, render_table, ExpConfig};

/// Paper Table 3 benchmarked values: (config, s_in/s_out, prefill, decode).
const PAPER: [(&str, usize, usize, f64, f64); 8] = [
    ("TP=8", 256, 32, 2.72, 2.43),
    ("TP=4 PP=2", 256, 32, 3.79, 2.25),
    ("TP=2 PP=4", 256, 32, 5.26, 3.29),
    ("PP=8", 256, 32, 8.04, 6.04),
    ("TP=8", 512, 64, 3.04, 4.76),
    ("TP=4 PP=2", 512, 64, 4.16, 4.32),
    ("TP=2 PP=4", 512, 64, 5.57, 6.65),
    ("PP=8", 512, 64, 8.27, 12.4),
];

fn a100_stages(config: &str) -> Vec<(Vec<usize>, usize)> {
    match config {
        "TP=8" => vec![((0..8).collect(), 80)],
        "TP=4 PP=2" => vec![((0..4).collect(), 40), ((4..8).collect(), 40)],
        "TP=2 PP=4" => (0..4).map(|j| ((2 * j..2 * j + 2).collect(), 20)).collect(),
        "PP=8" => (0..8).map(|j| (vec![j], 10)).collect(),
        _ => unreachable!(),
    }
}

fn try_estimate(cm: &CostModel, config: &str, t: &InferenceTask, phase: Phase) -> Option<f64> {
    cm.pipeline_cost(&a100_stages(config), t, phase)
}

fn estimate(cm: &CostModel, config: &str, t: &InferenceTask, phase: Phase) -> f64 {
    try_estimate(cm, config, t, phase).expect("A100 config feasible at fitted batch")
}

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let c = cluster::homogeneous_a100();
    let m = ModelSpec::llama2_70b();
    let cm = CostModel::new(&c, &m);

    println!("Table 3 — cost model vs benchmarked performance\n");

    // Fit the batch size the paper benchmarked with (not stated): pick
    // b minimizing mean relative error against their benchmark column.
    let mut best_b = 1;
    let mut best_err = f64::INFINITY;
    'fit: for b in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let mut err = 0.0;
        for (config, s_in, s_out, pre_bench, dec_bench) in PAPER {
            let t = InferenceTask::new(b, s_in, s_out);
            // skip batch sizes where any paper config would OOM
            let Some(pre) = try_estimate(&cm, config, &t, Phase::Prefill) else {
                continue 'fit;
            };
            let Some(dec) = try_estimate(&cm, config, &t, Phase::Decode) else {
                continue 'fit;
            };
            err += ((pre - pre_bench) / pre_bench).abs() + ((dec - dec_bench) / dec_bench).abs();
        }
        if err < best_err {
            best_err = err;
            best_b = b;
        }
    }
    println!("fitted batch size b={best_b} (paper does not state it)\n");

    let mut rows = Vec::new();
    let mut data = Json::obj();
    let mut shape_ok = 0;
    let mut shape_total = 0;
    for (config, s_in, s_out, pre_bench, dec_bench) in PAPER {
        let t = InferenceTask::new(best_b, s_in, s_out);
        let pre = estimate(&cm, config, &t, Phase::Prefill);
        let dec = estimate(&cm, config, &t, Phase::Decode);
        rows.push(vec![
            format!("{s_in}/{s_out}"),
            config.to_string(),
            format!("{pre_bench:.2}s"),
            format!("{pre:.2}s"),
            format!("{dec_bench:.2}s"),
            format!("{dec:.2}s"),
        ]);
        data.set(&format!("{config}/{s_in}-{s_out}/prefill"), Json::from(pre));
        data.set(&format!("{config}/{s_in}-{s_out}/decode"), Json::from(dec));
        shape_total += 2;
        // shape check: within 2x of the benchmarked value
        if (pre / pre_bench) < 2.0 && (pre_bench / pre) < 2.0 {
            shape_ok += 1;
        }
        if (dec / dec_bench) < 2.0 && (dec_bench / dec) < 2.0 {
            shape_ok += 1;
        }
    }
    println!(
        "{}",
        render_table(
            &["in/out", "parallel config", "prefill (paper bench)", "prefill (our est)",
              "decode (paper bench)", "decode (our est)"],
            &rows
        )
    );
    // Ordering checks the paper's table exhibits.
    let t = InferenceTask::new(best_b, 256, 32);
    let pre_order_ok = estimate(&cm, "TP=8", &t, Phase::Prefill)
        < estimate(&cm, "TP=4 PP=2", &t, Phase::Prefill)
        && estimate(&cm, "TP=4 PP=2", &t, Phase::Prefill)
            < estimate(&cm, "TP=2 PP=4", &t, Phase::Prefill)
        && estimate(&cm, "TP=2 PP=4", &t, Phase::Prefill)
            < estimate(&cm, "PP=8", &t, Phase::Prefill);
    let dec_pp8_worst = estimate(&cm, "PP=8", &t, Phase::Decode)
        > estimate(&cm, "TP=8", &t, Phase::Decode);
    println!("prefill ordering TP8 < TP4PP2 < TP2PP4 < PP8: {pre_order_ok}");
    println!("decode PP8 slowest: {dec_pp8_worst}");
    println!("estimates within 2x of paper's benchmark: {shape_ok}/{shape_total}\n");

    // Block 2: real demo-model wall-clock on this host across plan shapes.
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        println!("demo-model real execution on CPU PJRT (6 layers, H=128):");
        use crate::coordinator::{plan_from_strategy, PipelineExecutor};
        use crate::runtime::tokenizer;
        let prompt = tokenizer::encode("table three alignment probe", 32);
        let mut rows = Vec::new();
        for (name, tps, layers) in [
            ("TP=4", vec![4usize], vec![6usize]),
            ("TP=2 PP=2", vec![2, 2], vec![3, 3]),
            ("TP=2 PP=1+asym", vec![2, 1], vec![4, 2]),
            ("PP=2 (TP=1)", vec![1, 1], vec![3, 3]),
            ("TP=1", vec![1], vec![6]),
        ] {
            let plan = plan_from_strategy(&tps, &layers)?;
            let exec = PipelineExecutor::new(artifacts, plan)?;
            // warm-up compiles
            let _ = exec.generate(&[prompt.clone()], 2)?;
            let res = exec.generate(&[prompt.clone()], 8)?;
            rows.push(vec![
                name.to_string(),
                format!("{:.1}ms", res.prefill_seconds * 1e3),
                // decode_steps counts true decode iterations only (the
                // prefill-produced token is reported separately).
                format!("{:.1}ms", res.decode_seconds * 1e3 / res.decode_steps.max(1) as f64),
                format!("{}", res.comm.allreduce_ops),
            ]);
            data.set(&format!("demo/{name}/prefill"), Json::from(res.prefill_seconds));
            data.set(&format!("demo/{name}/decode"), Json::from(res.decode_seconds));
        }
        println!(
            "{}",
            render_table(&["plan", "prefill", "decode/token", "allreduce ops"], &rows)
        );
        println!("(CPU host: TP shards execute sequentially, so TP>1 adds overhead here;");
        println!(" the GPU speedup of TP comes from parallel shard execution, which the");
        println!(" cost model — not this single-CPU testbed — captures.)");
    } else {
        println!("(artifacts/ not built — skipping demo-model measurement block)");
    }

    maybe_dump(&cfg, "table3", data)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_orderings_hold_in_cost_model() {
        let c = cluster::homogeneous_a100();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        for b in [1usize, 8, 32] {
            let t = InferenceTask::new(b, 256, 32);
            // prefill: more TP is better on NVLink
            let p8 = estimate(&cm, "TP=8", &t, Phase::Prefill);
            let p42 = estimate(&cm, "TP=4 PP=2", &t, Phase::Prefill);
            let p24 = estimate(&cm, "TP=2 PP=4", &t, Phase::Prefill);
            let pp8 = estimate(&cm, "PP=8", &t, Phase::Prefill);
            assert!(p8 < p42 && p42 < p24 && p24 < pp8, "b={b}: {p8} {p42} {p24} {pp8}");
            // decode: PP=8 is the worst (full-model scan per GPU)
            let d8 = estimate(&cm, "TP=8", &t, Phase::Decode);
            let dpp8 = estimate(&cm, "PP=8", &t, Phase::Decode);
            assert!(dpp8 > 2.0 * d8, "b={b}: decode PP8 {dpp8} vs TP8 {d8}");
        }
    }

    #[test]
    fn all_paper_configs_feasible() {
        let c = cluster::homogeneous_a100();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        for (config, s_in, s_out, _, _) in PAPER {
            let t = InferenceTask::new(8, s_in, s_out);
            assert!(
                cm.pipeline_cost(&a100_stages(config), &t, Phase::Both).is_some(),
                "{config} infeasible"
            );
        }
    }
}
