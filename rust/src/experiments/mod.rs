//! Evaluation harnesses: one module per paper figure/table (see
//! rust/README.md for the experiment index). Each regenerates its series /
//! table's rows from scratch — scheduler runs, workload generation and
//! simulation included — and prints paper-shape checks alongside.

pub mod common;
pub mod figure1;
pub mod figure2;
pub mod figure3;
pub mod figure4;
pub mod figure5;
pub mod figure6;
pub mod figure7;
pub mod table3;
pub mod table4;
