//! Figure 3 — §5.3 HexGen (half-price heterogeneous) vs Petals-style
//! swarm parallelism: attainment vs SLO scale and vs rate; headline:
//! up to 3.5× lower deadline, 10× higher sustainable rate.

use anyhow::Result;

use crate::cluster;
use crate::model::ModelSpec;
use crate::simulator::SloModel;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{
    hexgen_system, maybe_dump, peak_rate, petals_system, render_series, render_table,
    run_point, ExpConfig, RATES, SLO_SCALES,
};

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let m = ModelSpec::llama2_70b();
    let slo = SloModel::new(&m);
    let s_outs = args.get_usize_list("s-out", &[32, 64]);
    let rates = args.get_f64_list("rates", &[0.25, 1.0]);

    println!("Figure 3 — HexGen vs Petals (half-price heterogeneous)\n");
    let systems = vec![
        hexgen_system("hexgen-half", cluster::heterogeneous_half_price(), &m, cfg.ga(31)),
        petals_system("petals-swarm", cluster::heterogeneous_half_price(), &m, cfg.seed ^ 31),
    ];
    for s in &systems {
        println!(
            "  {:<14} {}",
            s.name,
            super::common::deployment_summary(&s.cluster, &s.deployment)
        );
    }
    println!();

    let mut data = Json::obj();
    for &s_out in &s_outs {
        println!("== output length {s_out} ==");
        for &rate in &rates {
            let mut rows = Vec::new();
            for sys in &systems {
                let out = run_point(sys, &m, rate, s_out, cfg.requests, cfg.seed ^ 0xF30);
                let ys: Vec<f64> =
                    SLO_SCALES.iter().map(|&sc| out.attainment(&slo, sc)).collect();
                rows.push(vec![sys.name.clone(), render_series(&SLO_SCALES, &ys)]);
                data.set(&format!("att/{}/{s_out}/{rate}", sys.name), Json::from(ys));
            }
            println!("rate {rate} req/s — attainment vs SLO scale:");
            println!("{}", render_table(&["system", "scale:attainment"], &rows));
        }
        let mut rows = Vec::new();
        for sys in &systems {
            let ys: Vec<f64> = RATES
                .iter()
                .map(|&r| {
                    run_point(sys, &m, r, s_out, cfg.requests, cfg.seed ^ 0xF31)
                        .attainment(&slo, 5.0)
                })
                .collect();
            rows.push(vec![sys.name.clone(), render_series(&RATES, &ys)]);
        }
        println!("attainment vs rate (SLO scale 5):");
        println!("{}", render_table(&["system", "rate:attainment"], &rows));
    }

    // Headlines.
    let s_out = 32;
    let hex = &systems[0];
    let pet = &systems[1];
    let d_hex = run_point(hex, &m, 0.5, s_out, cfg.requests, cfg.seed ^ 0xF32)
        .min_scale_for_attainment(&slo, 0.99);
    let d_pet = run_point(pet, &m, 0.5, s_out, cfg.requests, cfg.seed ^ 0xF32)
        .min_scale_for_attainment(&slo, 0.99);
    let p_hex = peak_rate(hex, &m, &slo, 8.0, s_out, cfg.requests, cfg.seed ^ 0xF33, 0.95);
    let p_pet = peak_rate(pet, &m, &slo, 8.0, s_out, cfg.requests, cfg.seed ^ 0xF33, 0.95);
    println!(
        "deadline: hexgen {d_hex:.2} vs petals {d_pet:.2} → {:.1}x lower (paper: ≤3.5x)",
        d_pet / d_hex
    );
    let rate_ratio = if p_pet > 0.0 { p_hex / p_pet } else { f64::INFINITY };
    println!(
        "peak rate: hexgen {p_hex:.2} vs petals {p_pet:.2} req/s → {rate_ratio:.1}x (paper: ~10x)"
    );
    data.set("deadline-ratio", Json::from(d_pet / d_hex));
    data.set("peak-ratio", Json::from(rate_ratio.min(1e6)));
    maybe_dump(&cfg, "figure3", data)?;
    Ok(())
}
