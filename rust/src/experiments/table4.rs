//! Table 4 — Appendix F: the scheduled partition on the full-price
//! heterogeneous cluster, reported per region with Appendix-F strategy
//! notation, plus the replica-count comparison against the homogeneous
//! pool (paper: 12 heterogeneous replicas vs 4 homogeneous).

use std::collections::BTreeSet;

use anyhow::Result;

use crate::cluster;
use crate::model::ModelSpec;
use crate::scheduler::GeneticScheduler;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{maybe_dump, render_table, symmetric_system, ExpConfig};

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let m = ModelSpec::llama2_70b();
    let c = cluster::heterogeneous_full_price();

    println!("Table 4 — scheduled deployment by region (full-price cluster)\n");
    let mut ga_cfg = cfg.ga(0x74);
    // Table 4 is the flagship schedule: give the search a bit more room.
    ga_cfg.iterations = ga_cfg.iterations.max(30);
    let res = GeneticScheduler::new(&c, &m, ga_cfg).run();
    println!(
        "search: {} iterations, {:.1}s, estimated attainment {:.3}\n",
        res.iterations_run, res.wall_time, res.fitness
    );

    let mut rows = Vec::new();
    let mut data = Json::obj();
    for (i, p) in res.deployment.pipelines.iter().enumerate() {
        let regions: BTreeSet<&str> = p
            .devices()
            .iter()
            .map(|&d| c.regions[c.devices[d].region].name.as_str())
            .collect();
        let gpus: Vec<String> = p
            .stages
            .iter()
            .map(|s| format!("{}x{}", s.devices.len(), c.devices[s.devices[0]].gpu.name()))
            .collect();
        let region_s = regions.into_iter().collect::<Vec<_>>().join("+");
        rows.push(vec![
            region_s.clone(),
            gpus.join(" + "),
            p.strategy_string(),
            p.layer_string(),
        ]);
        data.set(
            &format!("replica{i}"),
            Json::from_pairs(vec![
                ("region", Json::from(region_s.as_str())),
                ("strategy", Json::from(p.strategy_string())),
                ("layers", Json::from(p.layer_string())),
            ]),
        );
    }
    println!(
        "{}",
        render_table(&["region", "GPU configuration", "strategy", "layers"], &rows)
    );

    // Replica-count comparison with the homogeneous pool.
    let homog = symmetric_system("homog", cluster::homogeneous_a100(), &m, cfg.ga(0x75));
    println!(
        "replicas: heterogeneous {} (paper: 12) vs homogeneous {} (paper: 4)",
        res.deployment.num_replicas(),
        homog.deployment.num_replicas()
    );
    // Structural observations the paper highlights.
    let cross_region = res.deployment.pipelines.iter().filter(|p| {
        let r0 = c.devices[p.devices()[0]].region;
        p.devices().iter().any(|&d| c.devices[d].region != r0)
    });
    println!(
        "cross-region pipelines: {} (paper: 0 — scheduler avoids cross-region links)",
        cross_region.count()
    );
    let asym = res
        .deployment
        .pipelines
        .iter()
        .filter(|p| {
            let tp0 = p.stages[0].tp_degree();
            p.stages.iter().any(|s| s.tp_degree() != tp0)
        })
        .count();
    println!("replicas using asymmetric TP degrees: {asym}");
    data.set("replicas", Json::from(res.deployment.num_replicas()));
    data.set("homogeneous-replicas", Json::from(homog.deployment.num_replicas()));
    maybe_dump(&cfg, "table4", data)?;
    Ok(())
}
