//! Figure 1 — §3.1 case study: parallel strategies over a heterogeneous
//! pool (4×A6000 + 2×A5000 + 2×A4000 serving LLAMA-2 70B, s_in=128,
//! s_out=64).
//!
//! Reproduces the paper's five candidate layouts (pure TP → OOM, naive
//! PP → OOM, proportional PP=8, TP4+PP2, HexGen's asymmetric [4,2,2])
//! plus the plan our Algorithm-1 DP finds, and reports single-request
//! latency and speedups.

use anyhow::Result;

use crate::cluster;
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::model::ModelSpec;
use crate::parallelism::{Pipeline, Stage};
use crate::scheduler::optimal_pipeline_opt;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{maybe_dump, render_table, ExpConfig};

struct Layout {
    name: &'static str,
    pipeline: Pipeline,
}

fn layouts() -> Vec<Layout> {
    let s = |devices: Vec<usize>, layers: usize| Stage { devices, layers };
    vec![
        Layout {
            // TP across all 8 GPUs (A4000 can't hold 1/8 of the model+cache)
            name: "pure TP (TP=8)",
            pipeline: Pipeline { stages: vec![s((0..8).collect(), 80)] },
        },
        Layout {
            // even PP: 10 layers per GPU (A4000 can't hold 10 layers)
            name: "pure PP (PP=8, even)",
            pipeline: Pipeline {
                stages: (0..8).map(|i| s(vec![i], 10)).collect(),
            },
        },
        Layout {
            // PP=8 with layers proportional to capacity: long pipeline
            name: "PP=8 proportional",
            pipeline: Pipeline {
                stages: vec![
                    s(vec![0], 14),
                    s(vec![1], 14),
                    s(vec![2], 14),
                    s(vec![3], 14),
                    s(vec![4], 7),
                    s(vec![5], 7),
                    s(vec![6], 5),
                    s(vec![7], 5),
                ],
            },
        },
        Layout {
            // TP=4 × PP=2: second stage's TP group spans two machines
            name: "TP=4 PP=2",
            pipeline: Pipeline {
                stages: vec![s(vec![0, 1, 2, 3], 56), s(vec![4, 5, 6, 7], 24)],
            },
        },
        Layout {
            // HexGen's asymmetric plan from the paper
            name: "HexGen [4,2,2] 48/20/12",
            pipeline: Pipeline {
                stages: vec![
                    s(vec![0, 1, 2, 3], 48),
                    s(vec![4, 5], 20),
                    s(vec![6, 7], 12),
                ],
            },
        },
    ]
}

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let c = cluster::case_study();
    let m = ModelSpec::llama2_70b();
    let cm = CostModel::new(&c, &m);
    let t = InferenceTask::case_study();

    println!("Figure 1 — case study: parallelism over heterogeneity");
    println!("cluster: 1x(4xA6000-48G) + 1x(2xA5000-24G) + 1x(2xA4000-16G)");
    println!("request: s_in={} s_out={} b={}\n", t.s_in, t.s_out, t.batch);

    let mut rows = Vec::new();
    let mut results: Vec<(String, Option<f64>)> = Vec::new();
    for layout in layouts() {
        let cost = layout.pipeline.cost(&cm, &t, Phase::Both);
        results.push((layout.name.to_string(), cost));
    }
    // The plan Algorithm 1 finds on the full pool.
    let dp = optimal_pipeline_opt(&cm, &c, &(0..8).collect::<Vec<_>>(), &t, 8, 8, true)
        .expect("case study feasible");
    results.push((
        format!(
            "HexGen DP-found {} {}",
            dp.pipeline.strategy_string(),
            dp.pipeline.layer_string()
        ),
        Some(dp.exact_cost),
    ));

    let hexgen_latency = results
        .iter()
        .find(|(n, _)| n.starts_with("HexGen [4,2,2]"))
        .and_then(|(_, c)| *c)
        .expect("paper layout feasible");

    for (name, cost) in &results {
        match cost {
            None => rows.push(vec![name.clone(), "OOM".into(), "-".into()]),
            Some(c) => rows.push(vec![
                name.clone(),
                format!("{c:.2}s"),
                format!("{:.1}x", c / hexgen_latency),
            ]),
        }
    }
    println!(
        "{}",
        render_table(&["layout", "latency", "vs HexGen [4,2,2]"], &rows)
    );

    // Paper's claims: pure TP and naive PP OOM; asymmetric beats TP4+PP2
    // by ~2x and the proportional PP by ~19x.
    let oom = results.iter().filter(|(_, c)| c.is_none()).count();
    let pp8 = results
        .iter()
        .find(|(n, _)| n.starts_with("PP=8"))
        .and_then(|(_, c)| *c);
    let tp4pp2 = results
        .iter()
        .find(|(n, _)| n.starts_with("TP=4"))
        .and_then(|(_, c)| *c);
    println!("paper-shape checks:");
    println!("  OOM layouts: {oom} (paper: 2 — pure TP and even PP)");
    if let (Some(a), Some(b)) = (tp4pp2, pp8) {
        println!(
            "  speedup vs TP4+PP2: {:.1}x (paper: ~2x);  vs PP=8 proportional: {:.1}x (paper: ~19x)",
            a / hexgen_latency,
            b / hexgen_latency
        );
    }

    let mut data = Json::obj();
    for (name, cost) in &results {
        data.set(
            name,
            cost.map(Json::from).unwrap_or(Json::Str("OOM".into())),
        );
    }
    maybe_dump(&cfg, "figure1", data)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_holds() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let costs: Vec<Option<f64>> =
            layouts().iter().map(|l| l.pipeline.cost(&cm, &t, Phase::Both)).collect();
        // pure TP and even PP OOM
        assert!(costs[0].is_none(), "TP=8 should OOM");
        assert!(costs[1].is_none(), "even PP=8 should OOM");
        // remaining three feasible
        let pp8 = costs[2].unwrap();
        let tp4pp2 = costs[3].unwrap();
        let hexgen = costs[4].unwrap();
        // asymmetric wins, and the orderings match the paper
        assert!(hexgen < tp4pp2 && hexgen < pp8);
        assert!(
            tp4pp2 / hexgen > 1.3,
            "vs TP4PP2 speedup too small: {}",
            tp4pp2 / hexgen
        );
        // The paper measured 19x vs proportional PP=8 on real hardware
        // (their PP had real framework per-stage overheads); the pure
        // alpha-beta model yields a smaller but still decisive gap.
        assert!(
            pp8 / hexgen > 2.0,
            "vs PP8 speedup too small: {}",
            pp8 / hexgen
        );
    }
}
