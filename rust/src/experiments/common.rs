//! Shared infrastructure for the figure/table regeneration harnesses.

use std::collections::BTreeMap;

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::model::ModelSpec;
use crate::parallelism::Deployment;
use crate::scheduler::{GaConfig, GaResult, GeneticScheduler, MutationMode, PipelinePlanner};
use crate::simulator::{simulate, BatchPolicy, RouterPolicy, SimConfig, SimOutcome, SloModel};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workload::{LengthDist, Request, WorkloadSpec};

/// SLO scales swept in the attainment curves (Figure 2/3/5 x-axes).
pub const SLO_SCALES: [f64; 8] = [1.0, 1.5, 2.0, 3.0, 5.0, 8.0, 12.0, 20.0];

/// Request rates swept (paper: 0.125 – 8+ req/s).
pub const RATES: [f64; 7] = [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0];

/// Experiment-wide knobs derived from CLI flags.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    pub seed: u64,
    /// Requests per simulated point.
    pub requests: usize,
    /// GA budget.
    pub ga_population: usize,
    pub ga_iterations: usize,
    pub ga_patience: usize,
    pub ga_fitness_requests: usize,
    /// Where to dump machine-readable results (optional).
    pub out_json: Option<String>,
}

impl ExpConfig {
    pub fn from_args(args: &Args) -> ExpConfig {
        let full = args.flag("full");
        ExpConfig {
            seed: args.get_u64("seed", 0x4E58_6E47),
            requests: args.get_usize("requests", if full { 500 } else { 200 }),
            ga_population: args.get_usize("population", if full { 16 } else { 10 }),
            ga_iterations: args.get_usize("iterations", if full { 60 } else { 25 }),
            ga_patience: args.get_usize("patience", if full { 15 } else { 10 }),
            ga_fitness_requests: args.get_usize("fitness-requests", if full { 200 } else { 100 }),
            out_json: args.get("out").map(str::to_string),
        }
    }

    pub fn ga(&self, seed_salt: u64) -> GaConfig {
        GaConfig {
            population: self.ga_population,
            iterations: self.ga_iterations,
            patience: self.ga_patience,
            seed: self.seed ^ seed_salt,
            fitness_requests: self.ga_fitness_requests,
            fitness_rate: 2.0,
            ..GaConfig::default()
        }
    }
}

/// A named serving system under comparison (deployment + sim policy).
pub struct System {
    pub name: String,
    pub cluster: Cluster,
    pub deployment: Deployment,
    pub sim: SimConfig,
    pub ga: Option<GaResult>,
}

/// Schedule HexGen (asymmetric) on a cluster.
pub fn hexgen_system(name: &str, cluster: Cluster, model: &ModelSpec, ga_cfg: GaConfig) -> System {
    let res = GeneticScheduler::new(&cluster, model, ga_cfg).run();
    System {
        name: name.to_string(),
        cluster,
        deployment: res.deployment.clone(),
        sim: SimConfig::default(),
        ga: Some(res),
    }
}

/// Schedule the symmetric-only ablation.
pub fn symmetric_system(
    name: &str,
    cluster: Cluster,
    model: &ModelSpec,
    mut ga_cfg: GaConfig,
) -> System {
    ga_cfg.planner = PipelinePlanner::Symmetric;
    let res = GeneticScheduler::new(&cluster, model, ga_cfg).run();
    System {
        name: name.to_string(),
        cluster,
        deployment: res.deployment.clone(),
        sim: SimConfig::default(),
        ga: Some(res),
    }
}

/// The random-mutation strawman (Figure 6/7).
pub fn random_mutation_system(
    name: &str,
    cluster: Cluster,
    model: &ModelSpec,
    mut ga_cfg: GaConfig,
) -> System {
    ga_cfg.mutation = MutationMode::Random;
    let res = GeneticScheduler::new(&cluster, model, ga_cfg).run();
    System {
        name: name.to_string(),
        cluster,
        deployment: res.deployment.clone(),
        sim: SimConfig::default(),
        ga: Some(res),
    }
}

/// The Petals-like swarm baseline: TP=1 chains, no batching beyond 1,
/// token-granular admission (its sessions stream token-by-token).
pub fn petals_system(name: &str, cluster: Cluster, model: &ModelSpec, seed: u64) -> System {
    let deployment = crate::scheduler::swarm_deployment(&cluster, model, seed);
    System {
        name: name.to_string(),
        cluster,
        deployment,
        sim: SimConfig {
            batch: BatchPolicy { max_batch: 1, continuous: true },
            router: RouterPolicy::RoundRobin,
        },
        ga: None,
    }
}

/// HF-TGI-like baseline: symmetric homogeneous plans + continuous
/// batching (Appendix D). The effective concurrent batch is capped at 4:
/// a 70B model's KV cache on 40 GB cards bounds TGI's admission well
/// below its configuration maximum (and an uncapped token-granular model
/// would overstate 2023-era TGI throughput by an order of magnitude).
pub fn tgi_system(name: &str, cluster: Cluster, model: &ModelSpec, ga_cfg: GaConfig) -> System {
    let mut sys = symmetric_system(name, cluster, model, ga_cfg);
    sys.sim = SimConfig {
        batch: BatchPolicy { max_batch: 4, continuous: true },
        router: RouterPolicy::LeastLoaded,
    };
    sys
}

/// Simulate one (system, rate, s_out) point.
pub fn run_point(
    system: &System,
    model: &ModelSpec,
    rate: f64,
    s_out: usize,
    requests: usize,
    seed: u64,
) -> SimOutcome {
    let cm = CostModel::new(&system.cluster, model);
    let trace: Vec<Request> = WorkloadSpec {
        rate,
        num_requests: requests,
        lengths: LengthDist::LmsysLike { s_out },
        seed,
    }
    .generate();
    simulate(&cm, &system.deployment, &trace, &system.sim)
}

/// Peak request rate sustaining `target` attainment at `scale` (binary
/// search over the rate axis) — the paper's "resilience to peak rate".
pub fn peak_rate(
    system: &System,
    model: &ModelSpec,
    slo: &SloModel,
    scale: f64,
    s_out: usize,
    requests: usize,
    seed: u64,
    target: f64,
) -> f64 {
    let ok = |rate: f64| {
        run_point(system, model, rate, s_out, requests, seed).attainment(slo, scale) >= target
    };
    if !ok(0.05) {
        return 0.0;
    }
    let mut lo = 0.05;
    let mut hi = 0.05;
    while ok(hi) && hi < 64.0 {
        lo = hi;
        hi *= 2.0;
    }
    if hi >= 64.0 && ok(hi) {
        return hi;
    }
    for _ in 0..12 {
        let mid = 0.5 * (lo + hi);
        if ok(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

// ----- report formatting ------------------------------------------------

/// Render an aligned text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!("{:<width$}  ", c, width = widths[i]));
        }
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    line(
        &mut out,
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
    );
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// Render an attainment-vs-x curve as a compact series string.
pub fn render_series(xs: &[f64], ys: &[f64]) -> String {
    xs.iter()
        .zip(ys)
        .map(|(x, y)| format!("{x}:{y:.3}"))
        .collect::<Vec<_>>()
        .join("  ")
}

/// Dump results JSON when `--out` was given.
pub fn maybe_dump(cfg: &ExpConfig, name: &str, payload: Json) -> anyhow::Result<()> {
    if let Some(path) = &cfg.out_json {
        let mut root = Json::obj();
        root.set("experiment", Json::from(name));
        root.set("seed", Json::from(cfg.seed));
        root.set("data", payload);
        std::fs::write(path, root.to_pretty())?;
        println!("(wrote {path})");
    }
    Ok(())
}

/// Pretty one-line deployment summary.
pub fn deployment_summary(cluster: &Cluster, d: &Deployment) -> String {
    let strategies: BTreeMap<String, usize> =
        d.pipelines.iter().fold(BTreeMap::new(), |mut m, p| {
            *m.entry(p.strategy_string()).or_insert(0) += 1;
            m
        });
    let s: Vec<String> = strategies
        .into_iter()
        .map(|(k, v)| format!("{v}x{k}"))
        .collect();
    format!(
        "{} replicas on {} GPUs: {}",
        d.num_replicas(),
        d.devices().len(),
        s.join(" ")
    )
    .replace("  ", " ")
    + &format!(" ({})", cluster.name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["sys", "val"],
            &[
                vec!["a".into(), "1.0".into()],
                vec!["longer-name".into(), "2".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("sys"));
        assert!(lines[2].contains('a'));
    }

    #[test]
    fn series_renders() {
        assert_eq!(render_series(&[1.0, 2.0], &[0.5, 1.0]), "1:0.500  2:1.000");
    }

    #[test]
    fn exp_config_defaults() {
        let cfg = ExpConfig::from_args(&Args::default());
        assert_eq!(cfg.requests, 200);
        let full = ExpConfig::from_args(&Args::parse(
            ["--full".to_string()].into_iter(),
        ));
        assert_eq!(full.requests, 500);
    }
}
