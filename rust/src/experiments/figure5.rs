//! Figure 5 — §5.3 HexGen (full-price heterogeneous) vs HuggingFace-TGI
//! (homogeneous datacenter, continuous batching): near-parity, with
//! HexGen up to 1.25× lower latency deadlines.

use anyhow::Result;

use crate::cluster;
use crate::model::ModelSpec;
use crate::simulator::SloModel;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{
    hexgen_system, maybe_dump, peak_rate, render_series, render_table, run_point,
    tgi_system, ExpConfig, RATES, SLO_SCALES,
};

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let m = ModelSpec::llama2_70b();
    let slo = SloModel::new(&m);
    let s_outs = args.get_usize_list("s-out", &[32, 64]);
    let rates = args.get_f64_list("rates", &[1.0, 4.0]);

    println!("Figure 5 — HexGen vs HuggingFace-TGI\n");
    let systems = vec![
        hexgen_system("hexgen-full", cluster::heterogeneous_full_price(), &m, cfg.ga(51)),
        tgi_system("hf-tgi-homogeneous", cluster::homogeneous_a100(), &m, cfg.ga(52)),
    ];
    for s in &systems {
        println!(
            "  {:<20} {}",
            s.name,
            super::common::deployment_summary(&s.cluster, &s.deployment)
        );
    }
    println!();

    let mut data = Json::obj();
    for &s_out in &s_outs {
        println!("== output length {s_out} ==");
        for &rate in &rates {
            let mut rows = Vec::new();
            for sys in &systems {
                let out = run_point(sys, &m, rate, s_out, cfg.requests, cfg.seed ^ 0xF50);
                let ys: Vec<f64> =
                    SLO_SCALES.iter().map(|&sc| out.attainment(&slo, sc)).collect();
                rows.push(vec![sys.name.clone(), render_series(&SLO_SCALES, &ys)]);
                data.set(&format!("att/{}/{s_out}/{rate}", sys.name), Json::from(ys));
            }
            println!("rate {rate} req/s — attainment vs SLO scale:");
            println!("{}", render_table(&["system", "scale:attainment"], &rows));
        }
        let mut rows = Vec::new();
        for sys in &systems {
            let ys: Vec<f64> = RATES
                .iter()
                .map(|&r| {
                    run_point(sys, &m, r, s_out, cfg.requests, cfg.seed ^ 0xF51)
                        .attainment(&slo, 5.0)
                })
                .collect();
            rows.push(vec![sys.name.clone(), render_series(&RATES, &ys)]);
        }
        println!("attainment vs rate (SLO scale 5):");
        println!("{}", render_table(&["system", "rate:attainment"], &rows));
    }

    let s_out = 32;
    let d_hex = run_point(&systems[0], &m, 1.0, s_out, cfg.requests, cfg.seed ^ 0xF52)
        .min_scale_for_attainment(&slo, 0.99);
    let d_tgi = run_point(&systems[1], &m, 1.0, s_out, cfg.requests, cfg.seed ^ 0xF52)
        .min_scale_for_attainment(&slo, 0.99);
    let p_hex = peak_rate(&systems[0], &m, &slo, 5.0, s_out, cfg.requests, cfg.seed ^ 0xF53, 0.99);
    let p_tgi = peak_rate(&systems[1], &m, &slo, 5.0, s_out, cfg.requests, cfg.seed ^ 0xF53, 0.99);
    println!(
        "deadline: hexgen {d_hex:.2} vs tgi {d_tgi:.2} → {:.2}x (paper: ≤1.25x lower for HexGen)",
        d_tgi / d_hex
    );
    println!("peak rate: hexgen {p_hex:.2} vs tgi {p_tgi:.2} req/s (paper: same level)");
    data.set("deadline-ratio", Json::from(d_tgi / d_hex));
    data.set("peak-hex", Json::from(p_hex));
    data.set("peak-tgi", Json::from(p_tgi));
    maybe_dump(&cfg, "figure5", data)?;
    Ok(())
}
