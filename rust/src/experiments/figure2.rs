//! Figure 2 — §5.2 cost-performance trade-off: SLO attainment of
//! HexGen-full, HexGen w/o asymmetric parallelism, HexGen-half, and the
//! homogeneous FlashAttention baseline, across output lengths 32/64/128,
//! SLO scales, and request rates. Also prints the headline metrics:
//! minimum latency deadline for 99% attainment and peak sustainable rate.

use anyhow::Result;

use crate::cluster;
use crate::model::ModelSpec;
use crate::simulator::SloModel;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{
    hexgen_system, maybe_dump, peak_rate, render_series, render_table, run_point,
    symmetric_system, ExpConfig, System, RATES, SLO_SCALES,
};

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let m = ModelSpec::llama2_70b();
    let slo = SloModel::new(&m);
    let s_outs = args.get_usize_list("s-out", &[32, 64, 128]);
    let rates = args.get_f64_list("rates", &[0.5, 1.0, 2.0, 4.0]);

    println!("Figure 2 — cost-performance trade-off (SLO attainment)\n");
    println!("scheduling the four systems (GA budget: pop={} iters={})...",
             cfg.ga_population, cfg.ga_iterations);

    let systems: Vec<System> = vec![
        hexgen_system("hexgen-full", cluster::heterogeneous_full_price(), &m, cfg.ga(1)),
        symmetric_system("hexgen-full-w/o-asym", cluster::heterogeneous_full_price(), &m, cfg.ga(2)),
        hexgen_system("hexgen-half", cluster::heterogeneous_half_price(), &m, cfg.ga(3)),
        symmetric_system("flash-attn-homogeneous", cluster::homogeneous_a100(), &m, cfg.ga(4)),
    ];
    for s in &systems {
        println!(
            "  {:<24} {}",
            s.name,
            super::common::deployment_summary(&s.cluster, &s.deployment)
        );
        if let Some(ga) = &s.ga {
            println!(
                "  {:<24} search: {} iters, {:.1}s, est. attainment {:.2}",
                "", ga.iterations_run, ga.wall_time, ga.fitness
            );
        }
    }
    println!();

    let mut data = Json::obj();
    for &s_out in &s_outs {
        println!("== output length {s_out} ==");
        // attainment vs SLO scale, one row per (system, rate)
        for &rate in &rates {
            let mut rows = Vec::new();
            for sys in &systems {
                let out = run_point(sys, &m, rate, s_out, cfg.requests, cfg.seed ^ 0xF2);
                let ys: Vec<f64> =
                    SLO_SCALES.iter().map(|&sc| out.attainment(&slo, sc)).collect();
                rows.push(vec![sys.name.clone(), render_series(&SLO_SCALES, &ys)]);
                data.set(
                    &format!("att/{}/{s_out}/{rate}", sys.name),
                    Json::from(ys),
                );
            }
            println!("rate {rate} req/s — attainment vs SLO scale:");
            println!("{}", render_table(&["system", "scale:attainment"], &rows));
        }

        // attainment vs rate at a fixed scale (last column of the figure)
        let fixed_scale = 5.0;
        let mut rows = Vec::new();
        for sys in &systems {
            let ys: Vec<f64> = RATES
                .iter()
                .map(|&r| {
                    run_point(sys, &m, r, s_out, cfg.requests, cfg.seed ^ 0xF3)
                        .attainment(&slo, fixed_scale)
                })
                .collect();
            rows.push(vec![sys.name.clone(), render_series(&RATES, &ys)]);
            data.set(&format!("att-vs-rate/{}/{s_out}", sys.name), Json::from(ys));
        }
        println!("attainment vs rate (SLO scale {fixed_scale}):");
        println!("{}", render_table(&["system", "rate:attainment"], &rows));
    }

    // Headline metrics at s_out=32, the paper's summary claims.
    println!("== headline metrics (s_out=32, 99% attainment) ==");
    let s_out = 32;
    let mut rows = Vec::new();
    let mut deadline_flash = 0.0;
    let mut peak_flash = 0.0;
    let mut deadline_hex = 0.0;
    let mut peak_hex = 0.0;
    for sys in &systems {
        let out = run_point(sys, &m, 1.0, s_out, cfg.requests, cfg.seed ^ 0xF4);
        let deadline = out.min_scale_for_attainment(&slo, 0.99);
        let peak = peak_rate(sys, &m, &slo, 5.0, s_out, cfg.requests, cfg.seed ^ 0xF5, 0.99);
        rows.push(vec![
            sys.name.clone(),
            format!("{deadline:.2}"),
            format!("{peak:.2}"),
        ]);
        data.set(&format!("deadline/{}", sys.name), Json::from(deadline));
        data.set(&format!("peak-rate/{}", sys.name), Json::from(peak));
        if sys.name == "flash-attn-homogeneous" {
            deadline_flash = deadline;
            peak_flash = peak;
        }
        if sys.name == "hexgen-full" {
            deadline_hex = deadline;
            peak_hex = peak;
        }
    }
    println!(
        "{}",
        render_table(
            &["system", "min deadline @99% (SLO scale)", "peak rate @scale5 (req/s)"],
            &rows
        )
    );
    if deadline_hex > 0.0 && peak_flash > 0.0 {
        println!(
            "hexgen-full vs homogeneous: {:.2}x lower deadline (paper: up to 2.3x), {:.2}x peak rate (paper: up to 4x)",
            deadline_flash / deadline_hex,
            peak_hex / peak_flash
        );
    }
    maybe_dump(&cfg, "figure2", data)?;
    Ok(())
}
