//! Figure 6 — §5.4 scheduler convergence: the proposed merge/split/swap
//! mutation policy vs random mutation on the full- and half-price
//! clusters (s_out=32, SLO scale 5). Also verifies the §5.4 claim that
//! estimated attainment aligns with "actual" attainment (an independent
//! evaluation trace).

use anyhow::Result;

use crate::cluster;
use crate::model::ModelSpec;
use crate::scheduler::{GeneticScheduler, MutationMode};
use crate::simulator::SloModel;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::common::{maybe_dump, render_table, run_point, ExpConfig, System};

pub fn run(args: &Args) -> Result<()> {
    let cfg = ExpConfig::from_args(args);
    let m = ModelSpec::llama2_70b();
    let slo = SloModel::new(&m);
    let s_out = 32;

    println!("Figure 6 — search convergence: guided vs random mutation\n");
    let mut data = Json::obj();
    for (cname, cluster) in [
        ("full-price", cluster::heterogeneous_full_price()),
        ("half-price", cluster::heterogeneous_half_price()),
    ] {
        println!("== {cname} ==");
        let mut ga_cfg = cfg.ga(61);
        ga_cfg.s_out = s_out;
        ga_cfg.slo_scale = 5.0;
        let guided = GeneticScheduler::new(&cluster, &m, ga_cfg.clone()).run();
        let mut rnd_cfg = ga_cfg.clone();
        rnd_cfg.mutation = MutationMode::Random;
        let random = GeneticScheduler::new(&cluster, &m, rnd_cfg).run();

        // Convergence histories.
        let mut rows = Vec::new();
        let fmt_hist = |r: &crate::scheduler::GaResult| {
            r.history
                .iter()
                .map(|h| format!("{}:{:.2}", h.iteration, h.best_fitness))
                .collect::<Vec<_>>()
                .join(" ")
        };
        rows.push(vec!["guided".into(), fmt_hist(&guided)]);
        rows.push(vec!["random".into(), fmt_hist(&random)]);
        println!("{}", render_table(&["policy", "iteration:best-fitness"], &rows));
        println!(
            "wall time to best: guided {:.1}s ({} iters), random {:.1}s ({} iters) (paper: 2.1/1.5 min)",
            guided.wall_time, guided.iterations_run, random.wall_time, random.iterations_run
        );
        println!(
            "final estimated attainment: guided {:.3} vs random {:.3} (paper: ~26% gap)",
            guided.fitness, random.fitness
        );

        // Estimated vs actual attainment of the guided deployment: same
        // workload parameters (rate, s_out, scale), the "estimate" on the
        // GA's fitness seed, the "actual" on an independent seed.
        let sys = System {
            name: "guided".into(),
            cluster: cluster.clone(),
            deployment: guided.deployment.clone(),
            sim: Default::default(),
            ga: None,
        };
        let estimated =
            run_point(&sys, &m, ga_cfg.fitness_rate, s_out, cfg.requests, ga_cfg.seed ^ 0x57_AC_E0)
                .attainment(&slo, 5.0);
        let actual =
            run_point(&sys, &m, ga_cfg.fitness_rate, s_out, cfg.requests, cfg.seed ^ 0x6A)
                .attainment(&slo, 5.0);
        println!(
            "estimated {estimated:.3} vs actual {actual:.3} attainment (paper: 92/94 and 82/86)\n"
        );
        data.set(&format!("{cname}/guided-fitness"), Json::from(guided.fitness));
        data.set(&format!("{cname}/random-fitness"), Json::from(random.fitness));
        data.set(&format!("{cname}/guided-wall"), Json::from(guided.wall_time));
        data.set(&format!("{cname}/actual"), Json::from(actual));
        let hist: Vec<Json> = guided
            .history
            .iter()
            .map(|h| {
                Json::from_pairs(vec![
                    ("iter", Json::from(h.iteration)),
                    ("t", Json::from(h.wall_time)),
                    ("best", Json::from(h.best_fitness)),
                ])
            })
            .collect();
        data.set(&format!("{cname}/guided-history"), Json::Arr(hist));
    }
    maybe_dump(&cfg, "figure6", data)?;
    Ok(())
}
