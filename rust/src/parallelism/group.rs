//! Type-count vector representation of GPU sets (the paper's τ, §4.2).
//!
//! A [`TypeVec`] abstracts a set of GPUs as counts per GPU type; the DP of
//! Algorithm 1 and the GA mutations (§4.3) operate on these vectors, and a
//! separate *binding* step maps them back to concrete devices.

use crate::cluster::{Cluster, DeviceId, GpuType};

pub const NUM_TYPES: usize = 6; // |GpuType::ALL|

/// Counts per GPU type; index = `GpuType::index()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default, PartialOrd, Ord)]
pub struct TypeVec(pub [usize; NUM_TYPES]);

impl TypeVec {
    pub fn zero() -> TypeVec {
        TypeVec::default()
    }

    /// τ_k · e_k — a homogeneous set of `count` GPUs of type `k`.
    pub fn single(gpu: GpuType, count: usize) -> TypeVec {
        let mut v = TypeVec::zero();
        v.0[gpu.index()] = count;
        v
    }

    /// Build from a concrete device set.
    pub fn from_devices(cluster: &Cluster, devices: &[DeviceId]) -> TypeVec {
        let mut v = TypeVec::zero();
        for &d in devices {
            v.0[cluster.devices[d].gpu.index()] += 1;
        }
        v
    }

    pub fn total(&self) -> usize {
        self.0.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    pub fn get(&self, gpu: GpuType) -> usize {
        self.0[gpu.index()]
    }

    /// Component-wise `self + other`.
    pub fn plus(&self, other: &TypeVec) -> TypeVec {
        let mut v = *self;
        for k in 0..NUM_TYPES {
            v.0[k] += other.0[k];
        }
        v
    }

    /// Component-wise `self - other`; `None` if any component would go
    /// negative.
    pub fn minus(&self, other: &TypeVec) -> Option<TypeVec> {
        let mut v = *self;
        for k in 0..NUM_TYPES {
            if v.0[k] < other.0[k] {
                return None;
            }
            v.0[k] -= other.0[k];
        }
        Some(v)
    }

    /// True when `other` fits inside `self` component-wise.
    pub fn contains(&self, other: &TypeVec) -> bool {
        (0..NUM_TYPES).all(|k| self.0[k] >= other.0[k])
    }

    /// Even split: (⌊τ/2⌋, ⌈τ/2⌉) per type — the GA *split* mutation.
    pub fn split_even(&self) -> (TypeVec, TypeVec) {
        let mut a = TypeVec::zero();
        let mut b = TypeVec::zero();
        for k in 0..NUM_TYPES {
            a.0[k] = self.0[k] / 2;
            b.0[k] = self.0[k] - a.0[k];
        }
        (a, b)
    }

    /// GPU types with non-zero counts.
    pub fn present_types(&self) -> Vec<GpuType> {
        GpuType::ALL
            .into_iter()
            .filter(|t| self.0[t.index()] > 0)
            .collect()
    }

    /// Total device memory of this set (for the GA's hold-a-replica
    /// early check).
    pub fn total_memory(&self) -> f64 {
        GpuType::ALL
            .into_iter()
            .map(|t| self.0[t.index()] as f64 * t.spec().memory_bytes)
            .sum()
    }

    /// Dense ranked index into a mixed-radix table with per-type capacity
    /// `caps` (each dimension sized `caps[k]+1`). The DP memo key.
    pub fn rank(&self, caps: &[usize; NUM_TYPES]) -> usize {
        let mut idx = 0;
        for k in 0..NUM_TYPES {
            debug_assert!(self.0[k] <= caps[k]);
            idx = idx * (caps[k] + 1) + self.0[k];
        }
        idx
    }

    /// Number of rank slots for capacity vector `caps`.
    pub fn rank_space(caps: &[usize; NUM_TYPES]) -> usize {
        caps.iter().map(|c| c + 1).product()
    }
}

impl std::fmt::Display for TypeVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = GpuType::ALL
            .into_iter()
            .filter(|t| self.0[t.index()] > 0)
            .map(|t| format!("{}x{}", self.0[t.index()], t.name()))
            .collect();
        write!(f, "{{{}}}", parts.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    #[test]
    fn arithmetic() {
        let a = TypeVec::single(GpuType::A6000, 4);
        let b = TypeVec::single(GpuType::A5000, 2);
        let s = a.plus(&b);
        assert_eq!(s.total(), 6);
        assert_eq!(s.get(GpuType::A6000), 4);
        assert_eq!(s.minus(&a), Some(b));
        assert_eq!(b.minus(&a), None);
        assert!(s.contains(&a));
        assert!(!a.contains(&s));
    }

    #[test]
    fn split_even_conserves() {
        let v = TypeVec::single(GpuType::RTX3090TI, 5)
            .plus(&TypeVec::single(GpuType::A40, 4));
        let (a, b) = v.split_even();
        assert_eq!(a.plus(&b), v);
        assert_eq!(a.get(GpuType::RTX3090TI), 2);
        assert_eq!(b.get(GpuType::RTX3090TI), 3);
        assert_eq!(a.get(GpuType::A40), 2);
    }

    #[test]
    fn from_devices_counts() {
        let c = cluster::case_study();
        let v = TypeVec::from_devices(&c, &[0, 1, 4, 6, 7]);
        assert_eq!(v.get(GpuType::A6000), 2);
        assert_eq!(v.get(GpuType::A5000), 1);
        assert_eq!(v.get(GpuType::A4000), 2);
        assert_eq!(v.total(), 5);
    }

    #[test]
    fn rank_is_bijective_in_space() {
        let caps = [2, 1, 0, 3, 0, 0];
        let mut seen = vec![false; TypeVec::rank_space(&caps)];
        for a in 0..=2 {
            for b in 0..=1 {
                for d in 0..=3 {
                    let mut v = TypeVec::zero();
                    v.0[0] = a;
                    v.0[1] = b;
                    v.0[3] = d;
                    let r = v.rank(&caps);
                    assert!(!seen[r], "collision at {v:?}");
                    seen[r] = true;
                }
            }
        }
        assert_eq!(seen.iter().filter(|&&x| x).count(), 3 * 2 * 4);
    }

    #[test]
    fn memory_total() {
        let v = TypeVec::single(GpuType::A4000, 2);
        assert!((v.total_memory() - 32e9).abs() < 1.0);
    }

    #[test]
    fn display_compact() {
        let v = TypeVec::single(GpuType::A6000, 4).plus(&TypeVec::single(GpuType::A4000, 2));
        assert_eq!(format!("{v}"), "{4xA6000,2xA4000}");
    }
}
