//! Deployment-plan serialization: the bridge from `hexgen schedule` to
//! `hexgen serve`.
//!
//! The §4 scheduler's output is a [`Deployment`] — per-replica stage TP
//! degrees, layer counts and device bindings. A [`DeploymentPlan`] is
//! that assignment σ written down (`util::json`-based, schema v1) so a
//! separate serving process can pick it up: `hexgen schedule --emit-plan
//! plan.json` writes one, `hexgen serve --plan plan.json` lowers it onto
//! the artifact manifest (see [`crate::coordinator::lowering`]) and
//! boots the live service from it. Each replica additionally carries its
//! Eq. 2 end-to-end latency estimate for a reference task, which seeds
//! the live router's per-replica speed weights.
//!
//! Schema (all keys required unless noted):
//!
//! ```json
//! {
//!   "version": 1,
//!   "cluster": "heterogeneous-full-price",
//!   "model": {"name": "llama2-70b", "layers": 80},
//!   "fitness": 0.93,                       // optional: scheduler fitness
//!   "replicas": [
//!     {
//!       "cost_estimate": 1.25,             // optional: Eq. 2 seconds
//!       "stages": [
//!         {"tp": 4, "layers": 48, "devices": [0, 1, 2, 3]},
//!         {"tp": 2, "layers": 32, "devices": [4, 5]}
//!       ]
//!     }
//!   ]
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::model::ModelSpec;
use crate::util::json::Json;

use super::{Deployment, Pipeline, Stage};

/// Plan schema version this build reads and writes.
pub const PLAN_VERSION: u64 = 1;

/// Reference task for the per-replica Eq. 2 cost estimates — the same
/// single-request task the simulator uses for its routing estimates.
pub fn plan_reference_task() -> InferenceTask {
    InferenceTask::new(1, 64, 64)
}

/// One pipeline stage of a serialized plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanStage {
    /// Tensor-parallel degree (`d_ij`; equals `devices.len()`).
    pub tp: usize,
    /// Transformer layers held by this stage (`l_ij`).
    pub layers: usize,
    /// Device bindings into the scheduled cluster.
    pub devices: Vec<DeviceId>,
}

/// One model replica (an independent pipeline) of a serialized plan.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaPlan {
    pub stages: Vec<PlanStage>,
    /// Eq. 2 end-to-end latency estimate (seconds) of
    /// [`plan_reference_task`] on this replica; `None` when the cost
    /// model flags the replica memory-infeasible.
    pub cost_estimate: Option<f64>,
}

impl ReplicaPlan {
    /// Appendix-F strategy notation, e.g. `[4,2,2]`.
    pub fn strategy_string(&self) -> String {
        let v: Vec<String> = self.stages.iter().map(|s| s.tp.to_string()).collect();
        format!("[{}]", v.join(","))
    }

    /// Layer counts per stage, e.g. `48/20/12`.
    pub fn layer_string(&self) -> String {
        let v: Vec<String> = self.stages.iter().map(|s| s.layers.to_string()).collect();
        v.join("/")
    }

    pub fn total_layers(&self) -> usize {
        self.stages.iter().map(|s| s.layers).sum()
    }
}

/// A serialized scheduler assignment σ (schema above).
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// Name of the cluster the plan was scheduled for.
    pub cluster: String,
    /// Name of the model the plan partitions.
    pub model_name: String,
    /// Total transformer layers the stage layer counts must sum to.
    pub model_layers: usize,
    /// Scheduler fitness (estimated SLO attainment), when known.
    pub fitness: Option<f64>,
    pub replicas: Vec<ReplicaPlan>,
}

impl DeploymentPlan {
    /// Capture a scheduler [`Deployment`] with per-replica Eq. 2 cost
    /// estimates evaluated against `cluster` + `model`.
    pub fn from_deployment(
        deployment: &Deployment,
        cluster: &Cluster,
        model: &ModelSpec,
        fitness: Option<f64>,
    ) -> DeploymentPlan {
        let cm = CostModel::new(cluster, model);
        let task = plan_reference_task();
        let replicas = deployment
            .pipelines
            .iter()
            .map(|p| ReplicaPlan {
                stages: p
                    .stages
                    .iter()
                    .map(|s| PlanStage {
                        tp: s.tp_degree(),
                        layers: s.layers,
                        devices: s.devices.clone(),
                    })
                    .collect(),
                cost_estimate: p.cost(&cm, &task, Phase::Both),
            })
            .collect();
        DeploymentPlan {
            cluster: cluster.name.clone(),
            model_name: model.name.clone(),
            model_layers: model.layers,
            fitness,
            replicas,
        }
    }

    /// Reconstruct the [`Deployment`] this plan serializes.
    pub fn deployment(&self) -> Deployment {
        Deployment {
            pipelines: self
                .replicas
                .iter()
                .map(|r| Pipeline {
                    stages: r
                        .stages
                        .iter()
                        .map(|s| Stage { devices: s.devices.clone(), layers: s.layers })
                        .collect(),
                })
                .collect(),
        }
    }

    /// Structural validation: non-empty replicas/stages, consistent TP
    /// degrees vs device bindings, plan-wide device disjointness, and
    /// per-replica layer sums equal to the plan's model layer count.
    pub fn validate(&self) -> Result<()> {
        if self.replicas.is_empty() {
            bail!("plan has no replicas");
        }
        if self.model_layers == 0 {
            bail!("plan model has zero layers");
        }
        let mut seen = std::collections::BTreeSet::new();
        for (i, r) in self.replicas.iter().enumerate() {
            if r.stages.is_empty() {
                bail!("replica {i} has no stages");
            }
            if r.total_layers() != self.model_layers {
                bail!(
                    "replica {i}: layer sum {} != model layers {}",
                    r.total_layers(),
                    self.model_layers
                );
            }
            if let Some(c) = r.cost_estimate {
                if !c.is_finite() || c <= 0.0 {
                    bail!("replica {i}: cost estimate {c} is not a positive finite number");
                }
            }
            for (j, s) in r.stages.iter().enumerate() {
                if s.layers == 0 {
                    bail!("replica {i} stage {j} has zero layers");
                }
                if s.tp == 0 || s.tp != s.devices.len() {
                    bail!(
                        "replica {i} stage {j}: tp {} != {} bound devices",
                        s.tp,
                        s.devices.len()
                    );
                }
                for &d in &s.devices {
                    if !seen.insert(d) {
                        bail!("device {d} bound twice in the plan");
                    }
                }
            }
        }
        Ok(())
    }

    // ----- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("version", Json::from(PLAN_VERSION));
        root.set("cluster", Json::from(self.cluster.as_str()));
        let mut model = Json::obj();
        model.set("name", Json::from(self.model_name.as_str()));
        model.set("layers", Json::from(self.model_layers));
        root.set("model", model);
        if let Some(f) = self.fitness {
            root.set("fitness", Json::from(f));
        }
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                let mut rep = Json::obj();
                if let Some(c) = r.cost_estimate {
                    rep.set("cost_estimate", Json::from(c));
                }
                let stages: Vec<Json> = r
                    .stages
                    .iter()
                    .map(|s| {
                        let mut st = Json::obj();
                        st.set("tp", Json::from(s.tp));
                        st.set("layers", Json::from(s.layers));
                        st.set("devices", Json::from(s.devices.clone()));
                        st
                    })
                    .collect();
                rep.set("stages", Json::Arr(stages));
                rep
            })
            .collect();
        root.set("replicas", Json::Arr(replicas));
        root
    }

    /// Parse and validate a plan from its JSON form.
    pub fn from_json(j: &Json) -> Result<DeploymentPlan> {
        let version = j.get("version")?.as_u64()?;
        if version != PLAN_VERSION {
            bail!("unsupported plan version {version} (this build reads v{PLAN_VERSION})");
        }
        let model = j.get("model")?;
        let mut replicas = Vec::new();
        for (i, rep) in j.arr("replicas")?.iter().enumerate() {
            let cost_estimate = match rep.opt("cost_estimate") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().with_context(|| format!("replica {i} cost_estimate"))?),
            };
            let mut stages = Vec::new();
            for (s_idx, st) in rep.arr("stages")?.iter().enumerate() {
                let devices: Vec<DeviceId> = st
                    .arr("devices")?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_, _>>()
                    .with_context(|| format!("replica {i} stage {s_idx} devices"))?;
                stages.push(PlanStage {
                    tp: st.usize("tp")?,
                    layers: st.usize("layers")?,
                    devices,
                });
            }
            replicas.push(ReplicaPlan { stages, cost_estimate });
        }
        let plan = DeploymentPlan {
            cluster: j.str("cluster")?.to_string(),
            model_name: model.str("name")?.to_string(),
            model_layers: model.usize("layers")?,
            fitness: match j.opt("fitness") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().context("fitness")?),
            },
            replicas,
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Write the plan (pretty JSON) to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing deployment plan {}", path.display()))
    }

    /// Load and validate a plan from `path`.
    pub fn load(path: &Path) -> Result<DeploymentPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading deployment plan {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing deployment plan {}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    fn case_deployment() -> Deployment {
        // §3.1 winning layout: [4,2,2] with 48/20/12 layers.
        Deployment {
            pipelines: vec![Pipeline {
                stages: vec![
                    Stage { devices: vec![0, 1, 2, 3], layers: 48 },
                    Stage { devices: vec![4, 5], layers: 20 },
                    Stage { devices: vec![6, 7], layers: 12 },
                ],
            }],
        }
    }

    #[test]
    fn capture_records_costs_and_shape() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let d = case_deployment();
        let plan = DeploymentPlan::from_deployment(&d, &c, &m, Some(0.9));
        assert_eq!(plan.cluster, "case-study");
        assert_eq!(plan.model_layers, 80);
        assert_eq!(plan.replicas.len(), 1);
        assert_eq!(plan.replicas[0].strategy_string(), "[4,2,2]");
        assert_eq!(plan.replicas[0].layer_string(), "48/20/12");
        let cost = plan.replicas[0].cost_estimate.expect("feasible replica has a cost");
        assert!(cost.is_finite() && cost > 0.0);
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn json_round_trip_preserves_the_deployment() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let d = case_deployment();
        let plan = DeploymentPlan::from_deployment(&d, &c, &m, Some(0.875));
        let j = plan.to_json();
        let back = DeploymentPlan::from_json(&Json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.deployment(), d);
        assert_eq!(back.fitness, Some(0.875));
    }

    #[test]
    fn infeasible_replica_has_no_cost_estimate() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        // 70 layers on 2×A4000-16G violates memory (cf. parallelism tests).
        let d = Deployment {
            pipelines: vec![Pipeline {
                stages: vec![
                    Stage { devices: vec![0, 1, 2, 3], layers: 10 },
                    Stage { devices: vec![6, 7], layers: 70 },
                ],
            }],
        };
        let plan = DeploymentPlan::from_deployment(&d, &c, &m, None);
        assert_eq!(plan.replicas[0].cost_estimate, None);
    }

    #[test]
    fn validation_rejects_bad_plans() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let good = DeploymentPlan::from_deployment(&case_deployment(), &c, &m, None);

        let mut wrong_sum = good.clone();
        wrong_sum.replicas[0].stages[0].layers = 10;
        let err = wrong_sum.validate().unwrap_err().to_string();
        assert!(err.contains("layer sum"), "{err}");

        let mut dup = good.clone();
        dup.replicas[0].stages[1].devices = vec![0, 5];
        assert!(dup.validate().is_err());

        let mut bad_tp = good.clone();
        bad_tp.replicas[0].stages[0].tp = 3;
        assert!(bad_tp.validate().is_err());

        let empty = DeploymentPlan {
            cluster: "x".into(),
            model_name: "m".into(),
            model_layers: 4,
            fitness: None,
            replicas: vec![],
        };
        assert!(empty.validate().is_err());
    }

    #[test]
    fn from_json_rejects_future_versions() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let mut j = DeploymentPlan::from_deployment(&case_deployment(), &c, &m, None).to_json();
        j.set("version", Json::from(2u64));
        assert!(DeploymentPlan::from_json(&j).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let plan = DeploymentPlan::from_deployment(&case_deployment(), &c, &m, Some(0.5));
        let dir = std::env::temp_dir().join("hexgen_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan.save(&path).unwrap();
        let back = DeploymentPlan::load(&path).unwrap();
        assert_eq!(back, plan);
        let _ = std::fs::remove_file(&path);
    }
}
