//! Deployment-plan serialization: the bridge from `hexgen schedule` to
//! `hexgen serve`.
//!
//! The §4 scheduler's output is a [`Deployment`] — per-replica stage TP
//! degrees, layer counts and device bindings. A [`DeploymentPlan`] is
//! that assignment σ written down (`util::json`-based, schema v2) so a
//! separate serving process can pick it up: `hexgen schedule --emit-plan
//! plan.json` writes one, `hexgen serve --plan plan.json` lowers it onto
//! the artifact manifest (see [`crate::coordinator::lowering`]) and
//! boots the live service from it. Each replica additionally carries its
//! Eq. 2 end-to-end latency estimate for a reference task, which seeds
//! the live router's per-replica speed weights.
//!
//! Schema v2 (all keys required unless noted):
//!
//! ```json
//! {
//!   "version": 2,
//!   "cluster": "heterogeneous-full-price",
//!   "model": {"name": "llama2-70b", "layers": 80},
//!   "fitness": 0.93,                       // optional: scheduler fitness
//!   "replicas": [
//!     {
//!       "phase_role": "hybrid",            // "prefill" | "decode" | "hybrid"
//!       "cost_estimate": 1.25,             // optional: Eq. 2 seconds, both phases
//!       "prefill_cost": 0.31,              // optional: Eq. 2 seconds, prefill only
//!       "decode_cost": 0.94,               // optional: Eq. 2 seconds, decode only
//!       "kv_block_budget": 256,            // optional: KV blocks this replica holds
//!       "stages": [
//!         {"tp": 4, "layers": 48, "devices": [0, 1, 2, 3]},
//!         {"tp": 2, "layers": 32, "devices": [4, 5]}
//!       ]
//!     }
//!   ]
//! }
//! ```
//!
//! **Migration.** v1 plans (no `phase_role` / per-phase costs) still
//! load: every replica migrates to `hybrid` with per-phase costs unset,
//! which lowers and serves exactly as before disaggregation existed.
//! Future versions (> 2) are rejected.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::model::ModelSpec;
use crate::util::json::Json;

use super::{Deployment, Pipeline, Stage};

/// Plan schema version this build writes (it reads v1 and v2).
pub const PLAN_VERSION: u64 = 2;

/// Serving phase(s) a replica participates in (HexGen-2 style
/// disaggregation). `Hybrid` is the pre-v2 behavior: the replica runs
/// prefill and decode fused, with no KV hand-off.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum PhaseRole {
    /// Prefill-only: runs prompt prefill, then ships the KV rows to a
    /// decode-capable partner.
    Prefill,
    /// Decode-only: admits imported KV segments and decodes them; never
    /// receives fresh prompts directly.
    Decode,
    /// Fused prefill + decode (the only pre-v2 mode).
    #[default]
    Hybrid,
}

impl PhaseRole {
    pub fn as_str(&self) -> &'static str {
        match self {
            PhaseRole::Prefill => "prefill",
            PhaseRole::Decode => "decode",
            PhaseRole::Hybrid => "hybrid",
        }
    }

    pub fn parse(s: &str) -> Result<PhaseRole> {
        match s {
            "prefill" => Ok(PhaseRole::Prefill),
            "decode" => Ok(PhaseRole::Decode),
            "hybrid" => Ok(PhaseRole::Hybrid),
            other => bail!("unknown phase_role '{other}' (expected prefill|decode|hybrid)"),
        }
    }

    /// Can this replica run prompt prefill?
    pub fn can_prefill(&self) -> bool {
        matches!(self, PhaseRole::Prefill | PhaseRole::Hybrid)
    }

    /// Can this replica run decode steps?
    pub fn can_decode(&self) -> bool {
        matches!(self, PhaseRole::Decode | PhaseRole::Hybrid)
    }
}

impl std::fmt::Display for PhaseRole {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Reference task for the per-replica Eq. 2 cost estimates — the same
/// single-request task the simulator uses for its routing estimates.
pub fn plan_reference_task() -> InferenceTask {
    InferenceTask::new(1, 64, 64)
}

/// One pipeline stage of a serialized plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanStage {
    /// Tensor-parallel degree (`d_ij`; equals `devices.len()`).
    pub tp: usize,
    /// Transformer layers held by this stage (`l_ij`).
    pub layers: usize,
    /// Device bindings into the scheduled cluster.
    pub devices: Vec<DeviceId>,
}

/// One model replica (an independent pipeline) of a serialized plan.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaPlan {
    pub stages: Vec<PlanStage>,
    /// Eq. 2 end-to-end latency estimate (seconds) of
    /// [`plan_reference_task`] on this replica; `None` when the cost
    /// model flags the replica memory-infeasible.
    pub cost_estimate: Option<f64>,
    /// Serving phase(s) this replica runs (v1 plans migrate to
    /// [`PhaseRole::Hybrid`]).
    pub phase_role: PhaseRole,
    /// Eq. 2 prefill-phase latency estimate (seconds) of
    /// [`plan_reference_task`]; seeds the router's prefill pricing.
    pub prefill_cost: Option<f64>,
    /// Eq. 2 decode-phase latency estimate (seconds) of
    /// [`plan_reference_task`]; seeds the router's decode pricing.
    pub decode_cost: Option<f64>,
    /// KV blocks this replica should provision (`None` → the serving
    /// default: one full sequence per batch slot).
    pub kv_block_budget: Option<usize>,
}

impl ReplicaPlan {
    /// Appendix-F strategy notation, e.g. `[4,2,2]`.
    pub fn strategy_string(&self) -> String {
        let v: Vec<String> = self.stages.iter().map(|s| s.tp.to_string()).collect();
        format!("[{}]", v.join(","))
    }

    /// Layer counts per stage, e.g. `48/20/12`.
    pub fn layer_string(&self) -> String {
        let v: Vec<String> = self.stages.iter().map(|s| s.layers.to_string()).collect();
        v.join("/")
    }

    pub fn total_layers(&self) -> usize {
        self.stages.iter().map(|s| s.layers).sum()
    }
}

/// A serialized scheduler assignment σ (schema above).
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// Name of the cluster the plan was scheduled for.
    pub cluster: String,
    /// Name of the model the plan partitions.
    pub model_name: String,
    /// Total transformer layers the stage layer counts must sum to.
    pub model_layers: usize,
    /// Scheduler fitness (estimated SLO attainment), when known.
    pub fitness: Option<f64>,
    pub replicas: Vec<ReplicaPlan>,
}

impl DeploymentPlan {
    /// Capture a scheduler [`Deployment`] with per-replica Eq. 2 cost
    /// estimates evaluated against `cluster` + `model`.
    pub fn from_deployment(
        deployment: &Deployment,
        cluster: &Cluster,
        model: &ModelSpec,
        fitness: Option<f64>,
    ) -> DeploymentPlan {
        let cm = CostModel::new(cluster, model);
        let task = plan_reference_task();
        let replicas = deployment
            .pipelines
            .iter()
            .map(|p| ReplicaPlan {
                stages: p
                    .stages
                    .iter()
                    .map(|s| PlanStage {
                        tp: s.tp_degree(),
                        layers: s.layers,
                        devices: s.devices.clone(),
                    })
                    .collect(),
                cost_estimate: p.cost(&cm, &task, Phase::Both),
                phase_role: PhaseRole::Hybrid,
                prefill_cost: p.cost(&cm, &task, Phase::Prefill),
                decode_cost: p.cost(&cm, &task, Phase::Decode),
                kv_block_budget: None,
            })
            .collect();
        DeploymentPlan {
            cluster: cluster.name.clone(),
            model_name: model.name.clone(),
            model_layers: model.layers,
            fitness,
            replicas,
        }
    }

    /// Reconstruct the [`Deployment`] this plan serializes.
    pub fn deployment(&self) -> Deployment {
        Deployment {
            pipelines: self
                .replicas
                .iter()
                .map(|r| Pipeline {
                    stages: r
                        .stages
                        .iter()
                        .map(|s| Stage { devices: s.devices.clone(), layers: s.layers })
                        .collect(),
                })
                .collect(),
        }
    }

    /// Structural validation: non-empty replicas/stages, consistent TP
    /// degrees vs device bindings, plan-wide device disjointness, and
    /// per-replica layer sums equal to the plan's model layer count.
    pub fn validate(&self) -> Result<()> {
        if self.replicas.is_empty() {
            bail!("plan has no replicas");
        }
        if self.model_layers == 0 {
            bail!("plan model has zero layers");
        }
        let mut seen = std::collections::BTreeSet::new();
        for (i, r) in self.replicas.iter().enumerate() {
            if r.stages.is_empty() {
                bail!("replica {i} has no stages");
            }
            if r.total_layers() != self.model_layers {
                bail!(
                    "replica {i}: layer sum {} != model layers {}",
                    r.total_layers(),
                    self.model_layers
                );
            }
            for (name, c) in [
                ("cost estimate", r.cost_estimate),
                ("prefill cost", r.prefill_cost),
                ("decode cost", r.decode_cost),
            ] {
                if let Some(c) = c {
                    if !c.is_finite() || c <= 0.0 {
                        bail!("replica {i}: {name} {c} is not a positive finite number");
                    }
                }
            }
            if r.kv_block_budget == Some(0) {
                bail!("replica {i}: kv_block_budget must be >= 1 when set");
            }
            for (j, s) in r.stages.iter().enumerate() {
                if s.layers == 0 {
                    bail!("replica {i} stage {j} has zero layers");
                }
                if s.tp == 0 || s.tp != s.devices.len() {
                    bail!(
                        "replica {i} stage {j}: tp {} != {} bound devices",
                        s.tp,
                        s.devices.len()
                    );
                }
                for &d in &s.devices {
                    if !seen.insert(d) {
                        bail!("device {d} bound twice in the plan");
                    }
                }
            }
        }
        Ok(())
    }

    // ----- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("version", Json::from(PLAN_VERSION));
        root.set("cluster", Json::from(self.cluster.as_str()));
        let mut model = Json::obj();
        model.set("name", Json::from(self.model_name.as_str()));
        model.set("layers", Json::from(self.model_layers));
        root.set("model", model);
        if let Some(f) = self.fitness {
            root.set("fitness", Json::from(f));
        }
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                let mut rep = Json::obj();
                // phase_role is always emitted — hybrid explicitly, never
                // implied by omission (satellite: hybrid shown, not omitted).
                rep.set("phase_role", Json::from(r.phase_role.as_str()));
                if let Some(c) = r.cost_estimate {
                    rep.set("cost_estimate", Json::from(c));
                }
                if let Some(c) = r.prefill_cost {
                    rep.set("prefill_cost", Json::from(c));
                }
                if let Some(c) = r.decode_cost {
                    rep.set("decode_cost", Json::from(c));
                }
                if let Some(b) = r.kv_block_budget {
                    rep.set("kv_block_budget", Json::from(b));
                }
                let stages: Vec<Json> = r
                    .stages
                    .iter()
                    .map(|s| {
                        let mut st = Json::obj();
                        st.set("tp", Json::from(s.tp));
                        st.set("layers", Json::from(s.layers));
                        st.set("devices", Json::from(s.devices.clone()));
                        st
                    })
                    .collect();
                rep.set("stages", Json::Arr(stages));
                rep
            })
            .collect();
        root.set("replicas", Json::Arr(replicas));
        root
    }

    /// Parse and validate a plan from its JSON form. Reads the current
    /// v2 schema and migrates v1 plans (every replica becomes `hybrid`
    /// with per-phase costs unset); rejects versions beyond v2.
    pub fn from_json(j: &Json) -> Result<DeploymentPlan> {
        let version = j.get("version")?.as_u64()?;
        if version == 0 || version > PLAN_VERSION {
            bail!("unsupported plan version {version} (this build reads v1..=v{PLAN_VERSION})");
        }
        let opt_f64 = |rep: &Json, key: &str, i: usize| -> Result<Option<f64>> {
            match rep.opt(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => Ok(Some(v.as_f64().with_context(|| format!("replica {i} {key}"))?)),
            }
        };
        let model = j.get("model")?;
        let mut replicas = Vec::new();
        for (i, rep) in j.arr("replicas")?.iter().enumerate() {
            let cost_estimate = opt_f64(rep, "cost_estimate", i)?;
            // v1 → v2 migration: no phase fields existed, every replica
            // served fused — load as hybrid with per-phase costs unset.
            let (phase_role, prefill_cost, decode_cost, kv_block_budget) = if version >= 2 {
                let role = match rep.opt("phase_role") {
                    None | Some(Json::Null) => PhaseRole::Hybrid,
                    Some(v) => PhaseRole::parse(
                        v.as_str().with_context(|| format!("replica {i} phase_role"))?,
                    )?,
                };
                let budget = match rep.opt("kv_block_budget") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(
                        v.as_usize().with_context(|| format!("replica {i} kv_block_budget"))?,
                    ),
                };
                (role, opt_f64(rep, "prefill_cost", i)?, opt_f64(rep, "decode_cost", i)?, budget)
            } else {
                (PhaseRole::Hybrid, None, None, None)
            };
            let mut stages = Vec::new();
            for (s_idx, st) in rep.arr("stages")?.iter().enumerate() {
                let devices: Vec<DeviceId> = st
                    .arr("devices")?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_, _>>()
                    .with_context(|| format!("replica {i} stage {s_idx} devices"))?;
                stages.push(PlanStage {
                    tp: st.usize("tp")?,
                    layers: st.usize("layers")?,
                    devices,
                });
            }
            replicas.push(ReplicaPlan {
                stages,
                cost_estimate,
                phase_role,
                prefill_cost,
                decode_cost,
                kv_block_budget,
            });
        }
        let plan = DeploymentPlan {
            cluster: j.str("cluster")?.to_string(),
            model_name: model.str("name")?.to_string(),
            model_layers: model.usize("layers")?,
            fitness: match j.opt("fitness") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().context("fitness")?),
            },
            replicas,
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Write the plan (pretty JSON) to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing deployment plan {}", path.display()))
    }

    /// Load and validate a plan from `path`.
    pub fn load(path: &Path) -> Result<DeploymentPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading deployment plan {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing deployment plan {}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    fn case_deployment() -> Deployment {
        // §3.1 winning layout: [4,2,2] with 48/20/12 layers.
        Deployment {
            pipelines: vec![Pipeline {
                stages: vec![
                    Stage { devices: vec![0, 1, 2, 3], layers: 48 },
                    Stage { devices: vec![4, 5], layers: 20 },
                    Stage { devices: vec![6, 7], layers: 12 },
                ],
            }],
        }
    }

    #[test]
    fn capture_records_costs_and_shape() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let d = case_deployment();
        let plan = DeploymentPlan::from_deployment(&d, &c, &m, Some(0.9));
        assert_eq!(plan.cluster, "case-study");
        assert_eq!(plan.model_layers, 80);
        assert_eq!(plan.replicas.len(), 1);
        assert_eq!(plan.replicas[0].strategy_string(), "[4,2,2]");
        assert_eq!(plan.replicas[0].layer_string(), "48/20/12");
        let cost = plan.replicas[0].cost_estimate.expect("feasible replica has a cost");
        assert!(cost.is_finite() && cost > 0.0);
        // Scheduler output is always hybrid, with both phase costs
        // captured for the router's per-phase pricing.
        assert_eq!(plan.replicas[0].phase_role, PhaseRole::Hybrid);
        let pc = plan.replicas[0].prefill_cost.expect("feasible replica has a prefill cost");
        let dc = plan.replicas[0].decode_cost.expect("feasible replica has a decode cost");
        assert!(pc > 0.0 && dc > 0.0);
        assert!(pc < cost && dc < cost, "each phase costs less than both together");
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn json_round_trip_preserves_the_deployment() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let d = case_deployment();
        let plan = DeploymentPlan::from_deployment(&d, &c, &m, Some(0.875));
        let j = plan.to_json();
        let back = DeploymentPlan::from_json(&Json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.deployment(), d);
        assert_eq!(back.fitness, Some(0.875));
    }

    #[test]
    fn infeasible_replica_has_no_cost_estimate() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        // 70 layers on 2×A4000-16G violates memory (cf. parallelism tests).
        let d = Deployment {
            pipelines: vec![Pipeline {
                stages: vec![
                    Stage { devices: vec![0, 1, 2, 3], layers: 10 },
                    Stage { devices: vec![6, 7], layers: 70 },
                ],
            }],
        };
        let plan = DeploymentPlan::from_deployment(&d, &c, &m, None);
        assert_eq!(plan.replicas[0].cost_estimate, None);
    }

    #[test]
    fn validation_rejects_bad_plans() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let good = DeploymentPlan::from_deployment(&case_deployment(), &c, &m, None);

        let mut wrong_sum = good.clone();
        wrong_sum.replicas[0].stages[0].layers = 10;
        let err = wrong_sum.validate().unwrap_err().to_string();
        assert!(err.contains("layer sum"), "{err}");

        let mut dup = good.clone();
        dup.replicas[0].stages[1].devices = vec![0, 5];
        assert!(dup.validate().is_err());

        let mut bad_tp = good.clone();
        bad_tp.replicas[0].stages[0].tp = 3;
        assert!(bad_tp.validate().is_err());

        let empty = DeploymentPlan {
            cluster: "x".into(),
            model_name: "m".into(),
            model_layers: 4,
            fitness: None,
            replicas: vec![],
        };
        assert!(empty.validate().is_err());
    }

    #[test]
    fn from_json_rejects_future_versions() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let mut j = DeploymentPlan::from_deployment(&case_deployment(), &c, &m, None).to_json();
        j.set("version", Json::from(3u64));
        assert!(DeploymentPlan::from_json(&j).is_err());
    }

    #[test]
    fn v1_documents_migrate_to_all_hybrid() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let mut j = DeploymentPlan::from_deployment(&case_deployment(), &c, &m, None).to_json();
        j.set("version", Json::from(1u64));
        let back = DeploymentPlan::from_json(&j).unwrap();
        // Phase fields are v2-only: a v1 document loads as fused hybrid
        // replicas with per-phase costs unset, even when stray phase
        // keys are present in the document.
        for r in &back.replicas {
            assert_eq!(r.phase_role, PhaseRole::Hybrid);
            assert_eq!(r.prefill_cost, None);
            assert_eq!(r.decode_cost, None);
            assert_eq!(r.kv_block_budget, None);
        }
        assert!(back.replicas[0].cost_estimate.is_some());
    }

    #[test]
    fn save_load_round_trip() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let plan = DeploymentPlan::from_deployment(&case_deployment(), &c, &m, Some(0.5));
        let dir = std::env::temp_dir().join("hexgen_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan.save(&path).unwrap();
        let back = DeploymentPlan::load(&path).unwrap();
        assert_eq!(back, plan);
        let _ = std::fs::remove_file(&path);
    }
}
