//! Asymmetric parallelism plans.
//!
//! The paper's core representational contribution (§3): each pipeline
//! stage may hold a different number of transformer layers *and* a
//! different tensor-model-parallel degree. A [`Deployment`] is the
//! assignment σ of §4.1 — a set of independent pipelines partitioning a
//! subset of the device pool, each serving one replica of the model.

pub mod group;
pub mod plan;

pub use group::TypeVec;
pub use plan::{DeploymentPlan, PhaseRole, PlanStage, ReplicaPlan};

use std::collections::BTreeSet;

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::model::ModelSpec;

/// One pipeline stage: a TP group and its layer count (`d_ij`, `l_ij`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stage {
    pub devices: Vec<DeviceId>,
    pub layers: usize,
}

impl Stage {
    pub fn tp_degree(&self) -> usize {
        self.devices.len()
    }
}

/// One independent inference pipeline (a model replica).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pipeline {
    pub stages: Vec<Stage>,
}

impl Pipeline {
    /// Paper Appendix F notation: `[4,2]` = TP degrees per stage.
    pub fn strategy_string(&self) -> String {
        let degs: Vec<String> = self.stages.iter().map(|s| s.tp_degree().to_string()).collect();
        format!("[{}]", degs.join(","))
    }

    /// Layer counts per stage, e.g. `48/20/12`.
    pub fn layer_string(&self) -> String {
        let ls: Vec<String> = self.stages.iter().map(|s| s.layers.to_string()).collect();
        ls.join("/")
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn total_layers(&self) -> usize {
        self.stages.iter().map(|s| s.layers).sum()
    }

    pub fn devices(&self) -> Vec<DeviceId> {
        self.stages.iter().flat_map(|s| s.devices.iter().copied()).collect()
    }

    /// End-to-end latency of one task on this pipeline (Eq. 2);
    /// `None` on memory violation.
    pub fn cost(&self, cm: &CostModel, t: &InferenceTask, phase: Phase) -> Option<f64> {
        let stages: Vec<(Vec<DeviceId>, usize)> = self
            .stages
            .iter()
            .map(|s| (s.devices.clone(), s.layers))
            .collect();
        cm.pipeline_cost(&stages, t, phase)
    }

    /// Validate against a model: layers sum to `L`, no empty/duplicate
    /// devices, every stage non-empty.
    pub fn validate(&self, model: &ModelSpec) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("pipeline with no stages".into());
        }
        if self.total_layers() != model.layers {
            return Err(format!(
                "layer sum {} != model layers {}",
                self.total_layers(),
                model.layers
            ));
        }
        let mut seen = BTreeSet::new();
        for (i, s) in self.stages.iter().enumerate() {
            if s.devices.is_empty() {
                return Err(format!("stage {i} has no devices"));
            }
            if s.layers == 0 {
                return Err(format!("stage {i} has zero layers"));
            }
            for &d in &s.devices {
                if !seen.insert(d) {
                    return Err(format!("device {d} appears twice"));
                }
            }
        }
        Ok(())
    }
}

/// A full assignment σ: independent pipelines over disjoint device sets.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Deployment {
    pub pipelines: Vec<Pipeline>,
}

impl Deployment {
    pub fn num_replicas(&self) -> usize {
        self.pipelines.len()
    }

    pub fn devices(&self) -> Vec<DeviceId> {
        self.pipelines.iter().flat_map(|p| p.devices()).collect()
    }

    /// Validate: each pipeline valid, pipelines pairwise disjoint, all
    /// devices exist and are online.
    pub fn validate(&self, cluster: &Cluster, model: &ModelSpec) -> Result<(), String> {
        if self.pipelines.is_empty() {
            return Err("deployment with no pipelines".into());
        }
        let mut seen = BTreeSet::new();
        for (i, p) in self.pipelines.iter().enumerate() {
            p.validate(model).map_err(|e| format!("pipeline {i}: {e}"))?;
            for d in p.devices() {
                if d >= cluster.devices.len() {
                    return Err(format!("pipeline {i}: unknown device {d}"));
                }
                if !cluster.devices[d].online {
                    return Err(format!("pipeline {i}: device {d} offline"));
                }
                if !seen.insert(d) {
                    return Err(format!("device {d} used by two pipelines"));
                }
            }
        }
        Ok(())
    }

    /// Validate + check memory feasibility of every stage.
    pub fn validate_memory(
        &self,
        cm: &CostModel,
        t: &InferenceTask,
    ) -> Result<(), String> {
        for (i, p) in self.pipelines.iter().enumerate() {
            for (j, s) in p.stages.iter().enumerate() {
                if !cm.mem_ok(&s.devices, s.layers, t) {
                    return Err(format!(
                        "pipeline {i} stage {j} ({} layers on {} GPUs) violates memory",
                        s.layers,
                        s.devices.len()
                    ));
                }
            }
        }
        Ok(())
    }

    /// Human-readable summary (Table 4 style).
    pub fn describe(&self, cluster: &Cluster) -> String {
        let mut out = String::new();
        for (i, p) in self.pipelines.iter().enumerate() {
            let regions: BTreeSet<&str> = p
                .devices()
                .iter()
                .map(|&d| cluster.regions[cluster.devices[d].region].name.as_str())
                .collect();
            let gpus: Vec<String> = p
                .stages
                .iter()
                .map(|s| {
                    format!(
                        "{}x{}",
                        s.devices.len(),
                        cluster.devices[s.devices[0]].gpu.name()
                    )
                })
                .collect();
            out.push_str(&format!(
                "replica {i:>2}: {} layers {} gpus [{}] regions {:?}\n",
                p.strategy_string(),
                p.layer_string(),
                gpus.join(", "),
                regions
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    fn case_plan() -> Pipeline {
        // §3.1 winning layout: [4,2,2] with 48/20/12 layers
        Pipeline {
            stages: vec![
                Stage { devices: vec![0, 1, 2, 3], layers: 48 },
                Stage { devices: vec![4, 5], layers: 20 },
                Stage { devices: vec![6, 7], layers: 12 },
            ],
        }
    }

    #[test]
    fn strategy_notation() {
        let p = case_plan();
        assert_eq!(p.strategy_string(), "[4,2,2]");
        assert_eq!(p.layer_string(), "48/20/12");
        assert_eq!(p.num_stages(), 3);
        assert_eq!(p.total_layers(), 80);
    }

    #[test]
    fn pipeline_validation() {
        let m = ModelSpec::llama2_70b();
        assert!(case_plan().validate(&m).is_ok());

        let mut wrong_layers = case_plan();
        wrong_layers.stages[0].layers = 10;
        assert!(wrong_layers.validate(&m).is_err());

        let mut dup = case_plan();
        dup.stages[1].devices = vec![0, 5];
        assert!(dup.validate(&m).is_err());

        let mut empty = case_plan();
        empty.stages[2].devices.clear();
        assert!(empty.validate(&m).is_err());

        let mut zero = case_plan();
        zero.stages[0].layers = 0;
        zero.stages[1].layers = 68;
        assert!(zero.validate(&m).is_err());
    }

    #[test]
    fn deployment_validation() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let d = Deployment { pipelines: vec![case_plan()] };
        assert!(d.validate(&c, &m).is_ok());

        // two pipelines sharing a device
        let d2 = Deployment {
            pipelines: vec![case_plan(), case_plan()],
        };
        assert!(d2.validate(&c, &m).is_err());

        // offline device rejected
        let mut c2 = c.clone();
        c2.take_offline(&[3]);
        let d3 = Deployment { pipelines: vec![case_plan()] };
        assert!(d3.validate(&c2, &m).is_err());
    }

    #[test]
    fn deployment_memory_validation() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let good = Deployment { pipelines: vec![case_plan()] };
        assert!(good.validate_memory(&cm, &t).is_ok());

        let bad = Deployment {
            pipelines: vec![Pipeline {
                stages: vec![
                    Stage { devices: vec![0, 1, 2, 3], layers: 10 },
                    Stage { devices: vec![6, 7], layers: 70 }, // A4000 OOM
                ],
            }],
        };
        assert!(bad.validate_memory(&cm, &t).is_err());
    }

    #[test]
    fn describe_mentions_strategy() {
        let c = cluster::case_study();
        let d = Deployment { pipelines: vec![case_plan()] };
        let s = d.describe(&c);
        assert!(s.contains("[4,2,2]"));
        assert!(s.contains("48/20/12"));
        assert!(s.contains("A6000"));
    }
}
