//! Inference workload generation (paper §5.1).
//!
//! Requests arrive by a Poisson process (exponential inter-arrival times)
//! parameterized by the request rate; prompt lengths follow a clipped
//! log-normal fit to chatbot-arena-style conversations; output lengths are
//! fixed per experiment (32/64/128), as in the paper's grids.

use crate::costmodel::InferenceTask;
use crate::util::rng::Xoshiro256pp;

/// One inference request in a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: usize,
    /// Arrival time, seconds from trace start.
    pub arrival: f64,
    pub task: InferenceTask,
}

/// Prompt/output length distribution.
#[derive(Debug, Clone, Copy)]
pub enum LengthDist {
    /// Every request has exactly this (s_in, s_out).
    Fixed { s_in: usize, s_out: usize },
    /// Log-normal prompt lengths (clipped), fixed output length — the
    /// §5.1 setup: real-prompt inputs, swept output lengths.
    LmsysLike { s_out: usize },
}

impl LengthDist {
    fn sample(&self, rng: &mut Xoshiro256pp) -> (usize, usize) {
        match *self {
            LengthDist::Fixed { s_in, s_out } => (s_in, s_out),
            LengthDist::LmsysLike { s_out } => {
                // Chatbot-arena prompts: median ≈ 50 tokens, heavy tail;
                // ln N(4.0, 0.8) → median e^4 ≈ 55, p95 ≈ 205. Clip to
                // [8, 1024].
                let s_in = rng.log_normal(4.0, 0.8).round().clamp(8.0, 1024.0) as usize;
                (s_in, s_out)
            }
        }
    }
}

/// Workload generator configuration.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    /// Mean request rate, requests/second (Poisson).
    pub rate: f64,
    /// Number of requests to generate.
    pub num_requests: usize,
    pub lengths: LengthDist,
    pub seed: u64,
}

impl WorkloadSpec {
    /// Generate the request trace (sorted by arrival).
    pub fn generate(&self) -> Vec<Request> {
        assert!(self.rate > 0.0);
        let mut rng = Xoshiro256pp::seed_from_u64(self.seed);
        let mut t = 0.0;
        (0..self.num_requests)
            .map(|id| {
                t += rng.exponential(self.rate);
                let (s_in, s_out) = self.lengths.sample(&mut rng);
                Request {
                    id,
                    arrival: t,
                    task: InferenceTask::new(1, s_in, s_out),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let spec = WorkloadSpec {
            rate: 4.0,
            num_requests: 8000,
            lengths: LengthDist::Fixed { s_in: 128, s_out: 32 },
            seed: 1,
        };
        let trace = spec.generate();
        assert_eq!(trace.len(), 8000);
        let span = trace.last().unwrap().arrival;
        let measured_rate = 8000.0 / span;
        assert!((measured_rate - 4.0).abs() < 0.2, "rate={measured_rate}");
        // arrivals sorted
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn lmsys_lengths_plausible() {
        let spec = WorkloadSpec {
            rate: 1.0,
            num_requests: 5000,
            lengths: LengthDist::LmsysLike { s_out: 64 },
            seed: 2,
        };
        let trace = spec.generate();
        let mean_in: f64 =
            trace.iter().map(|r| r.task.s_in as f64).sum::<f64>() / trace.len() as f64;
        assert!((40.0..120.0).contains(&mean_in), "mean_in={mean_in}");
        assert!(trace.iter().all(|r| (8..=1024).contains(&r.task.s_in)));
        assert!(trace.iter().all(|r| r.task.s_out == 64));
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec {
            rate: 2.0,
            num_requests: 100,
            lengths: LengthDist::LmsysLike { s_out: 32 },
            seed: 7,
        };
        assert_eq!(spec.generate(), spec.generate());
    }
}
