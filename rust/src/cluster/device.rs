//! Concrete devices, machines and regions of a heterogeneous pool.

use super::gpu::GpuType;

/// Stable device identifier: index into `Cluster::devices`.
pub type DeviceId = usize;

/// One physical GPU in the pool.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub id: DeviceId,
    pub gpu: GpuType,
    /// Machine (instance) this GPU is plugged into.
    pub machine: usize,
    /// Geographic region of the machine.
    pub region: usize,
    /// False when the GPU has left the pool (Figure 4 dynamics).
    pub online: bool,
}

/// A rented instance: a set of same-type GPUs with a fast local interconnect.
#[derive(Debug, Clone, PartialEq)]
pub struct Machine {
    pub id: usize,
    pub region: usize,
    pub gpu: GpuType,
    pub num_gpus: usize,
    /// Intra-machine interconnect class.
    pub link: LocalLink,
    pub name: String,
}

/// Intra-machine GPU interconnect class (determines α/β of local links).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalLink {
    /// NVLink/NVSwitch (A100 SXM systems).
    NvLink,
    /// PCIe 4.0 peer-to-peer (workstation/server cards).
    Pcie4,
}

impl LocalLink {
    /// (latency seconds, bandwidth bytes/s) of one GPU↔GPU hop.
    pub fn alpha_beta(self) -> (f64, f64) {
        match self {
            // NVSwitch: ~600 GB/s per-GPU aggregate; α ≈ 5 µs.
            LocalLink::NvLink => (5e-6, 300e9),
            // PCIe 4.0 x16 p2p: ~16 GB/s effective; α ≈ 10 µs.
            LocalLink::Pcie4 => (10e-6, 16e9),
        }
    }
}

/// A named geographic region.
#[derive(Debug, Clone, PartialEq)]
pub struct Region {
    pub id: usize,
    pub name: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_classes_ordered() {
        let (a_nv, b_nv) = LocalLink::NvLink.alpha_beta();
        let (a_pc, b_pc) = LocalLink::Pcie4.alpha_beta();
        assert!(b_nv > b_pc);
        assert!(a_nv <= a_pc);
    }

    #[test]
    fn device_construction() {
        let d = Device {
            id: 3,
            gpu: GpuType::A5000,
            machine: 1,
            region: 0,
            online: true,
        };
        assert_eq!(d.gpu.name(), "A5000");
    }
}
