//! GPU device-type catalog.
//!
//! The scheduler and cost model only observe `(peak FP16 FLOPS, memory
//! bandwidth, memory capacity)` per device (paper §4.1: `c_d`, `m_d`,
//! `M_d`), so a catalog entry is a faithful substitute for real hardware.
//! Published vendor numbers; prices follow the paper's §5.1 budgets.

/// A GPU model in the heterogeneous pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuType {
    /// NVIDIA A100-SXM4 40GB (the homogeneous-baseline datacenter GPU).
    A100_40G,
    /// NVIDIA GeForce RTX 3090 Ti 24GB.
    RTX3090TI,
    /// NVIDIA RTX A6000 48GB.
    A6000,
    /// NVIDIA RTX A5000 24GB.
    A5000,
    /// NVIDIA A40 48GB.
    A40,
    /// NVIDIA RTX A4000 16GB.
    A4000,
}

/// Static capability record for a [`GpuType`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Human-readable name.
    pub name: &'static str,
    /// Device memory limit `M_d` in bytes.
    pub memory_bytes: f64,
    /// Device memory bandwidth `m_d` in bytes/second.
    pub memory_bandwidth: f64,
    /// Tensor-core FP16 peak `c_d` in FLOP/second.
    pub peak_flops: f64,
    /// Indicative on-demand price, $/hour (paper §5.1 budget accounting).
    pub price_per_hour: f64,
}

impl GpuType {
    pub const ALL: [GpuType; 6] = [
        GpuType::A100_40G,
        GpuType::RTX3090TI,
        GpuType::A6000,
        GpuType::A5000,
        GpuType::A40,
        GpuType::A4000,
    ];

    /// Catalog lookup. FLOPS are dense FP16 tensor-core peaks; bandwidths
    /// are vendor HBM/GDDR peaks.
    pub fn spec(self) -> GpuSpec {
        match self {
            GpuType::A100_40G => GpuSpec {
                name: "A100-40G",
                memory_bytes: 40e9,
                memory_bandwidth: 1555e9,
                peak_flops: 312e12,
                // p4d.24xlarge: $32.77/h for 8 GPUs
                price_per_hour: 4.10,
            },
            GpuType::RTX3090TI => GpuSpec {
                name: "3090Ti",
                memory_bytes: 24e9,
                memory_bandwidth: 1008e9,
                peak_flops: 160e12,
                price_per_hour: 1.20,
            },
            GpuType::A6000 => GpuSpec {
                name: "A6000",
                memory_bytes: 48e9,
                memory_bandwidth: 768e9,
                peak_flops: 155e12,
                price_per_hour: 1.45,
            },
            GpuType::A5000 => GpuSpec {
                name: "A5000",
                memory_bytes: 24e9,
                memory_bandwidth: 768e9,
                peak_flops: 111e12,
                price_per_hour: 0.95,
            },
            GpuType::A40 => GpuSpec {
                name: "A40",
                memory_bytes: 48e9,
                memory_bandwidth: 696e9,
                peak_flops: 150e12,
                price_per_hour: 1.35,
            },
            GpuType::A4000 => GpuSpec {
                name: "A4000",
                memory_bytes: 16e9,
                memory_bandwidth: 448e9,
                peak_flops: 77e12,
                price_per_hour: 0.55,
            },
        }
    }

    pub fn name(self) -> &'static str {
        self.spec().name
    }

    pub fn from_name(name: &str) -> Option<GpuType> {
        GpuType::ALL.into_iter().find(|t| t.name() == name)
    }

    /// Stable index into type-count vectors (τ in the paper).
    pub fn index(self) -> usize {
        GpuType::ALL.iter().position(|t| *t == self).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_sane() {
        for t in GpuType::ALL {
            let s = t.spec();
            assert!(s.memory_bytes >= 16e9, "{:?}", t);
            assert!(s.memory_bandwidth > 100e9);
            assert!(s.peak_flops > 10e12);
            assert!(s.price_per_hour > 0.0);
        }
    }

    #[test]
    fn a100_dominates_a4000() {
        let a100 = GpuType::A100_40G.spec();
        let a4000 = GpuType::A4000.spec();
        assert!(a100.peak_flops > a4000.peak_flops);
        assert!(a100.memory_bandwidth > a4000.memory_bandwidth);
        assert!(a100.memory_bytes > a4000.memory_bytes);
    }

    #[test]
    fn name_roundtrip() {
        for t in GpuType::ALL {
            assert_eq!(GpuType::from_name(t.name()), Some(t));
        }
        assert_eq!(GpuType::from_name("H100"), None);
    }

    #[test]
    fn index_is_stable_bijection() {
        let mut seen = vec![false; GpuType::ALL.len()];
        for t in GpuType::ALL {
            assert!(!seen[t.index()]);
            seen[t.index()] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }
}
