//! Heterogeneous communication matrices.
//!
//! Paper §4.1: `A ∈ R+^{N×N}` holds pairwise latency (α, seconds) and
//! `B ∈ R+^{N×N}` pairwise bandwidth (β, bytes/s). We synthesize them from
//! link classes matching the paper's §5.1 measurements:
//!   - intra-machine: NVLink or PCIe (device.rs);
//!   - intra-region, cross-machine: 2 ms / 5 Gbps;
//!   - inter-region: 40–150 ms / 0.3–1.0 Gbps (deterministic per region
//!     pair, seeded).

use super::device::{Device, Machine};
use crate::util::rng::Xoshiro256pp;

/// Dense symmetric communication matrices between all devices.
#[derive(Debug, Clone)]
pub struct CommMatrices {
    pub n: usize,
    /// Latency seconds; `alpha[i*n + j]`. Diagonal is 0.
    pub alpha: Vec<f64>,
    /// Bandwidth bytes/s; diagonal is +inf (no self-communication cost).
    pub beta: Vec<f64>,
}

/// Link-class parameters used to synthesize [`CommMatrices`].
#[derive(Debug, Clone)]
pub struct NetworkProfile {
    /// Cross-machine, same-region: (latency s, bandwidth bytes/s).
    pub intra_region: (f64, f64),
    /// Cross-region latency range (s).
    pub inter_region_alpha: (f64, f64),
    /// Cross-region bandwidth range (bytes/s).
    pub inter_region_beta: (f64, f64),
    /// Seed for the deterministic per-region-pair draw.
    pub seed: u64,
}

impl Default for NetworkProfile {
    fn default() -> Self {
        NetworkProfile {
            // §5.1 footnote: intra-region 2 ms, 5 Gbps.
            intra_region: (2e-3, 5e9 / 8.0),
            // inter-region 40–150 ms, 0.3–1.0 Gbps.
            inter_region_alpha: (40e-3, 150e-3),
            inter_region_beta: (0.3e9 / 8.0, 1.0e9 / 8.0),
            seed: 0x4E57_0001,
        }
    }
}

/// High-bandwidth datacenter fabric (A100 p4d: 400 Gbps EFA between
/// machines in the same placement group).
pub fn datacenter_profile() -> NetworkProfile {
    NetworkProfile {
        intra_region: (50e-6, 400e9 / 8.0),
        inter_region_alpha: (40e-3, 150e-3),
        inter_region_beta: (0.3e9 / 8.0, 1.0e9 / 8.0),
        seed: 0x4E57_0002,
    }
}

impl CommMatrices {
    /// Build matrices for `devices` grouped into `machines`.
    pub fn build(
        devices: &[Device],
        machines: &[Machine],
        profile: &NetworkProfile,
    ) -> CommMatrices {
        let n = devices.len();
        let mut alpha = vec![0.0; n * n];
        let mut beta = vec![f64::INFINITY; n * n];
        // Deterministic per-region-pair inter-region links.
        let nregions = devices.iter().map(|d| d.region).max().map_or(0, |r| r + 1);
        let mut rng = Xoshiro256pp::seed_from_u64(profile.seed);
        let mut region_alpha = vec![0.0; nregions * nregions];
        let mut region_beta = vec![0.0; nregions * nregions];
        for r1 in 0..nregions {
            for r2 in (r1 + 1)..nregions {
                let a = rng.gen_f64_range(profile.inter_region_alpha.0, profile.inter_region_alpha.1);
                let b = rng.gen_f64_range(profile.inter_region_beta.0, profile.inter_region_beta.1);
                region_alpha[r1 * nregions + r2] = a;
                region_alpha[r2 * nregions + r1] = a;
                region_beta[r1 * nregions + r2] = b;
                region_beta[r2 * nregions + r1] = b;
            }
        }
        for i in 0..n {
            for j in (i + 1)..n {
                let (a, b) = link_params(&devices[i], &devices[j], machines, profile, &region_alpha, &region_beta, nregions);
                alpha[i * n + j] = a;
                alpha[j * n + i] = a;
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        CommMatrices { n, alpha, beta }
    }

    #[inline]
    pub fn alpha(&self, i: usize, j: usize) -> f64 {
        self.alpha[i * self.n + j]
    }

    #[inline]
    pub fn beta(&self, i: usize, j: usize) -> f64 {
        self.beta[i * self.n + j]
    }

    /// α–β transfer time for `bytes` between devices `i` and `j`.
    #[inline]
    pub fn transfer_time(&self, i: usize, j: usize, bytes: f64) -> f64 {
        if i == j {
            return 0.0;
        }
        self.alpha(i, j) + bytes / self.beta(i, j)
    }

    /// Restrict the matrices to a device subset (preserving order), used
    /// when GPUs leave the pool (Figure 4).
    pub fn restrict(&self, keep: &[usize]) -> CommMatrices {
        let m = keep.len();
        let mut alpha = vec![0.0; m * m];
        let mut beta = vec![f64::INFINITY; m * m];
        for (a, &i) in keep.iter().enumerate() {
            for (b, &j) in keep.iter().enumerate() {
                alpha[a * m + b] = self.alpha(i, j);
                beta[a * m + b] = self.beta(i, j);
            }
        }
        CommMatrices { n: m, alpha, beta }
    }
}

fn link_params(
    d1: &Device,
    d2: &Device,
    machines: &[Machine],
    profile: &NetworkProfile,
    region_alpha: &[f64],
    region_beta: &[f64],
    nregions: usize,
) -> (f64, f64) {
    if d1.machine == d2.machine {
        machines[d1.machine].link.alpha_beta()
    } else if d1.region == d2.region {
        profile.intra_region
    } else {
        let idx = d1.region * nregions + d2.region;
        (region_alpha[idx], region_beta[idx])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::device::LocalLink;
    use crate::cluster::gpu::GpuType;

    fn mini_pool() -> (Vec<Device>, Vec<Machine>) {
        // machine 0 (region 0): 2×A6000; machine 1 (region 0): 1×A5000;
        // machine 2 (region 1): 1×3090Ti.
        let machines = vec![
            Machine { id: 0, region: 0, gpu: GpuType::A6000, num_gpus: 2, link: LocalLink::Pcie4, name: "m0".into() },
            Machine { id: 1, region: 0, gpu: GpuType::A5000, num_gpus: 1, link: LocalLink::Pcie4, name: "m1".into() },
            Machine { id: 2, region: 1, gpu: GpuType::RTX3090TI, num_gpus: 1, link: LocalLink::Pcie4, name: "m2".into() },
        ];
        let devices = vec![
            Device { id: 0, gpu: GpuType::A6000, machine: 0, region: 0, online: true },
            Device { id: 1, gpu: GpuType::A6000, machine: 0, region: 0, online: true },
            Device { id: 2, gpu: GpuType::A5000, machine: 1, region: 0, online: true },
            Device { id: 3, gpu: GpuType::RTX3090TI, machine: 2, region: 1, online: true },
        ];
        (devices, machines)
    }

    #[test]
    fn symmetry_and_diagonal() {
        let (d, m) = mini_pool();
        let c = CommMatrices::build(&d, &m, &NetworkProfile::default());
        for i in 0..4 {
            assert_eq!(c.alpha(i, i), 0.0);
            assert_eq!(c.beta(i, i), f64::INFINITY);
            for j in 0..4 {
                assert_eq!(c.alpha(i, j), c.alpha(j, i));
                assert_eq!(c.beta(i, j), c.beta(j, i));
            }
        }
    }

    #[test]
    fn link_hierarchy() {
        let (d, m) = mini_pool();
        let c = CommMatrices::build(&d, &m, &NetworkProfile::default());
        // intra-machine faster than intra-region faster than inter-region
        assert!(c.beta(0, 1) > c.beta(0, 2));
        assert!(c.beta(0, 2) > c.beta(0, 3));
        assert!(c.alpha(0, 1) < c.alpha(0, 2));
        assert!(c.alpha(0, 2) < c.alpha(0, 3));
        // inter-region in the paper's measured ranges
        assert!((40e-3..=150e-3).contains(&c.alpha(0, 3)));
        let gbps = c.beta(0, 3) * 8.0 / 1e9;
        assert!((0.3..=1.0).contains(&gbps), "{gbps}");
    }

    #[test]
    fn transfer_time_alpha_beta() {
        let (d, m) = mini_pool();
        let c = CommMatrices::build(&d, &m, &NetworkProfile::default());
        let t = c.transfer_time(0, 2, 1e6);
        assert!((t - (2e-3 + 1e6 / (5e9 / 8.0))).abs() < 1e-12);
        assert_eq!(c.transfer_time(1, 1, 1e9), 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let (d, m) = mini_pool();
        let c1 = CommMatrices::build(&d, &m, &NetworkProfile::default());
        let c2 = CommMatrices::build(&d, &m, &NetworkProfile::default());
        assert_eq!(c1.alpha, c2.alpha);
        assert_eq!(c1.beta, c2.beta);
    }

    #[test]
    fn restrict_preserves_pairs() {
        let (d, m) = mini_pool();
        let c = CommMatrices::build(&d, &m, &NetworkProfile::default());
        let r = c.restrict(&[0, 2, 3]);
        assert_eq!(r.n, 3);
        assert_eq!(r.alpha(0, 1), c.alpha(0, 2));
        assert_eq!(r.beta(1, 2), c.beta(2, 3));
    }
}
