//! Cluster assembly, JSON config round-trip, and the paper's four presets.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::device::{Device, DeviceId, LocalLink, Machine, Region};
use super::gpu::GpuType;
use super::network::{datacenter_profile, CommMatrices, NetworkProfile};

/// A fully assembled heterogeneous GPU pool: devices, topology, comm
/// matrices and budget. This is the scheduler's world model.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub name: String,
    pub regions: Vec<Region>,
    pub machines: Vec<Machine>,
    pub devices: Vec<Device>,
    pub comm: CommMatrices,
    /// Total rental budget, $/hour (paper §5.1).
    pub budget_per_hour: f64,
}

/// Declarative description used to build a [`Cluster`]; what the JSON
/// config encodes.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    /// (region name, list of (gpu type, count, link) machines).
    pub regions: Vec<(String, Vec<(GpuType, usize, LocalLink)>)>,
    pub profile: NetworkProfile,
}

impl ClusterSpec {
    pub fn build(&self) -> Cluster {
        let mut regions = Vec::new();
        let mut machines = Vec::new();
        let mut devices = Vec::new();
        let mut budget = 0.0;
        for (rid, (rname, machs)) in self.regions.iter().enumerate() {
            regions.push(Region { id: rid, name: rname.clone() });
            for (gpu, count, link) in machs {
                let mid = machines.len();
                machines.push(Machine {
                    id: mid,
                    region: rid,
                    gpu: *gpu,
                    num_gpus: *count,
                    link: *link,
                    name: format!("{rname}/m{mid}-{}x{}", count, gpu.name()),
                });
                for _ in 0..*count {
                    let id = devices.len();
                    devices.push(Device { id, gpu: *gpu, machine: mid, region: rid, online: true });
                    budget += gpu.spec().price_per_hour;
                }
            }
        }
        let comm = CommMatrices::build(&devices, &machines, &self.profile);
        Cluster {
            name: self.name.clone(),
            regions,
            machines,
            devices,
            comm,
            budget_per_hour: budget,
        }
    }
}

impl Cluster {
    /// Devices currently online.
    pub fn online_devices(&self) -> Vec<DeviceId> {
        self.devices.iter().filter(|d| d.online).map(|d| d.id).collect()
    }

    /// Count of online devices per GPU type — the τ vector of the full pool.
    pub fn type_counts(&self) -> Vec<usize> {
        let mut counts = vec![0; GpuType::ALL.len()];
        for d in &self.devices {
            if d.online {
                counts[d.gpu.index()] += 1;
            }
        }
        counts
    }

    /// Number of distinct GPU types present (paper's `N_T`).
    pub fn num_types(&self) -> usize {
        self.type_counts().iter().filter(|&&c| c > 0).count()
    }

    /// Take `n` devices offline (Figure 4 dynamics). Returns the ids.
    pub fn take_offline(&mut self, ids: &[DeviceId]) {
        for &id in ids {
            self.devices[id].online = false;
        }
    }

    /// Group online device ids by machine.
    pub fn devices_by_machine(&self) -> BTreeMap<usize, Vec<DeviceId>> {
        let mut m: BTreeMap<usize, Vec<DeviceId>> = BTreeMap::new();
        for d in &self.devices {
            if d.online {
                m.entry(d.machine).or_default().push(d.id);
            }
        }
        m
    }

    // ----- JSON config ----------------------------------------------------

    /// Serialize the *spec-level* description (machines/regions/profile).
    pub fn spec_to_json(spec: &ClusterSpec) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut root = Json::obj();
        root.set("name", Json::from(spec.name.as_str()));
        let regions: Vec<Json> = spec
            .regions
            .iter()
            .map(|(rname, machs)| {
                let mut r = Json::obj();
                r.set("name", Json::from(rname.as_str()));
                let ms: Vec<Json> = machs
                    .iter()
                    .map(|(gpu, count, link)| {
                        let mut m = Json::obj();
                        m.set("gpu", Json::from(gpu.name()));
                        m.set("count", Json::from(*count));
                        m.set(
                            "link",
                            Json::from(match link {
                                LocalLink::NvLink => "nvlink",
                                LocalLink::Pcie4 => "pcie4",
                            }),
                        );
                        m
                    })
                    .collect();
                r.set("machines", Json::Arr(ms));
                r
            })
            .collect();
        root.set("regions", Json::Arr(regions));
        let mut prof = Json::obj();
        prof.set("intra_region_alpha", Json::from(spec.profile.intra_region.0));
        prof.set("intra_region_beta", Json::from(spec.profile.intra_region.1));
        prof.set("inter_region_alpha_lo", Json::from(spec.profile.inter_region_alpha.0));
        prof.set("inter_region_alpha_hi", Json::from(spec.profile.inter_region_alpha.1));
        prof.set("inter_region_beta_lo", Json::from(spec.profile.inter_region_beta.0));
        prof.set("inter_region_beta_hi", Json::from(spec.profile.inter_region_beta.1));
        prof.set("seed", Json::from(spec.profile.seed));
        root.set("network", prof);
        root
    }

    /// Parse a spec from JSON (inverse of [`Cluster::spec_to_json`]).
    pub fn spec_from_json(j: &crate::util::json::Json) -> Result<ClusterSpec> {
        let name = j.str("name").context("cluster name")?.to_string();
        let mut regions = Vec::new();
        for r in j.arr("regions").context("regions")? {
            let rname = r.str("name")?.to_string();
            let mut machs = Vec::new();
            for m in r.arr("machines")? {
                let gpu_name = m.str("gpu")?;
                let gpu = GpuType::from_name(gpu_name)
                    .with_context(|| format!("unknown gpu type '{gpu_name}'"))?;
                let count = m.usize("count")?;
                if count == 0 {
                    bail!("machine with zero GPUs");
                }
                let link = match m.str("link")? {
                    "nvlink" => LocalLink::NvLink,
                    "pcie4" => LocalLink::Pcie4,
                    other => bail!("unknown link class '{other}'"),
                };
                machs.push((gpu, count, link));
            }
            regions.push((rname, machs));
        }
        let profile = match j.opt("network") {
            None => NetworkProfile::default(),
            Some(p) => NetworkProfile {
                intra_region: (p.f64("intra_region_alpha")?, p.f64("intra_region_beta")?),
                inter_region_alpha: (
                    p.f64("inter_region_alpha_lo")?,
                    p.f64("inter_region_alpha_hi")?,
                ),
                inter_region_beta: (
                    p.f64("inter_region_beta_lo")?,
                    p.f64("inter_region_beta_hi")?,
                ),
                seed: p.get("seed")?.as_u64()?,
            },
        };
        Ok(ClusterSpec { name, regions, profile })
    }

    pub fn spec_from_file(path: &str) -> Result<ClusterSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading cluster config {path}"))?;
        let j = crate::util::json::Json::parse(&text)?;
        Self::spec_from_json(&j)
    }
}

// ----- paper presets --------------------------------------------------------

/// §5.1 homogeneous baseline: two AWS p4d.24xlarge (8×A100-40G each),
/// NVLink intra-machine, 400 Gbps fabric between them. $65.54/hour.
pub fn homogeneous_a100() -> Cluster {
    ClusterSpec {
        name: "homogeneous-a100".into(),
        regions: vec![(
            "us-east-1".into(),
            vec![
                (GpuType::A100_40G, 8, LocalLink::NvLink),
                (GpuType::A100_40G, 8, LocalLink::NvLink),
            ],
        )],
        profile: datacenter_profile(),
    }
    .build()
}

/// §5.1 heterogeneous-full-price: 2×(8×3090Ti) Iceland, 2×(3×3090Ti)
/// Norway, 1×(8×A5000) Nevada, Illinois: 2×(8×A6000) + 1×(8×A5000) +
/// 1×(4×A40). 58 GPUs, ~$65/hour.
pub fn heterogeneous_full_price() -> Cluster {
    ClusterSpec {
        name: "heterogeneous-full-price".into(),
        regions: vec![
            (
                "iceland".into(),
                vec![
                    (GpuType::RTX3090TI, 8, LocalLink::Pcie4),
                    (GpuType::RTX3090TI, 8, LocalLink::Pcie4),
                ],
            ),
            (
                "norway".into(),
                vec![
                    (GpuType::RTX3090TI, 3, LocalLink::Pcie4),
                    (GpuType::RTX3090TI, 3, LocalLink::Pcie4),
                ],
            ),
            ("nevada".into(), vec![(GpuType::A5000, 8, LocalLink::Pcie4)]),
            (
                "illinois".into(),
                vec![
                    (GpuType::A6000, 8, LocalLink::Pcie4),
                    (GpuType::A6000, 8, LocalLink::Pcie4),
                    (GpuType::A5000, 8, LocalLink::Pcie4),
                    (GpuType::A40, 4, LocalLink::Pcie4),
                ],
            ),
        ],
        profile: NetworkProfile::default(),
    }
    .build()
}

/// §5.1 heterogeneous-half-price: Iceland 2×(8×3090Ti), Norway
/// 2×(3×3090Ti), Nevada 1×(8×A5000). 30 GPUs, ~$29.6/hour.
pub fn heterogeneous_half_price() -> Cluster {
    ClusterSpec {
        name: "heterogeneous-half-price".into(),
        regions: vec![
            (
                "iceland".into(),
                vec![
                    (GpuType::RTX3090TI, 8, LocalLink::Pcie4),
                    (GpuType::RTX3090TI, 8, LocalLink::Pcie4),
                ],
            ),
            (
                "norway".into(),
                vec![
                    (GpuType::RTX3090TI, 3, LocalLink::Pcie4),
                    (GpuType::RTX3090TI, 3, LocalLink::Pcie4),
                ],
            ),
            ("nevada".into(), vec![(GpuType::A5000, 8, LocalLink::Pcie4)]),
        ],
        profile: NetworkProfile::default(),
    }
    .build()
}

/// §3.1 case-study pool: one machine with 4×A6000-48G, one with
/// 2×A5000-24G, one with 2×A4000-16G, all in one region.
pub fn case_study() -> Cluster {
    ClusterSpec {
        name: "case-study".into(),
        regions: vec![(
            "local".into(),
            vec![
                (GpuType::A6000, 4, LocalLink::Pcie4),
                (GpuType::A5000, 2, LocalLink::Pcie4),
                (GpuType::A4000, 2, LocalLink::Pcie4),
            ],
        )],
        profile: NetworkProfile::default(),
    }
    .build()
}

/// Look up a preset by name (CLI `--cluster`).
pub fn preset(name: &str) -> Option<Cluster> {
    match name {
        "homogeneous" | "homogeneous-a100" => Some(homogeneous_a100()),
        "full-price" | "heterogeneous-full-price" => Some(heterogeneous_full_price()),
        "half-price" | "heterogeneous-half-price" => Some(heterogeneous_half_price()),
        "case-study" => Some(case_study()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_price_has_58_gpus() {
        let c = heterogeneous_full_price();
        assert_eq!(c.devices.len(), 58);
        assert_eq!(c.regions.len(), 4);
        assert_eq!(c.machines.len(), 9);
        // 3090Ti: 16+6 = 22; A5000: 8+8 = 16; A6000: 16; A40: 4
        let counts = c.type_counts();
        assert_eq!(counts[GpuType::RTX3090TI.index()], 22);
        assert_eq!(counts[GpuType::A5000.index()], 16);
        assert_eq!(counts[GpuType::A6000.index()], 16);
        assert_eq!(counts[GpuType::A40.index()], 4);
        assert_eq!(c.num_types(), 4);
    }

    #[test]
    fn half_price_has_30_gpus() {
        let c = heterogeneous_half_price();
        assert_eq!(c.devices.len(), 30);
        assert_eq!(c.num_types(), 2);
    }

    #[test]
    fn homogeneous_budget_close_to_paper() {
        let c = homogeneous_a100();
        assert_eq!(c.devices.len(), 16);
        // paper: $65.54/hour for 16 A100s
        assert!((c.budget_per_hour - 65.54).abs() < 2.0, "{}", c.budget_per_hour);
    }

    #[test]
    fn full_vs_half_budget_ratio() {
        let full = heterogeneous_full_price().budget_per_hour;
        let half = heterogeneous_half_price().budget_per_hour;
        // paper: $65.04 vs $29.6 — half should be ~45% of full
        let ratio = half / full;
        assert!((0.35..0.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn case_study_pool() {
        let c = case_study();
        assert_eq!(c.devices.len(), 8);
        assert_eq!(c.machines.len(), 3);
    }

    #[test]
    fn offline_devices_excluded() {
        let mut c = heterogeneous_half_price();
        c.take_offline(&[0, 1, 2, 3]);
        assert_eq!(c.online_devices().len(), 26);
        assert_eq!(c.type_counts().iter().sum::<usize>(), 26);
    }

    #[test]
    fn json_spec_roundtrip() {
        let spec = ClusterSpec {
            name: "rt".into(),
            regions: vec![
                ("r0".into(), vec![(GpuType::A6000, 4, LocalLink::Pcie4)]),
                ("r1".into(), vec![(GpuType::A100_40G, 8, LocalLink::NvLink)]),
            ],
            profile: NetworkProfile::default(),
        };
        let j = Cluster::spec_to_json(&spec);
        let spec2 = Cluster::spec_from_json(&j).unwrap();
        assert_eq!(spec2.name, "rt");
        assert_eq!(spec2.regions.len(), 2);
        assert_eq!(spec2.regions[0].1[0].0, GpuType::A6000);
        assert_eq!(spec2.regions[1].1[0].2, LocalLink::NvLink);
        let c1 = spec.build();
        let c2 = spec2.build();
        assert_eq!(c1.devices.len(), c2.devices.len());
        assert_eq!(c1.comm.alpha, c2.comm.alpha);
    }

    #[test]
    fn bad_configs_rejected() {
        use crate::util::json::Json;
        let bad = Json::parse(r#"{"name":"x","regions":[{"name":"r","machines":[{"gpu":"H100","count":1,"link":"pcie4"}]}]}"#).unwrap();
        assert!(Cluster::spec_from_json(&bad).is_err());
        let zero = Json::parse(r#"{"name":"x","regions":[{"name":"r","machines":[{"gpu":"A40","count":0,"link":"pcie4"}]}]}"#).unwrap();
        assert!(Cluster::spec_from_json(&zero).is_err());
    }

    #[test]
    fn presets_resolve() {
        for name in ["homogeneous", "full-price", "half-price", "case-study"] {
            assert!(preset(name).is_some(), "{name}");
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn devices_by_machine_groups() {
        let c = case_study();
        let by_m = c.devices_by_machine();
        assert_eq!(by_m.len(), 3);
        assert_eq!(by_m[&0].len(), 4);
        assert_eq!(by_m[&1].len(), 2);
        assert_eq!(by_m[&2].len(), 2);
    }
}
