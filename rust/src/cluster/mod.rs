//! Heterogeneous GPU pool model: device catalog, machines/regions,
//! communication matrices (paper §4.1's **A** and **B**), and the four
//! cluster presets used by the evaluation (§5.1, §3.1).

pub mod device;
pub mod gpu;
pub mod network;
pub mod spec;

pub use device::{Device, DeviceId, LocalLink, Machine, Region};
pub use gpu::{GpuSpec, GpuType};
pub use network::{CommMatrices, NetworkProfile};
pub use spec::{
    case_study, heterogeneous_full_price, heterogeneous_half_price, homogeneous_a100,
    preset, Cluster, ClusterSpec,
};
