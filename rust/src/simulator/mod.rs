//! Serving simulation: discrete-event engine over deployments (the
//! evaluation substrate for Figures 2–7) and the SLO model.

pub mod engine;
pub mod event;

pub use engine::{
    attainment_absolute, batch_timing, estimate_attainment, simulate, BatchPolicy,
    RequestRecord, RouterPolicy, SimConfig, SimOutcome, SloModel,
};
pub use event::EventQueue;
