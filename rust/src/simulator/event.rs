//! Discrete-event queue: a time-ordered heap with stable tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at `time`; `seq` breaks ties FIFO.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (max-heap).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Min-heap event queue driving the simulation clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current simulation time (last popped event time).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute `time` (must not be in the past).
    pub fn schedule(&mut self, time: f64, event: E) {
        debug_assert!(time >= self.now - 1e-9, "schedule into the past: {time} < {}", self.now);
        self.heap.push(Scheduled { time, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            self.now = s.time;
            (s.time, s.event)
        })
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        assert!(q.is_empty());
    }
}
