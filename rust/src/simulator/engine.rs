//! Discrete-event serving simulator (AlpaServe-style, paper §4.3/§5.1).
//!
//! Deployment model: each pipeline replica is a chain of stage servers.
//! A replica admits a batch, which occupies the pipeline's *bottleneck
//! stage period* before the next batch can enter (standard pipeline
//! queueing), and completes after the full Eq. 2 latency. Batch formation
//! is FIFO with padding to the longest member.
//!
//! Batching granularity (Appendix D): HexGen's simple batching admits at
//! whole-job granularity (`continuous: false`); the HF-TGI baseline's
//! continuous batching admits at token granularity — new work can join a
//! running decode loop every output token — modeled as an admission
//! period of one decode-token bottleneck step (`continuous: true`).

use crate::cluster::Cluster;
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::model::ModelSpec;
use crate::parallelism::Deployment;
use crate::util::stats::fraction_within;
use crate::workload::Request;

use super::event::EventQueue;
use std::collections::VecDeque;

/// Batch admission policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests folded into one batch.
    pub max_batch: usize,
    /// Token-granularity admission (continuous batching, TGI-style).
    pub continuous: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // HexGen has no advanced batching policy (Appendix D), and the
        // FlashAttention baseline is the same stack in symmetric mode:
        // replicas process requests one at a time. Parallel request
        // processing comes from replica count — the §5.2 economics.
        // (The TGI baseline overrides this with continuous batching.)
        BatchPolicy { max_batch: 1, continuous: false }
    }
}

/// Request routing policy across replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    /// Estimated earliest completion (queue backlog × reference period).
    LeastLoaded,
}

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub batch: BatchPolicy,
    pub router: RouterPolicy,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { batch: BatchPolicy::default(), router: RouterPolicy::LeastLoaded }
    }
}

/// Per-request simulation record.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub task: InferenceTask,
    pub arrival: f64,
    pub completion: f64,
    /// Completion − arrival (queueing + execution).
    pub latency: f64,
    pub replica: usize,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub records: Vec<RequestRecord>,
    pub makespan: f64,
}

impl SimOutcome {
    /// SLO attainment: fraction of requests finishing within
    /// `scale × reference_latency(task)` (paper §5.1: SLO scaled to the
    /// A100 execution latency of the task).
    pub fn attainment(&self, slo: &SloModel, scale: f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let ok = self
            .records
            .iter()
            .filter(|r| r.latency <= scale * slo.reference_latency(&r.task))
            .count();
        ok as f64 / self.records.len() as f64
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.latency).collect()
    }

    /// Minimum SLO scale achieving `target` attainment (bisection over the
    /// per-request normalized latency distribution) — the paper's
    /// "minimum latency deadline" metric.
    pub fn min_scale_for_attainment(&self, slo: &SloModel, target: f64) -> f64 {
        // Guard the degenerate inputs like `attainment` does: with no
        // records (or a target rounding `target·n` to 0) the old index
        // arithmetic underflowed `0 - 1`.
        if self.records.is_empty() {
            return f64::INFINITY;
        }
        let mut norms: Vec<f64> = self
            .records
            .iter()
            .map(|r| r.latency / slo.reference_latency(&r.task))
            .collect();
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = norms.len();
        let idx = ((target * n as f64).ceil() as usize).clamp(1, n) - 1;
        norms[idx]
    }

    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / self.makespan
    }
}

/// SLO reference: the task's execution latency on the paper's A100
/// datacenter baseline (8×A100, TP=8).
pub struct SloModel {
    cluster: Cluster,
    model: ModelSpec,
}

impl SloModel {
    pub fn new(model: &ModelSpec) -> SloModel {
        SloModel {
            cluster: crate::cluster::homogeneous_a100(),
            model: model.clone(),
        }
    }

    /// Execution latency of `task` on 8×A100 TP=8 (no queueing).
    pub fn reference_latency(&self, task: &InferenceTask) -> f64 {
        let cm = CostModel::new(&self.cluster, &self.model);
        let g: Vec<usize> = (0..8).collect();
        cm.pipeline_cost(&[(g, self.model.layers)], task, Phase::Both)
            .expect("A100 TP=8 reference is feasible")
    }
}

// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Event {
    Arrival(usize),
    /// Replica may admit its next batch.
    Admit(usize),
    /// Batch completes: (replica, request indices, batch task).
    Done(usize, Vec<usize>),
}

struct ReplicaState {
    stages: Vec<(Vec<usize>, usize)>,
    queue: VecDeque<usize>,
    /// Earliest time the pipeline entry stage is free.
    next_admit: f64,
    /// Reference single-request (latency, period) for routing estimates.
    ref_latency: f64,
    ref_period: f64,
    /// False when the reference batch violates memory on this replica:
    /// the router must not estimate it (its reference timings are ∞, and
    /// `0 × ∞ = NaN` used to poison the least-loaded comparison).
    feasible: bool,
    /// Jobs in flight (for least-loaded accounting).
    in_flight: usize,
}

/// Run the discrete-event simulation of `deployment` over `trace`.
pub fn simulate(
    cm: &CostModel,
    deployment: &Deployment,
    trace: &[Request],
    cfg: &SimConfig,
) -> SimOutcome {
    assert!(!deployment.pipelines.is_empty());
    let ref_task = InferenceTask::new(1, 64, 64);
    let mut replicas: Vec<ReplicaState> = deployment
        .pipelines
        .iter()
        .map(|p| {
            let stages: Vec<(Vec<usize>, usize)> = p
                .stages
                .iter()
                .map(|s| (s.devices.clone(), s.layers))
                .collect();
            let timing = batch_timing(cm, &stages, &ref_task, cfg.batch.continuous);
            let (lat, per) = timing.unwrap_or((f64::INFINITY, f64::INFINITY));
            ReplicaState {
                stages,
                queue: VecDeque::new(),
                next_admit: 0.0,
                ref_latency: lat,
                ref_period: per,
                feasible: timing.is_some(),
                in_flight: 0,
            }
        })
        .collect();

    let mut records: Vec<Option<RequestRecord>> = vec![None; trace.len()];
    let mut q: EventQueue<Event> = EventQueue::new();
    for (i, r) in trace.iter().enumerate() {
        q.schedule(r.arrival, Event::Arrival(i));
    }
    let mut rr_next = 0usize;

    while let Some((now, ev)) = q.pop() {
        match ev {
            Event::Arrival(i) => {
                let r = pick_replica(&replicas, cfg.router, &mut rr_next, now);
                replicas[r].queue.push_back(i);
                if replicas[r].next_admit <= now {
                    q.schedule(now, Event::Admit(r));
                }
            }
            Event::Admit(r) => {
                let rep = &mut replicas[r];
                if rep.queue.is_empty() || rep.next_admit > now + 1e-12 {
                    continue;
                }
                // FIFO batch, padded to the longest member.
                let take = rep.queue.len().min(cfg.batch.max_batch);
                let members: Vec<usize> = (0..take).filter_map(|_| rep.queue.pop_front()).collect();
                let batch_task = InferenceTask::new(
                    members.len(),
                    members.iter().map(|&i| trace[i].task.s_in).max().unwrap(),
                    members.iter().map(|&i| trace[i].task.s_out).max().unwrap(),
                );
                match batch_timing(cm, &rep.stages, &batch_task, cfg.batch.continuous) {
                    Some((latency, period)) => {
                        rep.next_admit = now + period;
                        rep.in_flight += 1;
                        q.schedule(now + latency, Event::Done(r, members));
                        if !rep.queue.is_empty() {
                            q.schedule(rep.next_admit, Event::Admit(r));
                        }
                    }
                    None => {
                        // Batch violates memory (batch too big for the KV
                        // budget): retry with half the batch by re-queueing
                        // the tail; single requests that still violate are
                        // dropped as failed (counted as +inf latency).
                        if members.len() > 1 {
                            let half = members.len() / 2;
                            for &i in members[half..].iter().rev() {
                                rep.queue.push_front(i);
                            }
                            for &i in members[..half].iter().rev() {
                                rep.queue.push_front(i);
                            }
                            // force a smaller admit by temporarily lowering cap:
                            // simplest: admit exactly half now.
                            let take = half.max(1);
                            let retry: Vec<usize> =
                                (0..take).filter_map(|_| rep.queue.pop_front()).collect();
                            let retry_task = InferenceTask::new(
                                retry.len(),
                                retry.iter().map(|&i| trace[i].task.s_in).max().unwrap(),
                                retry.iter().map(|&i| trace[i].task.s_out).max().unwrap(),
                            );
                            if let Some((latency, period)) =
                                batch_timing(cm, &rep.stages, &retry_task, cfg.batch.continuous)
                            {
                                rep.next_admit = now + period;
                                rep.in_flight += 1;
                                q.schedule(now + latency, Event::Done(r, retry));
                            } else {
                                for i in retry {
                                    records[i] = Some(failed_record(&trace[i], r));
                                }
                            }
                            if !rep.queue.is_empty() {
                                q.schedule(rep.next_admit.max(now), Event::Admit(r));
                            }
                        } else {
                            for i in members {
                                records[i] = Some(failed_record(&trace[i], r));
                            }
                            if !rep.queue.is_empty() {
                                q.schedule(now, Event::Admit(r));
                            }
                        }
                    }
                }
            }
            Event::Done(r, members) => {
                replicas[r].in_flight = replicas[r].in_flight.saturating_sub(1);
                for i in members {
                    records[i] = Some(RequestRecord {
                        task: trace[i].task,
                        arrival: trace[i].arrival,
                        completion: now,
                        latency: now - trace[i].arrival,
                        replica: r,
                    });
                }
                if !replicas[r].queue.is_empty() && replicas[r].next_admit <= now {
                    q.schedule(now, Event::Admit(r));
                }
            }
        }
    }

    let records: Vec<RequestRecord> = records
        .into_iter()
        .map(|r| r.expect("request never completed"))
        .collect();
    let makespan = records
        .iter()
        .map(|r| r.completion)
        .fold(0.0_f64, f64::max);
    SimOutcome { records, makespan }
}

fn failed_record(req: &Request, replica: usize) -> RequestRecord {
    RequestRecord {
        task: req.task,
        arrival: req.arrival,
        completion: f64::INFINITY,
        latency: f64::INFINITY,
        replica,
    }
}

/// (end-to-end latency, admission period) of one batch on a pipeline.
///
/// Latency is the exact Eq. 2 cost. The period is the bottleneck stage
/// time (compute + TP comm + outgoing PP hand-off); continuous batching
/// divides it by `s_out` (token-granularity admission).
pub fn batch_timing(
    cm: &CostModel,
    stages: &[(Vec<usize>, usize)],
    task: &InferenceTask,
    continuous: bool,
) -> Option<(f64, f64)> {
    let latency = cm.pipeline_cost(stages, task, Phase::Both)?;
    let mut bottleneck: f64 = 0.0;
    for (j, (devs, layers)) in stages.iter().enumerate() {
        let mut t = cm.stage_cost(devs, *layers, task, Phase::Both)?;
        if j + 1 < stages.len() {
            t += cm.comm_pp_cost(devs, &stages[j + 1].0, task, Phase::Both);
        }
        bottleneck = bottleneck.max(t);
    }
    let period = if continuous {
        bottleneck / task.s_out as f64
    } else {
        bottleneck
    };
    Some((latency, period))
}

fn pick_replica(
    replicas: &[ReplicaState],
    policy: RouterPolicy,
    rr_next: &mut usize,
    now: f64,
) -> usize {
    match policy {
        RouterPolicy::RoundRobin => {
            let r = *rr_next % replicas.len();
            *rr_next += 1;
            r
        }
        RouterPolicy::LeastLoaded => {
            // Estimated completion if routed here: admission backlog plus
            // one reference latency. Replicas whose reference batch
            // violates memory are explicitly non-routable — an idle one
            // used to estimate `0 × ∞ = NaN` and silently fall through
            // the comparison.
            let mut best = None;
            let mut best_est = f64::INFINITY;
            for (i, rep) in replicas.iter().enumerate() {
                if !rep.feasible {
                    continue;
                }
                let backlog = rep.queue.len() as f64 * rep.ref_period;
                let est = rep.next_admit.max(now) + backlog + rep.ref_latency;
                if est < best_est {
                    best_est = est;
                    best = Some(i);
                }
            }
            // Every replica infeasible: fall back to replica 0, where the
            // requests are recorded as failed.
            best.unwrap_or(0)
        }
    }
}

/// Convenience: simulate and return attainment at one SLO scale.
pub fn estimate_attainment(
    cm: &CostModel,
    deployment: &Deployment,
    trace: &[Request],
    cfg: &SimConfig,
    slo: &SloModel,
    scale: f64,
) -> f64 {
    simulate(cm, deployment, trace, cfg).attainment(slo, scale)
}

/// Fraction of per-request latencies within an absolute deadline.
pub fn attainment_absolute(outcome: &SimOutcome, deadline: f64) -> f64 {
    fraction_within(&outcome.latencies(), deadline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::parallelism::{Pipeline, Stage};
    use crate::workload::{LengthDist, WorkloadSpec};

    fn a100_deploy(nrep: usize) -> Deployment {
        // 16 A100s → `nrep` replicas of TP=16/nrep... use TP=8 replicas.
        assert!(nrep <= 2);
        let pipelines = (0..nrep)
            .map(|i| Pipeline {
                stages: vec![Stage {
                    devices: (i * 8..(i + 1) * 8).collect(),
                    layers: 80,
                }],
            })
            .collect();
        Deployment { pipelines }
    }

    fn fixture() -> (Cluster, ModelSpec) {
        (cluster::homogeneous_a100(), ModelSpec::llama2_70b())
    }

    #[test]
    fn single_request_latency_equals_cost() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let d = a100_deploy(1);
        let task = InferenceTask::new(1, 128, 32);
        let trace = vec![Request { id: 0, arrival: 0.0, task }];
        let out = simulate(&cm, &d, &trace, &SimConfig::default());
        let expect = cm
            .pipeline_cost(&[((0..8).collect(), 80)], &task, Phase::Both)
            .unwrap();
        assert!((out.records[0].latency - expect).abs() < 1e-9);
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let d = a100_deploy(2);
        let trace = WorkloadSpec {
            rate: 2.0,
            num_requests: 300,
            lengths: LengthDist::Fixed { s_in: 128, s_out: 32 },
            seed: 3,
        }
        .generate();
        let out = simulate(&cm, &d, &trace, &SimConfig::default());
        assert_eq!(out.records.len(), 300);
        // completion >= arrival + pure execution lower bound
        for r in &out.records {
            assert!(r.latency > 0.0);
        }
    }

    #[test]
    fn attainment_monotone_in_scale() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let slo = SloModel::new(&m);
        let d = a100_deploy(2);
        let trace = WorkloadSpec {
            rate: 1.0,
            num_requests: 200,
            lengths: LengthDist::LmsysLike { s_out: 32 },
            seed: 4,
        }
        .generate();
        let out = simulate(&cm, &d, &trace, &SimConfig::default());
        let mut prev = 0.0;
        for scale in [1.0, 2.0, 4.0, 8.0, 16.0] {
            let a = out.attainment(&slo, scale);
            assert!(a >= prev - 1e-12, "attainment not monotone");
            prev = a;
        }
    }

    #[test]
    fn higher_rate_lowers_attainment() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let slo = SloModel::new(&m);
        let d = a100_deploy(1);
        let att = |rate: f64| {
            let trace = WorkloadSpec {
                rate,
                num_requests: 200,
                lengths: LengthDist::Fixed { s_in: 128, s_out: 32 },
                seed: 5,
            }
            .generate();
            simulate(&cm, &d, &trace, &SimConfig::default()).attainment(&slo, 5.0)
        };
        let low = att(0.05);
        let high = att(20.0);
        assert!(low > high, "low-rate {low} vs high-rate {high}");
        assert!(low > 0.9);
    }

    #[test]
    fn continuous_batching_improves_throughput() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let d = a100_deploy(1);
        let trace = WorkloadSpec {
            rate: 4.0,
            num_requests: 200,
            lengths: LengthDist::Fixed { s_in: 128, s_out: 32 },
            seed: 6,
        }
        .generate();
        let simple = simulate(
            &cm,
            &d,
            &trace,
            &SimConfig { batch: BatchPolicy { max_batch: 8, continuous: false }, router: RouterPolicy::LeastLoaded },
        );
        let cont = simulate(
            &cm,
            &d,
            &trace,
            &SimConfig { batch: BatchPolicy { max_batch: 8, continuous: true }, router: RouterPolicy::LeastLoaded },
        );
        assert!(cont.makespan <= simple.makespan + 1e-9);
        let mean = |o: &SimOutcome| {
            o.latencies().iter().sum::<f64>() / o.records.len() as f64
        };
        assert!(mean(&cont) <= mean(&simple) * 1.001);
    }

    #[test]
    fn least_loaded_beats_round_robin_on_asymmetric_replicas() {
        // replica 0: TP=8 (fast); replica 1: PP=8 (slow) — least-loaded
        // should push most traffic to the fast replica.
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let slow = Pipeline {
            stages: (0..8)
                .map(|i| Stage { devices: vec![8 + i], layers: 10 })
                .collect(),
        };
        let fast = Pipeline {
            stages: vec![Stage { devices: (0..8).collect(), layers: 80 }],
        };
        let d = Deployment { pipelines: vec![fast, slow] };
        let trace = WorkloadSpec {
            rate: 2.0,
            num_requests: 300,
            lengths: LengthDist::Fixed { s_in: 128, s_out: 32 },
            seed: 7,
        }
        .generate();
        // batch=8 keeps the system under capacity so the routing policy —
        // not overload queueing noise — determines mean latency.
        let batch = BatchPolicy { max_batch: 8, continuous: false };
        let ll = simulate(
            &cm,
            &d,
            &trace,
            &SimConfig { batch, router: RouterPolicy::LeastLoaded },
        );
        let rr = simulate(
            &cm,
            &d,
            &trace,
            &SimConfig { batch, router: RouterPolicy::RoundRobin },
        );
        let mean = |o: &SimOutcome| o.latencies().iter().sum::<f64>() / o.records.len() as f64;
        assert!(mean(&ll) < mean(&rr), "ll {} rr {}", mean(&ll), mean(&rr));
    }

    #[test]
    fn min_scale_matches_attainment() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let slo = SloModel::new(&m);
        let d = a100_deploy(2);
        let trace = WorkloadSpec {
            rate: 2.0,
            num_requests: 200,
            lengths: LengthDist::LmsysLike { s_out: 32 },
            seed: 8,
        }
        .generate();
        let out = simulate(&cm, &d, &trace, &SimConfig::default());
        let s99 = out.min_scale_for_attainment(&slo, 0.99);
        let att = out.attainment(&slo, s99);
        assert!(att >= 0.99, "att={att} at scale {s99}");
        let att_below = out.attainment(&slo, s99 * 0.95);
        assert!(att_below <= att);
    }

    #[test]
    fn min_scale_guards_degenerate_inputs() {
        // Regression: empty records (or a target rounding target·n to 0)
        // used to underflow `0 - 1` in the index arithmetic.
        let (c, m) = fixture();
        let slo = SloModel::new(&m);
        let empty = SimOutcome { records: vec![], makespan: 0.0 };
        assert!(empty.min_scale_for_attainment(&slo, 0.99).is_infinite());
        assert!(empty.min_scale_for_attainment(&slo, 0.0).is_infinite());

        let cm = CostModel::new(&c, &m);
        let d = a100_deploy(1);
        let task = InferenceTask::new(1, 128, 32);
        let trace = vec![Request { id: 0, arrival: 0.0, task }];
        let out = simulate(&cm, &d, &trace, &SimConfig::default());
        // target 0 clamps to the fastest request instead of indexing -1
        let s = out.min_scale_for_attainment(&slo, 0.0);
        assert!(s.is_finite() && s > 0.0);
        assert_eq!(s, out.min_scale_for_attainment(&slo, 1.0));
    }

    #[test]
    fn least_loaded_skips_memory_infeasible_replicas() {
        // Regression for the NaN load estimate: an idle replica whose
        // reference batch violates memory had ref_period = ∞, so its
        // backlog estimate was 0 × ∞ = NaN and the comparison silently
        // fell through. Infeasible replicas must be explicitly
        // non-routable.
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        // replica 0: one A100-40G cannot hold 80 fp16 layers (~129 GB);
        // replica 1: the feasible TP=8 pipeline.
        let infeasible = Pipeline { stages: vec![Stage { devices: vec![8], layers: 80 }] };
        let feasible = Pipeline {
            stages: vec![Stage { devices: (0..8).collect(), layers: 80 }],
        };
        let d = Deployment { pipelines: vec![infeasible, feasible] };
        let trace = WorkloadSpec {
            rate: 1.0,
            num_requests: 50,
            lengths: LengthDist::Fixed { s_in: 64, s_out: 32 },
            seed: 9,
        }
        .generate();
        let out = simulate(&cm, &d, &trace, &SimConfig::default());
        assert!(
            out.records.iter().all(|r| r.replica == 1),
            "traffic reached the infeasible replica"
        );
        assert!(out.records.iter().all(|r| r.latency.is_finite()));
    }

    #[test]
    fn all_infeasible_replicas_fail_without_panicking() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let slo = SloModel::new(&m);
        let d = Deployment {
            pipelines: vec![Pipeline { stages: vec![Stage { devices: vec![8], layers: 80 }] }],
        };
        let trace = WorkloadSpec {
            rate: 1.0,
            num_requests: 10,
            lengths: LengthDist::Fixed { s_in: 64, s_out: 32 },
            seed: 10,
        }
        .generate();
        let out = simulate(&cm, &d, &trace, &SimConfig::default());
        assert_eq!(out.records.len(), 10);
        assert!(out.records.iter().all(|r| r.latency.is_infinite()));
        assert_eq!(out.attainment(&slo, 100.0), 0.0);
        assert!(out.min_scale_for_attainment(&slo, 0.99).is_infinite());
    }

    #[test]
    fn slo_reference_scales_with_output_len() {
        let m = ModelSpec::llama2_70b();
        let slo = SloModel::new(&m);
        let short = slo.reference_latency(&InferenceTask::new(1, 128, 32));
        let long = slo.reference_latency(&InferenceTask::new(1, 128, 128));
        assert!(long > short * 2.0);
    }
}
