//! # HexGen — generative LLM inference over heterogeneous environments
//!
//! A from-scratch reproduction of *HexGen: Generative Inference of Large
//! Language Model over Heterogeneous Environment* (ICML 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: heterogeneous cluster
//!   model, the Table-1 analytic cost model, the two-phase scheduler
//!   (Algorithm-1 DP + genetic search), the discrete-event serving
//!   simulator that drives the paper's evaluation, and a real serving
//!   runtime that executes model stages through a pluggable
//!   [`runtime::ExecutionBackend`] — a pure-Rust reference backend by
//!   default, PJRT-compiled AOT artifacts behind the `pjrt` feature.
//! - **Layer 2** — a JAX transformer expressed as TP-shardable stage
//!   functions, AOT-lowered to HLO text (`python/compile/`).
//! - **Layer 1** — flash-attention-style Pallas kernels inside the Layer-2
//!   stages (`python/compile/kernels/`).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! model once; the `hexgen` binary is self-contained afterwards.
//!
//! See `rust/README.md` for build instructions, cargo features, and the
//! experiment index (Figures 1–7, Tables 3–4).

pub mod cluster;
pub mod coordinator;
pub mod costmodel;
pub mod experiments;
pub mod model;
pub mod parallelism;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod workload;
pub mod util;
