//! `hexgen` — CLI entry point: serve the demo model, run the scheduler,
//! and regenerate every figure/table of the paper's evaluation.

use anyhow::{bail, Result};

use hexgen::cluster;
use hexgen::costmodel::CostModel;
use hexgen::experiments;
use hexgen::model::ModelSpec;
use hexgen::scheduler::GeneticScheduler;
use hexgen::simulator::{simulate, SimConfig, SloModel};
use hexgen::util::cli::Args;
use hexgen::workload::{LengthDist, WorkloadSpec};

const USAGE: &str = "\
hexgen — generative LLM inference over heterogeneous environments
(ICML 2024 reproduction; see rust/README.md)

USAGE: hexgen <command> [options]

Experiments (regenerate the paper's evaluation):
  figure1            §3.1 case study (asymmetric parallelism speedups)
  figure2            §5.2 cost-performance trade-off grid
  figure3            §5.3 vs Petals (swarm parallelism)
  figure4            §5.3 dynamic GPU pool (4 GPUs offline)
  figure5            §5.3 vs HuggingFace-TGI
  figure6            §5.4 scheduler convergence (guided vs random)
  figure7            §5.4 init / random-mutation / HexGen bars
  table3             Appendix B cost-model alignment
  table4             Appendix F scheduled partitions by region
  all                run every experiment in sequence

Serving & tools:
  serve [--listen ADDR] [--prompt <text>] [--plan FILE] [--replicas N]
        [--disagg] [--max-new N] [--artifacts DIR]
        [--spec-draft DIR] [--spec-k K]
        [--fault-plan FILE] [--max-retries N]
                     serve the demo model; --plan boots the replicas from
                     a scheduler --emit-plan file (lowered onto the
                     artifact manifest, with plan cost estimates seeding
                     the router's per-phase speeds and phase roles
                     driving disaggregated prefill/decode serving),
                     otherwise toy presets via --replicas. --disagg makes
                     the toy presets disaggregated: even replicas
                     prefill-only, odd replicas decode-only (needs
                     --replicas >= 2).
                     --listen ADDR (e.g. 127.0.0.1:8080; port 0 picks an
                     ephemeral port) runs a long-lived HTTP/1.1 front-end:
                       POST /v1/completions   {"prompt": ..., "max_new": N,
                                               "stream": true -> SSE tokens}
                       GET  /healthz | /metrics | /v1/plan
                     Without --listen, serves --prompt once and exits.
                     --spec-draft DIR enables speculative decoding with
                     the draft model in DIR (--spec-k proposals per
                     round, default 3); emitted tokens stay identical to
                     plain decoding.
                     --fault-plan FILE injects deterministic backend
                     faults from a JSON plan (see rust/README.md § Fault
                     tolerance) to exercise failover; --max-retries N
                     sets the per-request retry budget (default 2).
  schedule [--cluster NAME] [--emit-plan FILE]
                     run the two-phase scheduler on a cluster preset and
                     print the deployment (presets: homogeneous,
                     full-price, half-price, case-study); --emit-plan
                     writes the chosen deployment as a servable plan JSON
  simulate [--cluster NAME] [--rate R] [--requests N] [--s-out N]
                     schedule + simulate one serving point

Common options:
  --seed N           base RNG seed (default 0x4E586E47)
  --full             paper-scale budgets (slower, tighter estimates)
  --out FILE         dump machine-readable results JSON
  --requests N, --population N, --iterations N   fine-grained budgets
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "figure1" => experiments::figure1::run(&args),
        "figure2" => experiments::figure2::run(&args),
        "figure3" => experiments::figure3::run(&args),
        "figure4" => experiments::figure4::run(&args),
        "figure5" => experiments::figure5::run(&args),
        "figure6" => experiments::figure6::run(&args),
        "figure7" => experiments::figure7::run(&args),
        "table3" => experiments::table3::run(&args),
        "table4" => experiments::table4::run(&args),
        "all" => {
            for (name, f) in [
                ("figure1", experiments::figure1::run as fn(&Args) -> Result<()>),
                ("figure2", experiments::figure2::run),
                ("figure3", experiments::figure3::run),
                ("figure4", experiments::figure4::run),
                ("figure5", experiments::figure5::run),
                ("figure6", experiments::figure6::run),
                ("figure7", experiments::figure7::run),
                ("table3", experiments::table3::run),
                ("table4", experiments::table4::run),
            ] {
                println!("\n════════ {name} ════════\n");
                f(&args)?;
            }
            Ok(())
        }
        "serve" => serve(&args),
        "schedule" => schedule(&args),
        "simulate" => simulate_cmd(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `hexgen help`)"),
    }
}

/// Serve the demo model end-to-end: replica plans from a scheduler
/// `--emit-plan` file (lowered onto the artifact manifest) or from the
/// toy `--replicas` presets.
fn serve(args: &Args) -> Result<()> {
    use hexgen::coordinator::{
        lower_plan, plan_from_strategy, BatchPolicy, FaultPolicy, HexGenService, HttpServer,
        RoutePolicy, ServiceConfig, SpecPolicy, StagePlan,
    };
    use hexgen::parallelism::{DeploymentPlan, PhaseRole};
    use hexgen::runtime::{FaultPlan, Manifest};

    /// Toy replica presets shaped to whatever model the artifacts serve:
    /// even replicas get an asymmetric TP(high)→TP1 split (front-loaded
    /// layers, as the paper's §3.1 case study), odd ones a uniform TP1
    /// pipeline.
    fn toy_plans(m: &Manifest, n: usize) -> Result<Vec<Vec<StagePlan>>> {
        let layers = m.model.layers;
        let tp_hi = m.tp_degrees.iter().copied().max().unwrap_or(1);
        (0..n.max(1))
            .map(|i| {
                if layers >= 2 && i % 2 == 0 {
                    let front = (layers * 2 / 3).clamp(1, layers - 1);
                    plan_from_strategy(&[tp_hi, 1], &[front, layers - front])
                } else if layers >= 2 {
                    let front = layers / 2;
                    plan_from_strategy(&[1, 1], &[front, layers - front])
                } else {
                    plan_from_strategy(&[1], &[layers])
                }
            })
            .collect()
    }

    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    if !dir.join("manifest.json").exists() {
        bail!("artifacts not found in {dir:?}; run `make artifacts` first");
    }
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let (plans, speeds, prefill_speeds, roles) = if let Some(path) = args.get("plan") {
        let plan = DeploymentPlan::load(std::path::Path::new(path))?;
        let lowered = lower_plan(&plan, &manifest)?;
        println!(
            "lowered plan {path} (cluster '{}', model {}) onto served model {}:",
            plan.cluster, plan.model_name, manifest.model.name
        );
        for a in &lowered.adjustments {
            println!("  adjusted: {a}");
        }
        for (i, (p, s)) in lowered.replicas.iter().zip(&lowered.speeds).enumerate() {
            let tps: Vec<String> = p.iter().map(|sp| sp.tp.to_string()).collect();
            let lay: Vec<String> = p.iter().map(|sp| sp.layer_count.to_string()).collect();
            println!(
                "  replica {i}: [{}] layers {} role {} routing speed {s:.3}",
                tps.join(","),
                lay.join("/"),
                lowered.roles.get(i).copied().unwrap_or_default(),
            );
        }
        (lowered.replicas, Some(lowered.speeds), Some(lowered.prefill_speeds), lowered.roles)
    } else {
        let n = args.get_usize("replicas", 2);
        let roles = if args.flag("disagg") {
            if n < 2 {
                bail!("--disagg needs --replicas >= 2 (a prefill and a decode replica)");
            }
            (0..n)
                .map(|i| if i % 2 == 0 { PhaseRole::Prefill } else { PhaseRole::Decode })
                .collect()
        } else {
            Vec::new()
        };
        (toy_plans(&manifest, n)?, None, None, roles)
    };
    let mut faults = FaultPolicy::default();
    if let Some(path) = args.get("fault-plan") {
        faults.plan = Some(FaultPlan::load(std::path::Path::new(path))?);
        println!("fault injection enabled from {path}");
    }
    faults.max_retries = args.get_usize("max-retries", faults.max_retries as usize) as u32;
    println!("starting service with {} replica(s)...", plans.len());
    let service = HexGenService::start(ServiceConfig {
        artifacts_dir: dir,
        backend: Default::default(),
        replicas: plans,
        batch: BatchPolicy::default(),
        route: RoutePolicy::LeastLoaded,
        speeds,
        prefill_speeds,
        roles,
        adapt_speeds: true,
        max_new_tokens: args.get_usize("max-new", 16),
        stop_token: None,
        kv: Default::default(),
        spec: args.get("spec-draft").map(|d| SpecPolicy {
            k: args.get_usize("spec-k", 3),
            draft_model: std::path::PathBuf::from(d),
        }),
        faults,
    })?;

    // Long-running mode: expose the service over HTTP and block.
    if let Some(listen) = args.get("listen") {
        let service = std::sync::Arc::new(service);
        let server = HttpServer::serve(service, listen)?;
        println!("listening on http://{}", server.addr());
        println!("  POST /v1/completions   (\"stream\": true -> SSE token events)");
        println!("  GET  /healthz | /metrics | /v1/plan");
        server.join();
        return Ok(());
    }

    let prompt = args.get_str("prompt", "the quick brown fox jumps over the lazy dog");
    let c = service.generate(&prompt, None)?;
    println!("prompt   : {prompt}");
    if c.truncated {
        println!(
            "           (truncated: only the last {} prompt tokens fit the context)",
            c.prompt_tokens
        );
    }
    println!("tokens   : {:?}", c.tokens);
    println!("text     : {:?}", c.text);
    println!(
        "latency  : {:.1}ms (prefill {:.1}ms, decode {:.1}ms, replica {}, batch {})",
        c.latency * 1e3,
        c.prefill_seconds * 1e3,
        c.decode_seconds * 1e3,
        c.replica,
        c.batch_size
    );
    let comm = service.comm_stats();
    println!(
        "comm     : {} all-reduces ({}), {} stage hand-offs ({})",
        comm.allreduce_ops,
        hexgen::util::fmt_bytes(comm.allreduce_bytes),
        comm.pp_sends,
        hexgen::util::fmt_bytes(comm.pp_bytes),
    );
    println!(
        "kv xfer  : {} prefill->decode segment(s) ({})",
        comm.kv_transfers,
        hexgen::util::fmt_bytes(comm.kv_transfer_bytes),
    );
    println!(
        "roles    : [{}]",
        service.roles().iter().map(|r| r.to_string()).collect::<Vec<_>>().join(","),
    );
    println!(
        "routing  : effective replica speeds {:?}",
        service
            .router_speeds()
            .iter()
            .map(|s| (s * 1e3).round() / 1e3)
            .collect::<Vec<_>>()
    );
    service.shutdown();
    Ok(())
}

/// Run the two-phase scheduler on a preset and print the deployment.
fn schedule(args: &Args) -> Result<()> {
    let name = args.get_str("cluster", "full-price");
    let Some(c) = cluster::preset(&name) else {
        bail!("unknown cluster preset '{name}'");
    };
    let m = ModelSpec::llama2_70b();
    let cfg = experiments::common::ExpConfig::from_args(args);
    let res = GeneticScheduler::new(&c, &m, cfg.ga(0x5C)).run();
    println!(
        "cluster {} (${:.2}/h, {} GPUs) — {} iterations in {:.1}s, est. attainment {:.3}",
        c.name,
        c.budget_per_hour,
        c.devices.len(),
        res.iterations_run,
        res.wall_time,
        res.fitness
    );
    print!("{}", res.deployment.describe(&c));
    if let Some(path) = args.get("emit-plan") {
        let plan = hexgen::parallelism::DeploymentPlan::from_deployment(
            &res.deployment,
            &c,
            &m,
            Some(res.fitness),
        );
        plan.save(std::path::Path::new(path))?;
        println!("wrote deployment plan ({} replicas) to {path}", plan.replicas.len());
    }
    Ok(())
}

/// Schedule + simulate one serving point.
fn simulate_cmd(args: &Args) -> Result<()> {
    let name = args.get_str("cluster", "half-price");
    let Some(c) = cluster::preset(&name) else {
        bail!("unknown cluster preset '{name}'");
    };
    let m = ModelSpec::llama2_70b();
    let cfg = experiments::common::ExpConfig::from_args(args);
    let res = GeneticScheduler::new(&c, &m, cfg.ga(0x51)).run();
    let rate = args.get_f64("rate", 1.0);
    let s_out = args.get_usize("s-out", 32);
    let trace = WorkloadSpec {
        rate,
        num_requests: cfg.requests,
        lengths: LengthDist::LmsysLike { s_out },
        seed: cfg.seed,
    }
    .generate();
    let cm = CostModel::new(&c, &m);
    let out = simulate(&cm, &res.deployment, &trace, &SimConfig::default());
    let slo = SloModel::new(&m);
    println!("{}", res.deployment.describe(&c));
    println!(
        "rate {rate} req/s, {} requests, s_out {s_out}: throughput {:.2} req/s",
        cfg.requests,
        out.throughput()
    );
    for scale in [1.0, 2.0, 5.0, 10.0] {
        println!("  attainment @scale {scale}: {:.3}", out.attainment(&slo, scale));
    }
    if let Some(s) = hexgen::util::stats::Summary::from_samples(
        &out.latencies().iter().copied().filter(|x| x.is_finite()).collect::<Vec<_>>(),
    ) {
        println!(
            "  latency p50 {:.2}s p95 {:.2}s p99 {:.2}s max {:.2}s",
            s.p50, s.p95, s.p99, s.max
        );
    }
    Ok(())
}
