//! The paper's analytic generative-inference cost model (Table 1,
//! Appendix B): computation time (Eq. 4), tensor-parallel communication
//! (Eq. 5), pipeline-parallel communication (Eq. 6) and the per-device
//! memory limit (Eq. 7).
//!
//! All times are seconds, all sizes bytes. Every function takes a concrete
//! set of [`DeviceId`]s so the heterogeneous `max`/`min` over group members
//! in the paper's formulas is evaluated against real per-device capability
//! and real pairwise α/β entries.

pub mod task;

pub use task::InferenceTask;

use crate::cluster::{Cluster, DeviceId};
use crate::model::ModelSpec;

/// Cost evaluator bound to a cluster + model.
#[derive(Debug, Clone, Copy)]
pub struct CostModel<'a> {
    pub cluster: &'a Cluster,
    pub model: &'a ModelSpec,
}

/// Phase selector for split (Table 3) accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
    /// Prefill + decode — the full Table 1 formulation.
    Both,
}

impl<'a> CostModel<'a> {
    pub fn new(cluster: &'a Cluster, model: &'a ModelSpec) -> Self {
        CostModel { cluster, model }
    }

    // ----- Eq. 4: computation ---------------------------------------------

    /// Computation time of `layers` transformer layers on the TP group
    /// `devices` (Eq. 4). `Phase::Both` is the paper's exact formula:
    ///
    /// ```text
    /// max_d (12 H² B s_out / (|d| m_d)) · l  +  max_d (24 b (s_in+s_out) H² / (|d| c_d)) · l
    /// ```
    ///
    /// The split phases are used for Table 3: prefill scans the parameters
    /// once and runs the `s_in` FLOPs; decode scans `s_out` times and runs
    /// the `s_out` FLOPs.
    pub fn comp_cost(
        &self,
        devices: &[DeviceId],
        layers: usize,
        t: &InferenceTask,
        phase: Phase,
    ) -> f64 {
        assert!(!devices.is_empty());
        let h = self.model.hidden as f64;
        let b_type = self.model.btype();
        let tp = devices.len() as f64;
        let l = layers as f64;
        let b = t.batch as f64;

        // Slowest member bounds the BSP superstep.
        let scan_per_pass = devices
            .iter()
            .map(|&d| 12.0 * h * h * b_type / (tp * self.gpu_mem_bw(d)))
            .fold(0.0_f64, f64::max);
        let flops_per_token = devices
            .iter()
            .map(|&d| 24.0 * b * h * h / (tp * self.gpu_flops(d)))
            .fold(0.0_f64, f64::max);

        let (scan_passes, flop_tokens) = match phase {
            // Paper's Table-1 expression: the s_out parameter scans
            // dominate; prefill FLOPs scale with s_in.
            Phase::Both => (t.s_out as f64, (t.s_in + t.s_out) as f64),
            Phase::Prefill => (1.0, t.s_in as f64),
            Phase::Decode => (t.s_out as f64, t.s_out as f64),
        };
        scan_per_pass * scan_passes * l + flops_per_token * flop_tokens * l
    }

    // ----- Eq. 5: tensor-parallel communication ----------------------------

    /// TP communication time of `layers` layers on group `devices` (Eq. 5):
    /// 2 AllReduce/layer, each modeled as ReduceScatter+AllGather under BSP,
    /// ⇒ 4 supersteps/layer; each superstep costs the *max* over members of
    /// the sum of its point-to-point chunk sends.
    pub fn comm_tp_cost(
        &self,
        devices: &[DeviceId],
        layers: usize,
        t: &InferenceTask,
        phase: Phase,
    ) -> f64 {
        if devices.len() <= 1 {
            return 0.0;
        }
        let h = self.model.hidden as f64;
        let b_type = self.model.btype();
        let tp = devices.len() as f64;
        let l = layers as f64;
        let b = t.batch as f64;

        // max_d Σ_{d'≠d} (α_{dd'} + bytes/(|d|·β_{dd'}))
        let superstep = |bytes_full: f64| -> f64 {
            devices
                .iter()
                .map(|&d| {
                    devices
                        .iter()
                        .filter(|&&d2| d2 != d)
                        .map(|&d2| {
                            self.cluster.comm.alpha(d, d2)
                                + bytes_full / (tp * self.cluster.comm.beta(d, d2))
                        })
                        .sum::<f64>()
                })
                .fold(0.0_f64, f64::max)
        };

        let prefill = superstep(b * t.s_in as f64 * h * b_type) * 4.0 * l;
        let decode = superstep(b * h * b_type) * 4.0 * t.s_out as f64 * l;
        match phase {
            Phase::Prefill => prefill,
            Phase::Decode => decode,
            Phase::Both => prefill + decode,
        }
    }

    // ----- Eq. 6: pipeline-parallel communication ---------------------------

    /// PP activation hand-off time between stage `from` and stage `to`
    /// (Eq. 6): routed over the *fastest* link between the two groups
    /// (the leader-GPU selection of §3.2).
    pub fn comm_pp_cost(
        &self,
        from: &[DeviceId],
        to: &[DeviceId],
        t: &InferenceTask,
        phase: Phase,
    ) -> f64 {
        let h = self.model.hidden as f64;
        let b_type = self.model.btype();
        let b = t.batch as f64;

        let best = |bytes: f64| -> f64 {
            let mut best = f64::INFINITY;
            for &d in from {
                for &d2 in to {
                    let c = self.cluster.comm.alpha(d, d2) + bytes / self.cluster.comm.beta(d, d2);
                    if c < best {
                        best = c;
                    }
                }
            }
            best
        };

        let prefill = best(b * t.s_in as f64 * h * b_type);
        let decode = best(b * h * b_type) * t.s_out as f64;
        match phase {
            Phase::Prefill => prefill,
            Phase::Decode => decode,
            Phase::Both => prefill + decode,
        }
    }

    // ----- Eq. 7: memory limit ---------------------------------------------

    /// Per-device memory footprint of serving `layers` layers with TP
    /// degree `tp` (Eq. 7): parameter shard + KV-cache shard + 4 reusable
    /// activation buffers.
    pub fn mem_bytes(&self, tp: usize, layers: usize, t: &InferenceTask) -> f64 {
        assert!(tp > 0);
        let h = self.model.hidden as f64;
        let b_type = self.model.btype();
        let tp = tp as f64;
        let l = layers as f64;
        let b = t.batch as f64;
        let s_total = t.total_len() as f64;

        let params = 12.0 * h * h * b_type / tp;
        let kv = 2.0 * b * s_total * h * b_type / tp;
        let act = 4.0 * b * s_total * h * b_type;
        (params + kv) * l + act
    }

    /// True when every device in the TP group can hold its shard.
    pub fn mem_ok(&self, devices: &[DeviceId], layers: usize, t: &InferenceTask) -> bool {
        let need = self.mem_bytes(devices.len(), layers, t);
        devices
            .iter()
            .all(|&d| need <= self.cluster.devices[d].gpu.spec().memory_bytes)
    }

    // ----- Eq. 2: whole-pipeline cost ---------------------------------------

    /// End-to-end inference cost of one pipeline (Eq. 2): per-stage compute
    /// + per-stage TP comm + inter-stage PP comm. Returns `None` when any
    /// stage violates its memory limit.
    pub fn pipeline_cost(
        &self,
        stages: &[(Vec<DeviceId>, usize)],
        t: &InferenceTask,
        phase: Phase,
    ) -> Option<f64> {
        assert!(!stages.is_empty());
        let mut total = 0.0;
        for (j, (devs, layers)) in stages.iter().enumerate() {
            if !self.mem_ok(devs, *layers, t) {
                return None;
            }
            total += self.comp_cost(devs, *layers, t, phase);
            total += self.comm_tp_cost(devs, *layers, t, phase);
            if j + 1 < stages.len() {
                total += self.comm_pp_cost(devs, &stages[j + 1].0, t, phase);
            }
        }
        Some(total)
    }

    /// Stage-local cost (compute + TP comm), the DP's per-stage term.
    pub fn stage_cost(
        &self,
        devices: &[DeviceId],
        layers: usize,
        t: &InferenceTask,
        phase: Phase,
    ) -> Option<f64> {
        if !self.mem_ok(devices, layers, t) {
            return None;
        }
        Some(self.comp_cost(devices, layers, t, phase) + self.comm_tp_cost(devices, layers, t, phase))
    }

    // ----- helpers -----------------------------------------------------------

    fn gpu_mem_bw(&self, d: DeviceId) -> f64 {
        self.cluster.devices[d].gpu.spec().memory_bandwidth
    }

    fn gpu_flops(&self, d: DeviceId) -> f64 {
        self.cluster.devices[d].gpu.spec().peak_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    fn fixture() -> (Cluster, ModelSpec) {
        (cluster::homogeneous_a100(), ModelSpec::llama2_70b())
    }

    #[test]
    fn comp_cost_hand_computed() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        // Single A100, one layer. scan = 12·8192²·2 / 1555e9 per pass;
        // flops = 24·1·8192² / 312e12 per token.
        let scan = 12.0 * 8192.0f64.powi(2) * 2.0 / 1555e9;
        let flop = 24.0 * 8192.0f64.powi(2) / 312e12;
        let expect = scan * 64.0 + flop * 192.0;
        let got = cm.comp_cost(&[0], 1, &t, Phase::Both);
        assert!((got - expect).abs() / expect < 1e-12, "{got} vs {expect}");
    }

    #[test]
    fn comp_cost_scales_with_tp() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        let c1 = cm.comp_cost(&[0], 80, &t, Phase::Both);
        let c4 = cm.comp_cost(&[0, 1, 2, 3], 80, &t, Phase::Both);
        assert!((c1 / c4 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn comp_cost_bounded_by_slowest_member() {
        // heterogeneous TP group: A6000 + A4000 — cost set by A4000
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        // device 0-3 = A6000, 6-7 = A4000
        let mixed = cm.comp_cost(&[0, 6], 1, &t, Phase::Both);
        let slow_pair = cm.comp_cost(&[6, 7], 1, &t, Phase::Both);
        assert!((mixed - slow_pair).abs() / slow_pair < 1e-12);
    }

    #[test]
    fn phases_sum_to_both_for_comm() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(4, 256, 32);
        let g: Vec<usize> = (0..4).collect();
        let pre = cm.comm_tp_cost(&g, 10, &t, Phase::Prefill);
        let dec = cm.comm_tp_cost(&g, 10, &t, Phase::Decode);
        let both = cm.comm_tp_cost(&g, 10, &t, Phase::Both);
        assert!((pre + dec - both).abs() < 1e-12);
        let pp_pre = cm.comm_pp_cost(&[0], &[8], &t, Phase::Prefill);
        let pp_dec = cm.comm_pp_cost(&[0], &[8], &t, Phase::Decode);
        let pp_both = cm.comm_pp_cost(&[0], &[8], &t, Phase::Both);
        assert!((pp_pre + pp_dec - pp_both).abs() < 1e-12);
    }

    #[test]
    fn tp_comm_zero_for_singleton() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        assert_eq!(cm.comm_tp_cost(&[3], 80, &t, Phase::Both), 0.0);
    }

    #[test]
    fn tp_comm_grows_across_machines() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        // TP within machine 0 (devices 0..8) vs TP spanning machines (4+4)
        let local: Vec<usize> = (0..4).collect();
        let spanning: Vec<usize> = vec![0, 1, 8, 9];
        let c_local = cm.comm_tp_cost(&local, 40, &t, Phase::Both);
        let c_span = cm.comm_tp_cost(&spanning, 40, &t, Phase::Both);
        assert!(c_span > c_local * 2.0, "{c_span} vs {c_local}");
    }

    #[test]
    fn pp_comm_uses_fastest_link() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        // stage A on machine0, stage B straddling machine0+machine1:
        // fastest link is intra-machine.
        let via_mixed = cm.comm_pp_cost(&[0, 1], &[2, 8], &t, Phase::Both);
        let local_only = cm.comm_pp_cost(&[0, 1], &[2, 3], &t, Phase::Both);
        assert!((via_mixed - local_only).abs() < 1e-12);
    }

    #[test]
    fn memory_eq7_hand_computed() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        let h = 8192.0f64;
        let expect = (12.0 * h * h * 2.0 / 4.0 + 2.0 * 192.0 * h * 2.0 / 4.0) * 20.0
            + 4.0 * 192.0 * h * 2.0;
        let got = cm.mem_bytes(4, 20, &t);
        assert!((got - expect).abs() < 1.0);
    }

    #[test]
    fn oom_detection_matches_case_study() {
        // §3.1: pure TP=8 over the mixed pool OOMs on A4000-16G;
        // naive PP=8 (10 layers/GPU) OOMs on A4000 too.
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let all: Vec<usize> = (0..8).collect();
        // TP=8 over all 80 layers: per-A4000 shard too big
        assert!(!cm.mem_ok(&all, 80, &t));
        // PP=8: each device alone with 10 layers — A4000 (dev 6,7) OOMs
        assert!(!cm.mem_ok(&[6], 10, &t));
        // but the HexGen layout fits: A6000×4 with 48 layers,
        // A5000×2 with 20, A4000×2 with 12
        assert!(cm.mem_ok(&[0, 1, 2, 3], 48, &t));
        assert!(cm.mem_ok(&[4, 5], 20, &t));
        assert!(cm.mem_ok(&[6, 7], 12, &t));
    }

    #[test]
    fn pipeline_cost_none_on_oom() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let bad = vec![(vec![6usize], 40), (vec![7usize], 40)];
        assert!(cm.pipeline_cost(&bad, &t, Phase::Both).is_none());
        let good = vec![
            (vec![0usize, 1, 2, 3], 48),
            (vec![4usize, 5], 20),
            (vec![6usize, 7], 12),
        ];
        let cost = cm.pipeline_cost(&good, &t, Phase::Both);
        assert!(cost.is_some() && cost.unwrap() > 0.0);
    }

    #[test]
    fn pipeline_cost_is_sum_of_parts() {
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        let stages = vec![(vec![0usize, 1], 40), (vec![8usize, 9], 40)];
        let total = cm.pipeline_cost(&stages, &t, Phase::Both).unwrap();
        let manual = cm.comp_cost(&[0, 1], 40, &t, Phase::Both)
            + cm.comm_tp_cost(&[0, 1], 40, &t, Phase::Both)
            + cm.comm_pp_cost(&[0, 1], &[8, 9], &t, Phase::Both)
            + cm.comp_cost(&[8, 9], 40, &t, Phase::Both)
            + cm.comm_tp_cost(&[8, 9], 40, &t, Phase::Both);
        assert!((total - manual).abs() < 1e-12);
    }

    #[test]
    fn a100_tp8_latency_plausible() {
        // Sanity: Table 3 benchmarks ~2.7s prefill + ~2.4s decode for
        // 256/32 at TP=8 on A100s (b=32 workload in their setup). With our
        // model at b=8, magnitudes should land in the right decade.
        let (c, m) = fixture();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(8, 256, 32);
        let g: Vec<usize> = (0..8).collect();
        let total = cm.pipeline_cost(&[(g, 80)], &t, Phase::Both).unwrap();
        assert!(total > 0.05 && total < 20.0, "total={total}");
    }
}
