//! Inference task description (paper §4.1: `b_t`, `s_in`, `s_out`).

/// One generative-inference task: a (possibly batched) request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct InferenceTask {
    /// Batch size `b_t`.
    pub batch: usize,
    /// Prompt length `s_in` (tokens).
    pub s_in: usize,
    /// Output length `s_out` (tokens).
    pub s_out: usize,
}

impl InferenceTask {
    pub fn new(batch: usize, s_in: usize, s_out: usize) -> InferenceTask {
        assert!(batch > 0 && s_in > 0 && s_out > 0);
        InferenceTask { batch, s_in, s_out }
    }

    /// Total sequence length `s_in + s_out`.
    pub fn total_len(&self) -> usize {
        self.s_in + self.s_out
    }

    /// The paper's case-study request (§3.1): s_in=128, s_out=64, b=1.
    pub fn case_study() -> InferenceTask {
        InferenceTask::new(1, 128, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction() {
        let t = InferenceTask::new(4, 128, 32);
        assert_eq!(t.total_len(), 160);
    }

    #[test]
    #[should_panic]
    fn zero_batch_rejected() {
        InferenceTask::new(0, 1, 1);
    }
}
