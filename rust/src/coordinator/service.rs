//! Threaded serving front-end: the real (non-simulated) HexGen service.
//!
//! One worker thread per replica, each owning a thread-confined
//! [`PipelineExecutor`] over its own [`ExecutionBackend`] instance
//! (backends need not be `Send`; PJRT handles are not). The router
//! assigns requests to replicas; each worker runs a **continuous
//! batching** admission loop over a persistent
//! [`DecodeSession`](super::pipeline::DecodeSession): at every
//! decode-step boundary it retires rows that hit their own `max_new` (or
//! stop token), frees their KV-cache slots, and prefills queued requests
//! into the free slots — so a late request joins the in-flight batch
//! instead of waiting behind it.
//!
//! [`ExecutionBackend`]: crate::runtime::ExecutionBackend

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::{make_backend, tokenizer, BackendKind, Manifest, WeightStore};

use super::batcher::{AdmissionQueue, BatchPolicy};
use super::collective::CommStats;

use super::pipeline::{PipelineExecutor, SlotRequest, StagePlan};
use super::router::{RoutePolicy, Router};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts_dir: PathBuf,
    /// Execution backend each replica worker constructs for itself.
    pub backend: BackendKind,
    /// One stage plan per replica.
    pub replicas: Vec<Vec<StagePlan>>,
    pub batch: BatchPolicy,
    pub route: RoutePolicy,
    /// Optional per-replica routing speed seeds (relative; e.g. the
    /// normalized 1/cost estimates of a lowered deployment plan —
    /// [`super::lowering::LoweredPlan::speeds`]). Length must match
    /// `replicas`; `None` routes every replica at weight 1.0.
    pub speeds: Option<Vec<f64>>,
    /// Keep router speeds fresh at runtime from an EWMA of each
    /// replica's measured decode throughput
    /// ([`Router::observe_rate`]).
    pub adapt_speeds: bool,
    /// Default generation length (≤ max_seq − prompt_len).
    pub max_new_tokens: usize,
    /// Optional stop token: rows retire early when they emit it.
    pub stop_token: Option<i32>,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Completion {
    pub text: String,
    pub tokens: Vec<i32>,
    /// End-to-end latency (submit → response), seconds.
    pub latency: f64,
    /// Queueing delay before this request was admitted into a slot,
    /// seconds.
    pub queued: f64,
    pub replica: usize,
    /// Rows in flight on the replica when this request was admitted
    /// (including itself).
    pub batch_size: usize,
    /// Wall time of this request's prefill pass, seconds.
    pub prefill_seconds: f64,
    /// Wall time from this request's prefill to its retirement, seconds.
    pub decode_seconds: f64,
    /// Decode iterations this request participated in
    /// (`tokens.len() - 1`; the first token comes from prefill).
    pub decode_steps: usize,
}

struct WorkItem {
    prompt_tokens: Vec<i32>,
    max_new: usize,
    submitted: Instant,
    reply: Sender<Result<Completion, String>>,
}

/// A request occupying a decode-session slot.
struct ActiveItem {
    item: WorkItem,
    admitted: Instant,
    /// Rows in flight when this request was admitted (incl. itself).
    cohort: usize,
    prefill_seconds: f64,
    decode_start: Instant,
}

/// Handle to a running service.
pub struct HexGenService {
    router: Arc<Router>,
    queues: Vec<Sender<WorkItem>>,
    workers: Vec<JoinHandle<()>>,
    manifest: Manifest,
    cfg: ServiceConfig,
    comm_rx: Receiver<CommStats>,
}

impl HexGenService {
    /// Start worker threads (compiling each replica's executables).
    pub fn start(cfg: ServiceConfig) -> Result<HexGenService> {
        if cfg.replicas.is_empty() {
            bail!("no replicas configured");
        }
        let manifest = Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
        let weights = Arc::new(WeightStore::load(&cfg.artifacts_dir.join("weights.bin"))?);
        let router = Arc::new(Router::new(cfg.route, cfg.replicas.len()));
        if let Some(speeds) = &cfg.speeds {
            if speeds.len() != cfg.replicas.len() {
                bail!("{} speed seeds for {} replicas", speeds.len(), cfg.replicas.len());
            }
            if speeds.iter().any(|&s| !s.is_finite() || s <= 0.0) {
                bail!("replica speed seeds must be positive and finite, got {speeds:?}");
            }
            router.set_speeds(speeds.clone());
        }

        let (comm_tx, comm_rx) = channel::<CommStats>();
        let mut queues = Vec::with_capacity(cfg.replicas.len());
        let mut workers = Vec::with_capacity(cfg.replicas.len());
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        for (rid, plan) in cfg.replicas.iter().enumerate() {
            let (tx, rx) = channel::<WorkItem>();
            queues.push(tx);
            let plan = plan.clone();
            let dir = cfg.artifacts_dir.clone();
            let manifest = manifest.clone();
            let weights = weights.clone();
            let batch = cfg.batch;
            let backend = cfg.backend;
            let stop_token = cfg.stop_token;
            let adapt_speeds = cfg.adapt_speeds;
            let router = router.clone();
            let comm_tx = comm_tx.clone();
            let ready_tx = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(
                    rid, backend, dir, manifest, weights, plan, batch, stop_token, adapt_speeds,
                    rx, router, comm_tx, ready_tx,
                )
            }));
        }
        // Wait until every replica compiled its pipeline (or failed).
        for _ in 0..cfg.replicas.len() {
            ready_rx
                .recv()
                .context("worker died during startup")?
                .map_err(|e| anyhow::anyhow!("replica startup failed: {e}"))?;
        }
        Ok(HexGenService { router, queues, workers, manifest, cfg, comm_rx })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn replicas(&self) -> usize {
        self.queues.len()
    }

    /// Effective per-replica routing speeds (plan seeds, overridden by
    /// measured decode-throughput EWMAs as replicas report in).
    pub fn router_speeds(&self) -> Vec<f64> {
        self.router.speeds()
    }

    /// Submit a prompt; returns a receiver for the completion. If the
    /// routed replica is dead (its queue hung up), the router's load
    /// count is released and the request re-routed to a live replica.
    pub fn submit(&self, prompt: &str, max_new: Option<usize>) -> Receiver<Result<Completion, String>> {
        let (reply_tx, reply_rx) = channel();
        let tokens = tokenizer::encode(prompt, self.manifest.model.prompt_len);
        let mut item = WorkItem {
            prompt_tokens: tokens,
            max_new: max_new.unwrap_or(self.cfg.max_new_tokens),
            submitted: Instant::now(),
            reply: reply_tx,
        };
        // Reject invalid limits here, per request — admission batches
        // several requests into one prefill, and one bad request must not
        // fail its co-batched neighbours.
        if item.max_new == 0 {
            let _ = item.reply.send(Err("max_new must be >= 1".to_string()));
            return reply_rx;
        }
        let mut dead: Vec<usize> = Vec::new();
        loop {
            let Some(replica) = self.router.route_excluding(&dead) else {
                let _ = item.reply.send(Err("all replicas are down".to_string()));
                return reply_rx;
            };
            match self.queues[replica].send(item) {
                Ok(()) => return reply_rx,
                Err(SendError(returned)) => {
                    // The worker hung up: release the routed load count so
                    // the policy stops charging the dead replica, then try
                    // the remaining ones.
                    self.router.complete(replica);
                    dead.push(replica);
                    item = returned;
                }
            }
        }
    }

    /// Submit and block for the completion.
    pub fn generate(&self, prompt: &str, max_new: Option<usize>) -> Result<Completion> {
        let rx = self.submit(prompt, max_new);
        rx.recv()
            .context("service dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Drain accumulated communication stats from all workers.
    pub fn comm_stats(&self) -> CommStats {
        let mut total = CommStats::default();
        while let Ok(s) = self.comm_rx.try_recv() {
            total.merge(&s);
        }
        total
    }

    /// Shut down: close queues and join workers.
    pub fn shutdown(self) {
        drop(self.queues);
        drop(self.comm_rx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Largest artifact bucket not exceeding `max_batch` (the session's slot
/// count); falls back to the smallest bucket when `max_batch` is below
/// them all.
fn session_bucket(buckets: &[usize], max_batch: usize) -> usize {
    buckets
        .iter()
        .copied()
        .filter(|&b| b <= max_batch.max(1))
        .max()
        .or_else(|| buckets.iter().copied().min())
        .unwrap_or(1)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rid: usize,
    backend: BackendKind,
    dir: PathBuf,
    manifest: Manifest,
    weights: Arc<WeightStore>,
    plan: Vec<StagePlan>,
    batch: BatchPolicy,
    stop_token: Option<i32>,
    adapt_speeds: bool,
    rx: Receiver<WorkItem>,
    router: Arc<Router>,
    comm_tx: Sender<CommStats>,
    ready_tx: Sender<Result<(), String>>,
) {
    // Thread-confined backend instance (backends need not be Send).
    let exec = match make_backend(backend, &dir, manifest, weights)
        .and_then(|be| PipelineExecutor::with_backend(be, plan))
    {
        Ok(e) => e,
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return;
        }
    };
    let bucket = session_bucket(&exec.manifest().batch_buckets, batch.max_batch);
    let mut session = match exec.new_session(bucket) {
        Ok(s) => {
            let _ = ready_tx.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return;
        }
    };
    // Continuous admission co-batches rows at different cache depths,
    // which needs per-row decode positions; backends bound to the
    // scalar-position AOT artifact signature degrade to
    // run-to-completion batching instead of failing mid-step.
    let continuous = batch.continuous && exec.backend().supports_rowwise_decode_positions();
    if batch.continuous && !continuous {
        crate::log_warn!(
            "replica {rid}: backend {} lacks per-row decode positions; \
             falling back to run-to-completion batching",
            exec.backend().name()
        );
    }
    crate::log_info!(
        "replica {rid} ready: backend {} strategy {} ({bucket} slots, {})",
        exec.backend().name(),
        exec.strategy_string(),
        if continuous { "continuous batching" } else { "run-to-completion batching" },
    );

    let mut queue = AdmissionQueue::new(rx);
    let mut active: Vec<Option<ActiveItem>> = (0..bucket).map(|_| None).collect();

    let fail = |active_item: ActiveItem, msg: &str| {
        let _ = active_item.item.reply.send(Err(msg.to_string()));
        router.complete(rid);
    };
    let deliver = |active_item: ActiveItem, tokens: Vec<i32>| {
        let completion = Completion {
            text: tokenizer::decode(&tokens),
            latency: active_item.item.submitted.elapsed().as_secs_f64(),
            queued: (active_item.admitted - active_item.item.submitted).as_secs_f64(),
            replica: rid,
            batch_size: active_item.cohort,
            prefill_seconds: active_item.prefill_seconds,
            decode_seconds: active_item.decode_start.elapsed().as_secs_f64(),
            decode_steps: tokens.len().saturating_sub(1),
            tokens,
        };
        let _ = active_item.item.reply.send(Ok(completion));
        router.complete(rid);
    };

    loop {
        // ---- block when idle, otherwise just sweep the channel --------
        if session.active() == 0 && !queue.wait() {
            break; // shutdown: channel closed and drained, nothing in flight
        }

        // ---- admission at a step boundary -----------------------------
        // In run-to-completion mode slots only open once the whole batch
        // retired; continuous mode admits into any freed slot.
        let free = session.free_slots();
        let avail = if continuous || session.active() == 0 { free.len() } else { 0 };
        let admitted = queue.admit(avail, session.active() == 0, &batch);
        if !admitted.is_empty() {
            let now = Instant::now();
            let cohort = session.active() + admitted.len();
            let mut reqs = Vec::with_capacity(admitted.len());
            let mut slots_used = Vec::with_capacity(admitted.len());
            for (item, &slot) in admitted.into_iter().zip(free.iter()) {
                reqs.push((
                    slot,
                    SlotRequest {
                        prompt: item.prompt_tokens.clone(),
                        max_new: item.max_new,
                        stop: stop_token,
                    },
                ));
                active[slot] = Some(ActiveItem {
                    item,
                    admitted: now,
                    cohort,
                    prefill_seconds: 0.0,
                    decode_start: now,
                });
                slots_used.push(slot);
            }
            let t0 = Instant::now();
            match session.prefill_into_slots(reqs) {
                Ok(finished) => {
                    let pf = t0.elapsed().as_secs_f64();
                    let end = Instant::now();
                    for &slot in &slots_used {
                        if let Some(a) = active[slot].as_mut() {
                            a.prefill_seconds = pf;
                            a.decode_start = end;
                        }
                    }
                    for (slot, tokens) in finished {
                        if let Some(a) = active[slot].take() {
                            deliver(a, tokens);
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("replica {rid} prefill failed: {e:#}");
                    crate::log_error!("{msg}");
                    for slot in slots_used {
                        if let Some(a) = active[slot].take() {
                            fail(a, &msg);
                        }
                    }
                }
            }
        }

        // ---- one decode iteration for every in-flight row -------------
        if session.active() > 0 {
            let rows = session.active();
            let t0 = Instant::now();
            match session.decode_step() {
                Ok(finished) => {
                    if adapt_speeds {
                        // One token per active row per iteration: fold the
                        // measured decode throughput into the router's
                        // per-replica speed EWMA.
                        let dt = t0.elapsed().as_secs_f64();
                        if dt > 0.0 {
                            router.observe_rate(rid, rows as f64 / dt);
                        }
                    }
                    for (slot, tokens) in finished {
                        if let Some(a) = active[slot].take() {
                            deliver(a, tokens);
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("replica {rid} decode failed: {e:#}");
                    crate::log_error!("{msg}");
                    for slot_item in active.iter_mut() {
                        if let Some(a) = slot_item.take() {
                            fail(a, &msg);
                        }
                    }
                    // The session's slot state may be inconsistent after a
                    // mid-step failure: start from a fresh one.
                    session = match exec.new_session(bucket) {
                        Ok(s) => s,
                        Err(_) => return,
                    };
                }
            }
        }

        let comm = session.take_comm();
        if comm != CommStats::default() {
            let _ = comm_tx.send(comm);
        }
    }
}

/// Convenience: wait on many submissions.
pub fn collect_all(
    rxs: Vec<Receiver<Result<Completion, String>>>,
    timeout: Duration,
) -> Vec<Result<Completion, String>> {
    rxs.into_iter()
        .map(|rx| {
            rx.recv_timeout(timeout)
                .unwrap_or_else(|e| Err(format!("timeout: {e}")))
        })
        .collect()
}
