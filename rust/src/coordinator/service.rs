//! Threaded serving front-end: the real (non-simulated) HexGen service.
//!
//! One worker thread per replica, each owning a thread-confined
//! [`PipelineExecutor`] over its own [`ExecutionBackend`] instance
//! (backends need not be `Send`; PJRT handles are not). The router
//! assigns requests to replicas; each worker runs a **continuous
//! batching** admission loop over a persistent
//! [`DecodeSession`](super::pipeline::DecodeSession): at every
//! decode-step boundary it retires rows that hit their own `max_new` (or
//! stop token), frees their KV blocks, honours cancellations
//! ([`RequestHandle::cancel`] / handle drop), and prefills queued
//! requests into the free slots — so a late request joins the in-flight
//! batch instead of waiting behind it.
//!
//! The public surface is the request-lifecycle API of [`super::api`]:
//! [`HexGenService::submit`] takes a [`GenRequest`] and returns a
//! [`RequestHandle`] streaming [`RequestEvent`]s (per-token streaming,
//! typed [`ServiceError`] failures, cancellation). The blocking
//! [`HexGenService::generate`] is a thin wrapper that drains the stream.
//!
//! **Disaggregated prefill/decode.** When [`ServiceConfig::roles`]
//! assigns non-hybrid phase roles, the request lifecycle splits:
//! `submit` routes among prefill-capable replicas
//! ([`Router::route_phase`]); a prefill-only worker admits and prefills
//! the request, streams its first token, then exports the populated KV
//! rows as a [`KvSegment`], frees the slot, and hands the segment to a
//! decode-capable replica priced by decode-side speeds, where it is
//! imported into a fresh slot and decoded to completion. All-hybrid
//! deployments (the default) take exactly the fused path below —
//! byte-for-byte the same admission, routing, and decode flow as before
//! roles existed.
//!
//! **Fault tolerance.** A replica fault mid-request no longer fails the
//! row: the worker emits [`RequestEvent::Retrying`], releases its router
//! count, and hands the request to a central failover dispatcher thread,
//! which re-routes it to a healthy replica after an exponential backoff
//! — up to [`FaultPolicy::max_retries`] times before the request fails
//! with `ReplicaFailed`. Retries re-prefill the *original* prompt
//! (greedy decoding makes the token stream deterministic) and replay the
//! already-streamed tokens silently, so the client-visible stream
//! continues byte-identically where it left off. Faults also feed the
//! router's per-replica circuit breaker ([`Router::report_fault`]):
//! repeatedly faulting replicas are quarantined out of routing until a
//! timed half-open probe readmits them. Per-request deadlines
//! ([`GenRequest::deadline_ms`]) are enforced here, at every
//! admission/decode-step boundary next to the cancel flag, so an expired
//! request frees its KV blocks instead of burning decode steps. Faults
//! themselves are injectable deterministically via
//! [`FaultPolicy::plan`] ([`FaultPlan`]).
//!
//! [`ExecutionBackend`]: crate::runtime::ExecutionBackend

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, SendError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::parallelism::PhaseRole;
use crate::runtime::{
    make_backend, make_fault_backend, tokenizer, BackendKind, FaultPlan, KvPolicy, Manifest,
    Utf8Stream, WeightStore,
};
use crate::util::sync::{locks, OrderedMutex};

use super::api::{
    CancelFlag, Completion, GenRequest, RequestEvent, RequestHandle, RequestId, ServiceError,
};
use super::batcher::{AdmissionQueue, BatchPolicy, WaitOutcome};
use super::collective::CommStats;

use super::pipeline::{
    plan_from_strategy, DecodeSession, KvSegment, PipelineExecutor, SlotRequest, StagePlan,
    StepOutcome,
};
use super::router::{BreakerPolicy, ReplicaHealth, RoutePolicy, Router, ServePhase};
use super::speculative::{SpecPolicy, SpecStats, SpeculativeSession};

/// How often an idle worker wakes from its request-channel wait to sweep
/// cancelled requests out of its queue.
const CANCEL_SWEEP_INTERVAL: Duration = Duration::from_millis(25);

/// Fault-tolerance policy: optional deterministic fault injection plus
/// the retry and circuit-breaker knobs governing automatic failover.
#[derive(Debug, Clone)]
pub struct FaultPolicy {
    /// Deterministic fault-injection plan every replica wraps its
    /// backend in ([`FaultPlan`]); `None` (the default) injects nothing.
    pub plan: Option<FaultPlan>,
    /// Per-request retry budget: a request whose replica faults
    /// mid-flight is re-routed up to this many times before it fails
    /// with [`ServiceError::ReplicaFailed`]. `0` disables failover.
    pub max_retries: u32,
    /// Base delay before re-dispatching a retried request; attempt `n`
    /// waits `retry_backoff * 2^(n-1)`.
    pub retry_backoff: Duration,
    /// Router circuit-breaker thresholds ([`Router::report_fault`]).
    pub breaker: BreakerPolicy,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            plan: None,
            max_retries: 2,
            retry_backoff: Duration::from_millis(20),
            breaker: BreakerPolicy::default(),
        }
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts_dir: PathBuf,
    /// Execution backend each replica worker constructs for itself.
    pub backend: BackendKind,
    /// One stage plan per replica.
    pub replicas: Vec<Vec<StagePlan>>,
    pub batch: BatchPolicy,
    pub route: RoutePolicy,
    /// Optional per-replica routing speed seeds (relative; e.g. the
    /// normalized 1/cost estimates of a lowered deployment plan —
    /// [`super::lowering::LoweredPlan::speeds`]). Length must match
    /// `replicas`; `None` routes every replica at weight 1.0.
    pub speeds: Option<Vec<f64>>,
    /// Optional per-replica **prefill-side** routing speed seeds
    /// ([`super::lowering::LoweredPlan::prefill_speeds`]). When `None`,
    /// the prefill side is seeded from `speeds` (the fused estimate).
    pub prefill_speeds: Option<Vec<f64>>,
    /// Phase role per replica for disaggregated prefill/decode serving.
    /// Empty means all-hybrid — every replica runs the fused
    /// prefill+decode path, exactly as before roles existed. When
    /// non-empty it must match `replicas` in length, contain at least
    /// one prefill-capable and one decode-capable replica, and requests
    /// flow prefill-replica → KV hand-off → decode-replica.
    pub roles: Vec<PhaseRole>,
    /// Keep router speeds fresh at runtime from an EWMA of each
    /// replica's measured decode throughput
    /// ([`Router::observe_rate`]), and of its measured prefill
    /// throughput on the prefill side.
    pub adapt_speeds: bool,
    /// Default generation length (≤ max_seq − prompt_len).
    pub max_new_tokens: usize,
    /// Default stop token: rows retire early when they emit it
    /// (overridable per request via [`GenRequest::stop`]).
    pub stop_token: Option<i32>,
    /// Paged-KV sizing for each replica's decode session (block
    /// granularity and pool capacity); the default sizes the pool to
    /// hold every slot at full depth.
    pub kv: KvPolicy,
    /// Opt-in speculative decoding: every replica pairs its session with
    /// a draft-model session ([`SpeculativeSession`]) proposing
    /// [`SpecPolicy::k`] tokens per round, verified by the replica's own
    /// model in one batched forward. Emitted streams stay token-identical
    /// to plain decoding; only the per-token cost changes. `None` (the
    /// default) serves exactly as before. Not yet compatible with
    /// disaggregated phase `roles`.
    pub spec: Option<SpecPolicy>,
    /// Fault tolerance: injection plan, retry budget and backoff, and
    /// circuit-breaker thresholds.
    pub faults: FaultPolicy,
}

/// Monotonic lifetime counters of a running service (`GET /metrics`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Total generated tokens across completed requests.
    pub tokens_out: u64,
    /// KV block capacity summed over every replica's pool.
    pub kv_blocks_total: u64,
    /// KV blocks currently referenced by in-flight rows across all
    /// replicas (a gauge, not a monotonic counter).
    pub kv_blocks_used: u64,
    /// Prefix-cache chunk hits (prompt blocks shared instead of
    /// recomputed) across all replicas.
    pub prefix_cache_hits: u64,
    /// Prefix-cache chunk misses across all replicas.
    pub prefix_cache_misses: u64,
    /// Admissions served without a prefill forward pass (full-prefix
    /// cache hit with a memoized first token) across all replicas.
    pub prefill_skips: u64,
    /// Speculative propose/verify rounds completed across all replicas
    /// (0 unless [`ServiceConfig::spec`] is set).
    pub spec_rounds: u64,
    /// Draft tokens proposed across all speculative rounds.
    pub spec_proposed: u64,
    /// Proposed tokens the target model accepted into the stream.
    pub spec_accepted: u64,
    /// Failover retries dispatched (one per `Retrying` event).
    pub retries: u64,
    /// Requests that completed after at least one failover retry.
    pub failovers: u64,
    /// Requests lost to replica failure: terminal `ReplicaFailed` (retry
    /// budget exhausted) or `AllReplicasDown`.
    pub requests_lost: u64,
    /// Requests failed by deadline expiry (`DeadlineExceeded`); also
    /// counted in `failed`.
    pub deadline_expired: u64,
}

impl ServiceStats {
    /// Fraction of proposed draft tokens accepted (0 when nothing was
    /// proposed — e.g. speculation disabled).
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_proposed as f64
        }
    }
}

#[derive(Debug, Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    tokens_out: AtomicU64,
    kv_blocks_total: AtomicU64,
    kv_blocks_used: AtomicU64,
    prefix_cache_hits: AtomicU64,
    prefix_cache_misses: AtomicU64,
    prefill_skips: AtomicU64,
    spec_rounds: AtomicU64,
    spec_proposed: AtomicU64,
    spec_accepted: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
    requests_lost: AtomicU64,
    deadline_expired: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ServiceStats {
        ServiceStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            tokens_out: self.tokens_out.load(Ordering::Relaxed),
            kv_blocks_total: self.kv_blocks_total.load(Ordering::Relaxed),
            kv_blocks_used: self.kv_blocks_used.load(Ordering::Relaxed),
            prefix_cache_hits: self.prefix_cache_hits.load(Ordering::Relaxed),
            prefix_cache_misses: self.prefix_cache_misses.load(Ordering::Relaxed),
            prefill_skips: self.prefill_skips.load(Ordering::Relaxed),
            spec_rounds: self.spec_rounds.load(Ordering::Relaxed),
            spec_proposed: self.spec_proposed.load(Ordering::Relaxed),
            spec_accepted: self.spec_accepted.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            requests_lost: self.requests_lost.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
        }
    }

    fn count_terminal(&self, err: &ServiceError) {
        match err {
            ServiceError::Cancelled => {
                self.cancelled.fetch_add(1, Ordering::Relaxed);
                return;
            }
            ServiceError::DeadlineExceeded => {
                self.deadline_expired.fetch_add(1, Ordering::Relaxed);
            }
            ServiceError::ReplicaFailed { .. } | ServiceError::AllReplicasDown => {
                self.requests_lost.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
        self.failed.fetch_add(1, Ordering::Relaxed);
    }
}

/// A routed request travelling to a replica worker.
struct WorkItem {
    id: RequestId,
    prompt_tokens: Vec<i32>,
    /// Prompt tokens actually in context (≤ prompt_len).
    prompt_used: usize,
    /// Oldest prompt tokens were dropped at encode time.
    truncated: bool,
    max_new: usize,
    stop: Option<i32>,
    submitted: Instant,
    /// Absolute expiry ([`GenRequest::deadline_ms`] past submission):
    /// checked at every admission/decode-step boundary, not just on the
    /// waiting side.
    deadline: Option<Instant>,
    /// Failover retries consumed so far (0 on first dispatch).
    attempt: u32,
    /// Token events already streamed by earlier attempts: a retried
    /// request re-prefills its original prompt and replays this many
    /// tokens without re-emitting them (greedy decoding reproduces them
    /// exactly), so the client stream resumes where it broke.
    replayed: usize,
    events: Sender<RequestEvent>,
    cancel: Arc<CancelFlag>,
}

/// A handed-off request travelling from a prefill-only replica to a
/// decode-capable one, carrying its exported KV rows and the streaming
/// state accumulated so far (the prefill token was already emitted).
struct DecodeWork {
    item: WorkItem,
    seg: KvSegment,
    /// When the prefill replica admitted the request (queued-time
    /// accounting stays anchored to the original admission).
    admitted: Instant,
    /// Rows in flight when the request was admitted on the prefill side.
    cohort: usize,
    prefill_seconds: f64,
    /// Token events emitted so far (1: the prefill-produced token).
    emitted: usize,
    /// The in-flight UTF-8 decoder state, carried across the hand-off so
    /// a multi-byte character split over the phase boundary still
    /// renders exactly once.
    text: Utf8Stream,
}

/// What travels on a replica worker's queue: a fresh routed request
/// (prefill side of its lifecycle) or a handed-off KV segment (decode
/// side). Hybrid deployments only ever see `Prefill`.
enum WorkMsg {
    Prefill(WorkItem),
    Decode(DecodeWork),
}

impl WorkMsg {
    fn cancel_flag(&self) -> &CancelFlag {
        match self {
            WorkMsg::Prefill(it) => &it.cancel,
            WorkMsg::Decode(dw) => &dw.item.cancel,
        }
    }

    fn deadline(&self) -> Option<Instant> {
        match self {
            WorkMsg::Prefill(it) => it.deadline,
            WorkMsg::Decode(dw) => dw.item.deadline,
        }
    }

    fn into_item(self) -> WorkItem {
        match self {
            WorkMsg::Prefill(it) => it,
            WorkMsg::Decode(dw) => dw.item,
        }
    }
}

/// A faulted request travelling from a replica worker back to the
/// failover dispatcher for re-routing.
enum RetryWork {
    /// Re-prefill the original prompt on another replica (the common
    /// path; `item.replayed` tokens are replayed silently).
    Prefill { item: WorkItem, from: usize },
    /// Re-import a handed-off KV segment on another decode-capable
    /// replica (disaggregated path) before falling back to re-prefill.
    Decode { dw: DecodeWork, from: usize },
}

impl RetryWork {
    fn into_item(self) -> WorkItem {
        match self {
            RetryWork::Prefill { item, .. } => item,
            RetryWork::Decode { dw, .. } => dw.item,
        }
    }
}

/// Per-worker fault-tolerance wiring: the injection plan its backend
/// wraps itself in, the retry budget/backoff, and the channel back to
/// the failover dispatcher.
struct Recovery {
    plan: Option<Arc<FaultPlan>>,
    max_retries: u32,
    backoff: Duration,
    retry_tx: Sender<(Instant, RetryWork)>,
}

/// A request occupying a decode-session slot.
struct ActiveItem {
    item: WorkItem,
    admitted: Instant,
    /// Rows in flight when this request was admitted (incl. itself).
    cohort: usize,
    prefill_seconds: f64,
    decode_start: Instant,
    /// Token events emitted so far (the next event's `index`).
    emitted: usize,
    /// Incremental UTF-8 decoder for `Token.text_delta`: multi-byte
    /// characters buffer until complete instead of rendering as
    /// replacement glyphs mid-stream.
    text: Utf8Stream,
}

/// Handle to a running service.
pub struct HexGenService {
    router: Arc<Router>,
    queues: Vec<Sender<WorkMsg>>,
    workers: Vec<JoinHandle<()>>,
    /// The failover dispatcher thread re-routing faulted requests.
    failover: Option<JoinHandle<()>>,
    /// Exit signal for the dispatcher, which holds clones of every
    /// worker queue sender and so must stop before workers can see
    /// their queues close.
    failover_stop: Arc<AtomicBool>,
    manifest: Manifest,
    cfg: ServiceConfig,
    // Behind ranked mutexes so the service can be shared
    // (`Arc<HexGenService>` across HTTP handler threads): stats
    // accumulate into `comm_total`, which is only taken under `comm_rx`
    // (ranks in `util::sync::locks`).
    comm_rx: OrderedMutex<Receiver<CommStats>>,
    comm_total: OrderedMutex<CommStats>,
    counters: Arc<Counters>,
    next_id: AtomicU64,
}

impl HexGenService {
    /// Start worker threads (compiling each replica's executables).
    pub fn start(cfg: ServiceConfig) -> Result<HexGenService> {
        if cfg.replicas.is_empty() {
            bail!("no replicas configured");
        }
        let manifest = Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
        let weights = Arc::new(WeightStore::load(&cfg.artifacts_dir.join("weights.bin"))?);
        let router = Arc::new(Router::new(cfg.route, cfg.replicas.len()));
        if let Some(speeds) = &cfg.speeds {
            if speeds.len() != cfg.replicas.len() {
                bail!("{} speed seeds for {} replicas", speeds.len(), cfg.replicas.len());
            }
            if speeds.iter().any(|&s| !s.is_finite() || s <= 0.0) {
                bail!("replica speed seeds must be positive and finite, got {speeds:?}");
            }
            router.set_speeds(speeds.clone());
        }
        if let Some(speeds) = &cfg.prefill_speeds {
            if speeds.len() != cfg.replicas.len() {
                bail!("{} prefill speed seeds for {} replicas", speeds.len(), cfg.replicas.len());
            }
            if speeds.iter().any(|&s| !s.is_finite() || s <= 0.0) {
                bail!("prefill speed seeds must be positive and finite, got {speeds:?}");
            }
            router.set_phase_speeds(ServePhase::Prefill, speeds.clone());
        }
        if !cfg.roles.is_empty() {
            if cfg.roles.len() != cfg.replicas.len() {
                bail!("{} phase roles for {} replicas", cfg.roles.len(), cfg.replicas.len());
            }
            if !cfg.roles.iter().any(|r| r.can_decode()) {
                bail!(
                    "no decode-capable replica: prefill-only replicas need a decode partner \
                     for the KV hand-off"
                );
            }
            if !cfg.roles.iter().any(|r| r.can_prefill()) {
                bail!("no prefill-capable replica: no replica can admit prompts");
            }
            router.set_roles(cfg.roles.clone());
        }
        let roles: Vec<PhaseRole> = (0..cfg.replicas.len())
            .map(|i| cfg.roles.get(i).copied().unwrap_or_default())
            .collect();
        // Speculative decoding: load the draft model once here (failing
        // fast, sharing the mmap'd weights across workers) and ship it to
        // every replica worker alongside the policy.
        let spec: Option<(SpecPolicy, Manifest, Arc<WeightStore>)> = match &cfg.spec {
            None => None,
            Some(policy) => {
                if policy.k == 0 {
                    bail!("speculative k must be >= 1");
                }
                if roles.iter().any(|&r| r != PhaseRole::Hybrid) {
                    bail!("speculative decoding is not supported with disaggregated phase roles");
                }
                let dm = Manifest::load(&policy.draft_model.join("manifest.json"))?;
                let dw = Arc::new(WeightStore::load(&policy.draft_model.join("weights.bin"))?);
                let (t, d) = (&manifest.model, &dm.model);
                if t.vocab != d.vocab || t.prompt_len != d.prompt_len || t.max_seq != d.max_seq {
                    bail!(
                        "draft model disagrees with target on (vocab, prompt_len, max_seq): \
                         ({}, {}, {}) vs ({}, {}, {})",
                        d.vocab,
                        d.prompt_len,
                        d.max_seq,
                        t.vocab,
                        t.prompt_len,
                        t.max_seq
                    );
                }
                Some((policy.clone(), dm, dw))
            }
        };

        let counters = Arc::new(Counters::default());
        router.set_breaker_policy(cfg.faults.breaker);
        let fault_plan: Option<Arc<FaultPlan>> = cfg.faults.plan.clone().map(Arc::new);
        let (retry_tx, retry_rx) = channel::<(Instant, RetryWork)>();
        let (comm_tx, comm_rx) = channel::<CommStats>();
        let mut queues = Vec::with_capacity(cfg.replicas.len());
        let mut receivers = Vec::with_capacity(cfg.replicas.len());
        for _ in 0..cfg.replicas.len() {
            let (tx, rx) = channel::<WorkMsg>();
            queues.push(tx);
            receivers.push(rx);
        }
        let failover_stop = Arc::new(AtomicBool::new(false));
        let failover = {
            let queues = queues.clone();
            let roles = roles.clone();
            let router = router.clone();
            let counters = counters.clone();
            let stop = failover_stop.clone();
            std::thread::spawn(move || {
                failover_loop(retry_rx, queues, roles, router, counters, stop)
            })
        };
        let mut workers = Vec::with_capacity(cfg.replicas.len());
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        for (rid, rx) in receivers.into_iter().enumerate() {
            let plan = cfg.replicas[rid].clone();
            let role = roles[rid];
            // Hand-off senders, prefill-only workers only, and only
            // toward decode-capable replicas. Holding no other senders
            // keeps the shutdown chain acyclic: dropping the service's
            // senders closes the prefill queues, the exiting prefill
            // workers drop these clones, and the decode queues close in
            // turn.
            let handoff: Vec<Option<Sender<WorkMsg>>> = if role == PhaseRole::Prefill {
                queues
                    .iter()
                    .zip(&roles)
                    .map(|(tx, r)| if r.can_decode() { Some(tx.clone()) } else { None })
                    .collect()
            } else {
                (0..cfg.replicas.len()).map(|_| None).collect()
            };
            let dir = cfg.artifacts_dir.clone();
            let manifest = manifest.clone();
            let weights = weights.clone();
            let batch = cfg.batch;
            let kv = cfg.kv;
            let backend = cfg.backend;
            let adapt_speeds = cfg.adapt_speeds;
            let router = router.clone();
            let counters = counters.clone();
            let comm_tx = comm_tx.clone();
            let ready_tx = ready_tx.clone();
            let spec = spec.clone();
            let recovery = Recovery {
                plan: fault_plan.clone(),
                max_retries: cfg.faults.max_retries,
                backoff: cfg.faults.retry_backoff,
                retry_tx: retry_tx.clone(),
            };
            workers.push(std::thread::spawn(move || {
                worker_loop(
                    rid, backend, dir, manifest, weights, plan, batch, kv, adapt_speeds, role,
                    spec, recovery, handoff, rx, router, counters, comm_tx, ready_tx,
                )
            }));
        }
        // Wait until every replica compiled its pipeline (or failed).
        for _ in 0..cfg.replicas.len() {
            let up = ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker died during startup"))
                .and_then(|r| r.map_err(|e| anyhow::anyhow!("replica startup failed: {e}")));
            if let Err(e) = up {
                // Unwedge before bailing: the dispatcher holds queue
                // senders, so it must stop for the already-running
                // workers to see their queues close and exit.
                failover_stop.store(true, Ordering::Relaxed);
                return Err(e);
            }
        }
        Ok(HexGenService {
            router,
            queues,
            workers,
            failover: Some(failover),
            failover_stop,
            manifest,
            cfg,
            comm_rx: OrderedMutex::new(locks::COMM_RX, "service.comm_rx", comm_rx),
            comm_total: OrderedMutex::new(
                locks::COMM_TOTAL,
                "service.comm_total",
                CommStats::default(),
            ),
            counters,
            next_id: AtomicU64::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn replicas(&self) -> usize {
        self.queues.len()
    }

    /// The per-replica stage plans being served (`GET /v1/plan`).
    pub fn stage_plans(&self) -> &[Vec<StagePlan>] {
        &self.cfg.replicas
    }

    /// Effective per-replica routing speeds (plan seeds, overridden by
    /// measured decode-throughput EWMAs as replicas report in).
    pub fn router_speeds(&self) -> Vec<f64> {
        self.router.speeds()
    }

    /// Effective per-replica **prefill-side** routing speeds.
    pub fn router_prefill_speeds(&self) -> Vec<f64> {
        self.router.phase_speeds(ServePhase::Prefill)
    }

    /// Phase role per replica (`GET /v1/plan`); all-hybrid when the
    /// configuration left roles unset.
    pub fn roles(&self) -> Vec<PhaseRole> {
        if self.cfg.roles.is_empty() {
            vec![PhaseRole::Hybrid; self.cfg.replicas.len()]
        } else {
            self.cfg.roles.clone()
        }
    }

    /// Per-replica `(outstanding requests, effective speed)` snapshot.
    pub fn router_snapshot(&self) -> Vec<(usize, f64)> {
        self.router.load_snapshot()
    }

    /// Per-replica circuit-breaker health (`GET /healthz`, `/metrics`,
    /// `/v1/plan`).
    pub fn router_health(&self) -> Vec<ReplicaHealth> {
        self.router.health()
    }

    /// Lifetime request counters.
    pub fn stats(&self) -> ServiceStats {
        self.counters.snapshot()
    }

    /// Submit a request; returns a [`RequestHandle`] streaming its
    /// lifecycle events (`Queued → Admitted → Token… → Done/Failed`).
    /// If the routed replica is dead (its queue hung up), the router's
    /// load count is released and the request re-routed to a live
    /// replica. Dropping the handle before its terminal event cancels
    /// the request.
    pub fn submit(&self, req: GenRequest) -> RequestHandle {
        let id = RequestId(self.next_id.fetch_add(1, Ordering::Relaxed));
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let cancel = Arc::new(CancelFlag::default());
        let handle = RequestHandle::new(id, rx, cancel.clone());

        // Reject invalid limits here, per request — admission batches
        // several requests into one prefill, and one bad request must not
        // fail its co-batched neighbours.
        let max_new = req.max_new.unwrap_or(self.cfg.max_new_tokens);
        if max_new == 0 {
            self.counters.failed.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(RequestEvent::Failed(ServiceError::InvalidRequest(
                "max_new must be >= 1".to_string(),
            )));
            return handle;
        }
        let prompt_len = self.manifest.model.prompt_len;
        let (prompt_tokens, full) = tokenizer::encode_report(&req.prompt, prompt_len);
        let submitted = Instant::now();
        let mut item = WorkItem {
            id,
            prompt_tokens,
            prompt_used: full.min(prompt_len),
            truncated: full > prompt_len,
            max_new,
            stop: req.stop.or(self.cfg.stop_token),
            submitted,
            deadline: req.deadline_ms.map(|ms| submitted + Duration::from_millis(ms)),
            attempt: 0,
            replayed: 0,
            events: tx,
            cancel,
        };
        // Queued is emitted before the worker can race an Admitted in.
        let _ = item.events.send(RequestEvent::Queued);
        // All-hybrid deployments route phase-lessly — the exact pre-role
        // code path; mixed-role plans route the prefill leg among
        // prefill-capable replicas only.
        let disagg = self.cfg.roles.iter().any(|&r| r != PhaseRole::Hybrid);
        let mut dead: Vec<usize> = Vec::new();
        loop {
            let replica = if disagg {
                self.router.route_phase(ServePhase::Prefill, &dead)
            } else {
                self.router.route_excluding(&dead)
            };
            let Some(replica) = replica else {
                self.counters.count_terminal(&ServiceError::AllReplicasDown);
                let _ = item.events.send(RequestEvent::Failed(ServiceError::AllReplicasDown));
                return handle;
            };
            match self.queues[replica].send(WorkMsg::Prefill(item)) {
                Ok(()) => return handle,
                Err(SendError(WorkMsg::Prefill(returned))) => {
                    // The worker hung up: release the routed load count so
                    // the policy stops charging the dead replica, then try
                    // the remaining ones.
                    self.router.complete(replica);
                    dead.push(replica);
                    item = returned;
                }
                Err(SendError(returned)) => {
                    // Unreachable (a Prefill send returns a Prefill), but
                    // fail the request cleanly rather than trusting it.
                    self.router.complete(replica);
                    self.counters.count_terminal(&ServiceError::AllReplicasDown);
                    let _ = returned
                        .into_item()
                        .events
                        .send(RequestEvent::Failed(ServiceError::AllReplicasDown));
                    return handle;
                }
            }
        }
    }

    /// Submit and block for the completion: a thin wrapper draining the
    /// event stream ([`RequestHandle::wait`]).
    pub fn generate(&self, prompt: &str, max_new: Option<usize>) -> Result<Completion> {
        let mut req = GenRequest::new(prompt);
        req.max_new = max_new;
        self.submit(req).wait().map_err(anyhow::Error::from)
    }

    /// Accumulated communication stats from all workers (cumulative
    /// since service start).
    pub fn comm_stats(&self) -> CommStats {
        let rx = self.comm_rx.lock();
        let mut total = self.comm_total.lock();
        while let Ok(s) = rx.try_recv() {
            total.merge(&s);
        }
        *total
    }

    /// Shut down: stop the failover dispatcher (it holds clones of every
    /// worker queue sender, so it must exit first), close the queues,
    /// and join everything.
    pub fn shutdown(mut self) {
        self.failover_stop.store(true, Ordering::Relaxed);
        self.queues.clear();
        if let Some(h) = self.failover.take() {
            let _ = h.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HexGenService {
    /// A dropped (rather than shut-down) service — e.g. an
    /// `Arc<HexGenService>` shared with HTTP handler threads — still
    /// signals the dispatcher to exit; otherwise its queue-sender clones
    /// would keep every worker thread parked forever.
    fn drop(&mut self) {
        self.failover_stop.store(true, Ordering::Relaxed);
    }
}

/// The failover dispatcher: a single service-lifetime thread receiving
/// faulted requests from replica workers and re-routing them once their
/// backoff expires. Centralizing the retry path keeps workers free of
/// each other's queue senders (which would deadlock the close-on-drop
/// shutdown chain) and gives retries one place to enforce deadlines,
/// budgets, and the all-replicas-down verdict.
fn failover_loop(
    rx: Receiver<(Instant, RetryWork)>,
    queues: Vec<Sender<WorkMsg>>,
    roles: Vec<PhaseRole>,
    router: Arc<Router>,
    counters: Arc<Counters>,
    stop: Arc<AtomicBool>,
) {
    let disagg = roles.iter().any(|&r| r != PhaseRole::Hybrid);
    // Replicas whose queue hung up (worker exited): permanently dead,
    // unlike quarantined replicas which the breaker may readmit.
    let mut dead: Vec<usize> = Vec::new();
    // Not-yet-due retries, scanned linearly (failover volume is tiny).
    let mut pending: Vec<(Instant, RetryWork)> = Vec::new();

    let fail = |work: RetryWork, err: ServiceError| {
        counters.count_terminal(&err);
        let _ = work.into_item().events.send(RequestEvent::Failed(err));
    };
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let now = Instant::now();
        let mut due = Vec::new();
        let mut i = 0;
        while i < pending.len() {
            if pending[i].0 <= now {
                due.push(pending.swap_remove(i).1);
            } else {
                i += 1;
            }
        }
        for work in due {
            // Terminal states first: a retried request may have been
            // cancelled or expired while it waited out its backoff.
            {
                let item = match &work {
                    RetryWork::Prefill { item, .. } => item,
                    RetryWork::Decode { dw, .. } => &dw.item,
                };
                if item.cancel.is_cancelled() {
                    fail(work, ServiceError::Cancelled);
                    continue;
                }
                if item.deadline.is_some_and(|d| now >= d) {
                    fail(work, ServiceError::DeadlineExceeded);
                    continue;
                }
            }
            // Disaggregated decode-side faults retry the KV import on
            // another decode-capable replica first; when none is
            // routable the request falls back to a full re-prefill
            // (replaying everything streamed so far).
            let (mut item, from) = match work {
                RetryWork::Decode { mut dw, from } => {
                    let mut exclude = dead.clone();
                    if !exclude.contains(&from) {
                        exclude.push(from);
                    }
                    let mut routed = false;
                    while let Some(target) = router.route_phase(ServePhase::Decode, &exclude) {
                        match queues[target].send(WorkMsg::Decode(dw)) {
                            Ok(()) => {
                                routed = true;
                                break;
                            }
                            Err(SendError(WorkMsg::Decode(returned))) => {
                                router.complete(target);
                                dead.push(target);
                                exclude.push(target);
                                dw = returned;
                            }
                            Err(SendError(returned)) => {
                                router.complete(target);
                                fail(
                                    RetryWork::Prefill { item: returned.into_item(), from },
                                    ServiceError::AllReplicasDown,
                                );
                                routed = true;
                                break;
                            }
                        }
                    }
                    if routed {
                        continue;
                    }
                    let mut item = dw.item;
                    item.replayed = dw.emitted;
                    (item, from)
                }
                RetryWork::Prefill { item, from } => (item, from),
            };
            // Prefer any replica other than the faulted one; if the
            // faulted replica is the only one admitted by its breaker,
            // let it try again rather than waiting out the quarantine.
            let mut exclude = dead.clone();
            if !exclude.contains(&from) {
                exclude.push(from);
            }
            loop {
                let route = |excl: &[usize]| {
                    if disagg {
                        router.route_phase(ServePhase::Prefill, excl)
                    } else {
                        router.route_excluding(excl)
                    }
                };
                let Some(replica) = route(&exclude).or_else(|| route(&dead)) else {
                    if dead.len() >= queues.len() {
                        fail(
                            RetryWork::Prefill { item, from },
                            ServiceError::AllReplicasDown,
                        );
                    } else {
                        // Every live replica is quarantined right now:
                        // hold the request until a breaker half-opens.
                        pending.push((
                            now + CANCEL_SWEEP_INTERVAL,
                            RetryWork::Prefill { item, from },
                        ));
                    }
                    break;
                };
                match queues[replica].send(WorkMsg::Prefill(item)) {
                    Ok(()) => break,
                    Err(SendError(WorkMsg::Prefill(returned))) => {
                        router.complete(replica);
                        dead.push(replica);
                        if !exclude.contains(&replica) {
                            exclude.push(replica);
                        }
                        item = returned;
                    }
                    Err(SendError(returned)) => {
                        router.complete(replica);
                        fail(
                            RetryWork::Prefill { item: returned.into_item(), from },
                            ServiceError::AllReplicasDown,
                        );
                        break;
                    }
                }
            }
        }
        let wait = pending
            .iter()
            .map(|(t, _)| t.saturating_duration_since(now))
            .min()
            .unwrap_or(CANCEL_SWEEP_INTERVAL)
            .min(CANCEL_SWEEP_INTERVAL)
            .max(Duration::from_millis(1));
        match rx.recv_timeout(wait) {
            Ok(msg) => pending.push(msg),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Shutdown (or every worker gone): anything still waiting cannot
    // complete — fail it instead of hanging its sender forever.
    for (_, work) in pending.drain(..).chain(std::iter::from_fn(|| rx.try_recv().ok())) {
        fail(work, ServiceError::AllReplicasDown);
    }
}

/// Largest artifact bucket not exceeding `max_batch` (the session's slot
/// count); falls back to the smallest bucket when `max_batch` is below
/// them all.
fn session_bucket(buckets: &[usize], max_batch: usize) -> usize {
    buckets
        .iter()
        .copied()
        .filter(|&b| b <= max_batch.max(1))
        .max()
        .or_else(|| buckets.iter().copied().min())
        .unwrap_or(1)
}

/// The session a replica worker serves with: a plain [`DecodeSession`],
/// or a [`SpeculativeSession`] pairing it with a draft model. Both
/// expose the same step-boundary surface (admit, step, cancel, KV
/// gauges), so the worker loop is indifferent — a speculative "step" is
/// one propose/verify round that may emit several tokens per row.
enum ServeSession<'a> {
    Plain(DecodeSession<'a>),
    Spec(SpeculativeSession<'a>),
}

impl<'a> ServeSession<'a> {
    fn active(&self) -> usize {
        match self {
            ServeSession::Plain(s) => s.active(),
            ServeSession::Spec(s) => s.active(),
        }
    }

    fn free_slots(&self) -> Vec<usize> {
        match self {
            ServeSession::Plain(s) => s.free_slots(),
            ServeSession::Spec(s) => s.free_slots(),
        }
    }

    /// Pool capacity; a speculative session's gauge spans both pools.
    fn kv_blocks_total(&self) -> usize {
        match self {
            ServeSession::Plain(s) => s.kv_blocks_total(),
            ServeSession::Spec(s) => s.target().kv_blocks_total() + s.draft().kv_blocks_total(),
        }
    }

    fn kv_blocks_used(&self) -> usize {
        match self {
            ServeSession::Plain(s) => s.kv_blocks_used(),
            ServeSession::Spec(s) => s.target().kv_blocks_used() + s.draft().kv_blocks_used(),
        }
    }

    fn prefix_cache_hits(&self) -> u64 {
        match self {
            ServeSession::Plain(s) => s.prefix_cache_hits(),
            ServeSession::Spec(s) => s.target().prefix_cache_hits() + s.draft().prefix_cache_hits(),
        }
    }

    fn prefix_cache_misses(&self) -> u64 {
        match self {
            ServeSession::Plain(s) => s.prefix_cache_misses(),
            ServeSession::Spec(s) => {
                s.target().prefix_cache_misses() + s.draft().prefix_cache_misses()
            }
        }
    }

    fn prefill_skips(&self) -> usize {
        match self {
            ServeSession::Plain(s) => s.prefill_skips(),
            ServeSession::Spec(s) => s.target().prefill_skips() + s.draft().prefill_skips(),
        }
    }

    /// Admission gate: blocks still grantable. A speculative admission
    /// must fit **both** pools, so the budget is the tighter of the two.
    fn free_block_budget(&self) -> usize {
        match self {
            ServeSession::Plain(s) => s.free_block_budget(),
            ServeSession::Spec(s) => {
                s.target().free_block_budget().min(s.draft().free_block_budget())
            }
        }
    }

    /// Worst-case blocks one admission reserves. The draft row is
    /// admitted with the widest limit (it must never retire mid-round),
    /// so the speculative bound is the larger of the two sessions' needs
    /// — conservative against the min-budget above.
    fn blocks_needed(&self, max_new: usize) -> usize {
        match self {
            ServeSession::Plain(s) => s.blocks_needed(max_new),
            ServeSession::Spec(s) => {
                let info = &s.draft().manifest().model;
                let draft_max = info.max_seq.saturating_sub(info.prompt_len);
                s.target().blocks_needed(max_new).max(s.draft().blocks_needed(draft_max))
            }
        }
    }

    fn blocks_needed_at(&self, pos: usize, max_new: usize) -> usize {
        match self {
            ServeSession::Plain(s) => s.blocks_needed_at(pos, max_new),
            // Unreachable in practice: speculative replicas reject
            // disaggregated roles at startup, so no KV segment is ever
            // routed here. Price it off the target anyway.
            ServeSession::Spec(s) => s.target().blocks_needed_at(pos, max_new),
        }
    }

    fn prefill(&mut self, reqs: Vec<(usize, SlotRequest)>) -> Result<StepOutcome> {
        match self {
            ServeSession::Plain(s) => s.prefill_into_slots(reqs),
            ServeSession::Spec(s) => s.admit(reqs),
        }
    }

    /// One serving iteration: a decode step (one token per row) or a
    /// speculative round (1 to k+1 tokens per row).
    fn step(&mut self) -> Result<StepOutcome> {
        match self {
            ServeSession::Plain(s) => s.decode_step(),
            ServeSession::Spec(s) => s.spec_round(),
        }
    }

    fn cancel_slot(&mut self, slot: usize) -> Result<Option<Vec<i32>>> {
        match self {
            ServeSession::Plain(s) => s.cancel_slot(slot),
            ServeSession::Spec(s) => s.cancel_slot(slot),
        }
    }

    fn export_rows(&mut self, slot: usize) -> Result<KvSegment> {
        match self {
            ServeSession::Plain(s) => s.export_rows(slot),
            ServeSession::Spec(_) => bail!("speculative replicas do not serve KV hand-offs"),
        }
    }

    fn import_rows(
        &mut self,
        slot: usize,
        seg: &KvSegment,
        max_new: usize,
        stop: Option<i32>,
    ) -> Result<()> {
        match self {
            ServeSession::Plain(s) => s.import_rows(slot, seg, max_new, stop),
            ServeSession::Spec(_) => bail!("speculative replicas do not serve KV hand-offs"),
        }
    }

    fn take_comm(&mut self) -> CommStats {
        match self {
            ServeSession::Plain(s) => s.take_comm(),
            ServeSession::Spec(s) => s.take_comm(),
        }
    }

    fn spec_stats(&self) -> SpecStats {
        match self {
            ServeSession::Plain(_) => SpecStats::default(),
            ServeSession::Spec(s) => s.stats(),
        }
    }
}

/// Build the worker's serving session: plain, or target+draft paired
/// into a [`SpeculativeSession`] when a draft executor is present.
fn build_serve_session<'a>(
    exec: &'a PipelineExecutor,
    draft: Option<&'a (PipelineExecutor, usize)>,
    bucket: usize,
    kv: KvPolicy,
) -> Result<ServeSession<'a>> {
    let target = exec.new_session_with(bucket, kv)?;
    match draft {
        None => Ok(ServeSession::Plain(target)),
        Some((dexec, k)) => {
            let dsession = dexec.new_session_with(bucket, kv)?;
            Ok(ServeSession::Spec(SpeculativeSession::new(target, dsession, *k)?))
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rid: usize,
    backend: BackendKind,
    dir: PathBuf,
    manifest: Manifest,
    weights: Arc<WeightStore>,
    plan: Vec<StagePlan>,
    batch: BatchPolicy,
    kv: KvPolicy,
    adapt_speeds: bool,
    role: PhaseRole,
    spec: Option<(SpecPolicy, Manifest, Arc<WeightStore>)>,
    recovery: Recovery,
    handoff: Vec<Option<Sender<WorkMsg>>>,
    rx: Receiver<WorkMsg>,
    router: Arc<Router>,
    counters: Arc<Counters>,
    comm_tx: Sender<CommStats>,
    ready_tx: Sender<Result<(), String>>,
) {
    // Thread-confined backend instance (backends need not be Send).
    // With a fault plan the backend wraps itself in the deterministic
    // injector — built once, outside the session-rebuild path, so fault
    // counters persist across rebuilds (a "fail every call after K"
    // spec keeps failing the rebuilt session too).
    let built = match &recovery.plan {
        Some(fp) => make_fault_backend(backend, &dir, manifest, weights, fp.clone(), rid),
        None => make_backend(backend, &dir, manifest, weights),
    };
    let exec = match built.and_then(|be| PipelineExecutor::with_backend(be, plan)) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return;
        }
    };
    // Speculative decoding: a second thread-confined executor over the
    // draft model (single stage, tp=1 — drafts are small by design).
    let draft_exec: Option<(PipelineExecutor, usize)> = match &spec {
        None => None,
        Some((policy, dmanifest, dweights)) => {
            let built = plan_from_strategy(&[1], &[dmanifest.model.layers]).and_then(|dplan| {
                make_backend(backend, &policy.draft_model, dmanifest.clone(), dweights.clone())
                    .and_then(|be| PipelineExecutor::with_backend(be, dplan))
            });
            match built {
                Ok(e) => Some((e, policy.k)),
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("draft model: {e:#}")));
                    return;
                }
            }
        }
    };
    let bucket = session_bucket(&exec.manifest().batch_buckets, batch.max_batch);
    if let Some((dexec, _)) = &draft_exec {
        let db = session_bucket(&dexec.manifest().batch_buckets, batch.max_batch);
        if db != bucket {
            let _ = ready_tx.send(Err(format!(
                "draft session bucket {db} != target session bucket {bucket}: \
                 speculative slots pair one-to-one"
            )));
            return;
        }
    }
    let mut session = match build_serve_session(&exec, draft_exec.as_ref(), bucket, kv) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return;
        }
    };
    // Pool capacity is fixed for the worker's lifetime (rebuilds reuse
    // the same policy), so its share of the fleet-wide capacity posts
    // once — before the ready signal, so `stats()` is never mid-startup.
    counters.kv_blocks_total.fetch_add(session.kv_blocks_total() as u64, Ordering::Relaxed);
    let _ = ready_tx.send(Ok(()));
    // Last-published values of the per-session KV stats: the shared
    // counters accumulate deltas so they stay correct across replicas
    // and session rebuilds.
    let mut kv_used_last: u64 = 0;
    let mut kv_hits_last: u64 = 0;
    let mut kv_misses_last: u64 = 0;
    let mut kv_skips_last: u64 = 0;
    let mut spec_last = SpecStats::default();
    let prompt_len = exec.manifest().model.prompt_len;
    // Continuous admission co-batches rows at different cache depths,
    // which needs per-row decode positions (on the draft side too, when
    // speculating); backends bound to the scalar-position AOT artifact
    // signature degrade to run-to-completion batching instead of failing
    // mid-step.
    let draft_rowwise = match &draft_exec {
        None => true,
        Some((d, _)) => d.backend().supports_rowwise_decode_positions(),
    };
    let continuous =
        batch.continuous && exec.backend().supports_rowwise_decode_positions() && draft_rowwise;
    if batch.continuous && !continuous {
        crate::log_warn!(
            "replica {rid}: backend {} lacks per-row decode positions; \
             falling back to run-to-completion batching",
            exec.backend().name()
        );
    }
    crate::log_info!(
        "replica {rid} ready: backend {} strategy {} ({bucket} slots, {})",
        exec.backend().name(),
        exec.strategy_string(),
        if continuous { "continuous batching" } else { "run-to-completion batching" },
    );

    let mut queue: AdmissionQueue<WorkMsg> = AdmissionQueue::new(rx);
    let mut active: Vec<Option<ActiveItem>> = (0..bucket).map(|_| None).collect();

    let fail_item = |item: WorkItem, err: ServiceError| {
        counters.count_terminal(&err);
        let _ = item.events.send(RequestEvent::Failed(err));
        router.complete(rid);
    };
    let deliver = |active_item: ActiveItem, tokens: Vec<i32>| {
        counters.completed.fetch_add(1, Ordering::Relaxed);
        counters.tokens_out.fetch_add(tokens.len() as u64, Ordering::Relaxed);
        if active_item.item.attempt > 0 {
            counters.failovers.fetch_add(1, Ordering::Relaxed);
        }
        router.report_success(rid);
        let completion = Completion {
            id: active_item.item.id,
            text: tokenizer::decode(&tokens),
            prompt_tokens: active_item.item.prompt_used,
            truncated: active_item.item.truncated,
            latency: active_item.item.submitted.elapsed().as_secs_f64(),
            queued: (active_item.admitted - active_item.item.submitted).as_secs_f64(),
            replica: rid,
            batch_size: active_item.cohort,
            prefill_seconds: active_item.prefill_seconds,
            decode_seconds: active_item.decode_start.elapsed().as_secs_f64(),
            decode_steps: tokens.len().saturating_sub(1),
            tokens,
        };
        let _ = active_item.item.events.send(RequestEvent::Done(completion));
        router.complete(rid);
    };
    // `last` marks the row's final token: any bytes still buffered in
    // its UTF-8 stream flush into this delta, so the concatenation of a
    // request's deltas equals its completion text exactly. A failover
    // retry replays its first `replayed` tokens silently — they were
    // already streamed by the faulted attempt — but still pushes them
    // through the fresh UTF-8 decoder so multi-byte characters split
    // across the fault render exactly once.
    let emit_token = |a: &mut ActiveItem, token: i32, last: bool| {
        let mut text_delta = a.text.push(token);
        if last {
            text_delta.push_str(&a.text.finish());
        }
        if a.emitted >= a.item.replayed {
            let _ = a.item.events.send(RequestEvent::Token { index: a.emitted, token, text_delta });
        }
        a.emitted += 1;
    };

    // Failover: instead of failing a row its replica faulted under, send
    // it back to the dispatcher for re-routing — budget and backoff per
    // the service's FaultPolicy. The routed count moves with the
    // request (complete here, re-acquired when the dispatcher routes).
    let fail_or_retry = |mut a: ActiveItem, message: &str| {
        if a.item.attempt >= recovery.max_retries || a.item.cancel.is_cancelled() {
            fail_item(
                a.item,
                ServiceError::ReplicaFailed { replica: rid, message: message.to_string() },
            );
            return;
        }
        a.item.attempt += 1;
        a.item.replayed = a.emitted;
        let _ = a
            .item
            .events
            .send(RequestEvent::Retrying { replica: rid, attempt: a.item.attempt });
        counters.retries.fetch_add(1, Ordering::Relaxed);
        router.complete(rid);
        let due = Instant::now() + recovery.backoff * 2u32.saturating_pow(a.item.attempt - 1);
        let work = RetryWork::Prefill { item: a.item, from: rid };
        if let Err(SendError((_, work))) = recovery.retry_tx.send((due, work)) {
            // Dispatcher gone (shutdown): the request cannot complete.
            let item = work.into_item();
            counters.count_terminal(&ServiceError::AllReplicasDown);
            let _ = item.events.send(RequestEvent::Failed(ServiceError::AllReplicasDown));
        }
    };
    // Same, for a handed-off KV segment on the disaggregated path: the
    // dispatcher retries the import on another decode-capable replica
    // before falling back to a full re-prefill.
    let retry_decode = |mut dw: DecodeWork, message: &str| {
        if dw.item.attempt >= recovery.max_retries || dw.item.cancel.is_cancelled() {
            fail_item(
                dw.item,
                ServiceError::ReplicaFailed { replica: rid, message: message.to_string() },
            );
            return;
        }
        dw.item.attempt += 1;
        let _ = dw
            .item
            .events
            .send(RequestEvent::Retrying { replica: rid, attempt: dw.item.attempt });
        counters.retries.fetch_add(1, Ordering::Relaxed);
        router.complete(rid);
        let due = Instant::now() + recovery.backoff * 2u32.saturating_pow(dw.item.attempt - 1);
        let work = RetryWork::Decode { dw, from: rid };
        if let Err(SendError((_, work))) = recovery.retry_tx.send((due, work)) {
            let item = work.into_item();
            counters.count_terminal(&ServiceError::AllReplicasDown);
            let _ = item.events.send(RequestEvent::Failed(ServiceError::AllReplicasDown));
        }
    };

    // When a session operation reports a replica fault (decode failure,
    // KV bookkeeping corruption on cancel), the fault message lands here
    // and the top of the next iteration fails the in-flight rows and
    // rebuilds the session before anything else touches it.
    let mut rebuild: Option<String> = None;

    loop {
        // ---- rebuild after a replica fault ----------------------------
        // The session's slot/pool state may be inconsistent after a
        // mid-step failure: fail every in-flight row and start from a
        // fresh session. If even the rebuild fails, the replica is dead
        // — fail everything still buffered in its queue instead of
        // dropping the requests silently (their senders would hang
        // forever).
        if let Some(message) = rebuild.take() {
            // One incident, one breaker report: repeated rebuilds are
            // what trip this replica into quarantine.
            router.report_fault(rid);
            for slot_item in active.iter_mut() {
                if let Some(a) = slot_item.take() {
                    fail_or_retry(a, &message);
                }
            }
            // Retract the dead session's gauge contribution; the fresh
            // session's stats restart from zero.
            counters.kv_blocks_used.fetch_sub(kv_used_last, Ordering::Relaxed);
            kv_used_last = 0;
            kv_hits_last = 0;
            kv_misses_last = 0;
            kv_skips_last = 0;
            spec_last = SpecStats::default();
            session = match build_serve_session(&exec, draft_exec.as_ref(), bucket, kv) {
                Ok(s) => s,
                Err(e2) => {
                    let message = format!("session rebuild failed: {e2:#}");
                    crate::log_error!(
                        "replica {rid} {message}; re-routing queued requests and exiting"
                    );
                    // Queued requests never ran here: hand them to the
                    // dispatcher for immediate re-routing — no budget
                    // consumed, no Retrying event — exactly like
                    // `submit` skipping a dead replica.
                    for msg in queue.drain_all() {
                        router.complete(rid);
                        let work = match msg {
                            WorkMsg::Prefill(item) => RetryWork::Prefill { item, from: rid },
                            WorkMsg::Decode(dw) => RetryWork::Decode { dw, from: rid },
                        };
                        if let Err(SendError((_, work))) =
                            recovery.retry_tx.send((Instant::now(), work))
                        {
                            let item = work.into_item();
                            counters.count_terminal(&ServiceError::ReplicaFailed {
                                replica: rid,
                                message: message.clone(),
                            });
                            let _ = item.events.send(RequestEvent::Failed(
                                ServiceError::ReplicaFailed {
                                    replica: rid,
                                    message: message.clone(),
                                },
                            ));
                        }
                    }
                    return;
                }
            };
        }

        // ---- cancellation/deadline sweep at the step boundary ---------
        // Cancelled or expired active rows release their KV blocks
        // (admissible again below) and the router's load count;
        // cancelled/expired queued requests never run at all. Checking
        // deadlines here — where the work happens — is what frees an
        // expired request's blocks instead of burning decode steps on
        // output nobody is waiting for.
        let sweep_now = Instant::now();
        for slot in 0..bucket {
            let verdict = active[slot].as_ref().and_then(|a| {
                if a.item.cancel.is_cancelled() {
                    Some(ServiceError::Cancelled)
                } else if a.item.deadline.is_some_and(|d| sweep_now >= d) {
                    Some(ServiceError::DeadlineExceeded)
                } else {
                    None
                }
            });
            let Some(err) = verdict else { continue };
            if let Some(a) = active[slot].take() {
                if let Err(e) = session.cancel_slot(slot) {
                    // The row is done either way, but a release failure
                    // means the block pool can no longer be trusted:
                    // surface it as a replica fault.
                    let message = format!("cancel failed releasing slot {slot}: {e:#}");
                    crate::log_error!("replica {rid} {message}");
                    rebuild = Some(message);
                }
                fail_item(a.item, err);
            }
        }
        for msg in queue.drain_where(|m| {
            m.cancel_flag().is_cancelled() || m.deadline().is_some_and(|d| sweep_now >= d)
        }) {
            let err = if msg.cancel_flag().is_cancelled() {
                ServiceError::Cancelled
            } else {
                ServiceError::DeadlineExceeded
            };
            fail_item(msg.into_item(), err);
        }
        if rebuild.is_some() {
            continue;
        }

        // ---- block when idle (waking periodically for the sweep) ------
        if session.active() == 0 && queue.pending() == 0 {
            match queue.wait_for(CANCEL_SWEEP_INTERVAL) {
                WaitOutcome::Ready => {}
                WaitOutcome::TimedOut => continue,
                WaitOutcome::Closed => break, // shutdown: drained, nothing in flight
            }
        }

        // ---- admission at a step boundary -----------------------------
        // In run-to-completion mode slots only open once the whole batch
        // retired; continuous mode admits into any freed slot. Slots and
        // KV blocks gate independently: a freed slot admits nothing while
        // the pool lacks the worst-case blocks its request must reserve
        // (the request defers, it is never failed or over-committed).
        let free = session.free_slots();
        let avail = if continuous || session.active() == 0 { free.len() } else { 0 };
        let mut admitted = Vec::new();
        for msg in queue.admit_budgeted(
            avail,
            session.active() == 0,
            &batch,
            session.free_block_budget(),
            |m| match m {
                WorkMsg::Prefill(it) => session.blocks_needed(it.max_new),
                // A handed-off row already holds `seg.pos` tokens of
                // context: budget from that depth, not the prompt's.
                WorkMsg::Decode(dw) => session.blocks_needed_at(dw.seg.pos, dw.item.max_new),
            },
        ) {
            // Cancelled or expired between the sweep and the admit:
            // never runs.
            if msg.cancel_flag().is_cancelled() {
                fail_item(msg.into_item(), ServiceError::Cancelled);
            } else if msg.deadline().is_some_and(|d| Instant::now() >= d) {
                fail_item(msg.into_item(), ServiceError::DeadlineExceeded);
            } else {
                admitted.push(msg);
            }
        }
        if !admitted.is_empty() {
            let now = Instant::now();
            let cohort = session.active() + admitted.len();
            let mut reqs = Vec::with_capacity(admitted.len());
            let mut slots_used = Vec::with_capacity(admitted.len());
            for (msg, &slot) in admitted.into_iter().zip(free.iter()) {
                match msg {
                    WorkMsg::Prefill(item) => {
                        reqs.push((
                            slot,
                            SlotRequest {
                                prompt: item.prompt_tokens.clone(),
                                max_new: item.max_new,
                                stop: item.stop,
                            },
                        ));
                        let _ = item
                            .events
                            .send(RequestEvent::Admitted { replica: rid, batch_size: cohort });
                        active[slot] = Some(ActiveItem {
                            item,
                            admitted: now,
                            cohort,
                            prefill_seconds: 0.0,
                            decode_start: now,
                            emitted: 0,
                            text: Utf8Stream::new(),
                        });
                        slots_used.push(slot);
                    }
                    WorkMsg::Decode(dw) => {
                        // Import the handed-off KV rows into the free slot
                        // and resume the request mid-lifecycle: Admitted
                        // and the first Token were already emitted on the
                        // prefill side. `import_rows` rolls its block
                        // allocations back on failure, so the session
                        // stays consistent without a rebuild.
                        match session.import_rows(slot, &dw.seg, dw.item.max_new, dw.item.stop) {
                            Ok(()) => {
                                active[slot] = Some(ActiveItem {
                                    item: dw.item,
                                    admitted: dw.admitted,
                                    cohort: dw.cohort,
                                    prefill_seconds: dw.prefill_seconds,
                                    decode_start: Instant::now(),
                                    emitted: dw.emitted,
                                    text: dw.text,
                                });
                            }
                            Err(e) => {
                                // `import_rows` rolled its allocations
                                // back, so the session is consistent —
                                // no rebuild; retry the import on
                                // another decode replica.
                                let message = format!("kv import failed: {e:#}");
                                crate::log_error!("replica {rid} {message}");
                                router.report_fault(rid);
                                retry_decode(dw, &message);
                            }
                        }
                    }
                }
            }
            if !reqs.is_empty() {
                let reqs_len = reqs.len();
                let t0 = Instant::now();
                match session.prefill(reqs) {
                    Ok(out) => {
                        let pf = t0.elapsed().as_secs_f64();
                        let end = Instant::now();
                        if adapt_speeds && pf > 0.0 {
                            // Fold the measured prefill throughput
                            // (prompt tokens per second) into the
                            // prefill-side speed EWMA. Hybrid routing
                            // never reads the prefill view, so the fused
                            // path is unaffected.
                            router.observe_phase_rate(
                                ServePhase::Prefill,
                                rid,
                                (reqs_len * prompt_len) as f64 / pf,
                            );
                        }
                        for &slot in &slots_used {
                            if let Some(a) = active[slot].as_mut() {
                                a.prefill_seconds = pf;
                                a.decode_start = end;
                            }
                        }
                        for &(slot, tok) in &out.tokens {
                            if let Some(a) = active[slot].as_mut() {
                                let last = out.finished.iter().any(|&(s, _)| s == slot);
                                emit_token(a, tok, last);
                            }
                        }
                        for (slot, tokens) in out.finished {
                            if let Some(a) = active[slot].take() {
                                deliver(a, tokens);
                            }
                        }
                        // ---- prefill-only: export and hand off --------
                        // Every row still active after prefill leaves
                        // this replica: export its KV rows, free the
                        // slot, and send the segment to a decode-capable
                        // replica priced by decode-side speeds. Rows that
                        // finished at the first token were delivered
                        // above and have nothing to hand off.
                        if role == PhaseRole::Prefill {
                            for &slot in &slots_used {
                                let Some(a) = active[slot].take() else { continue };
                                let seg = match session.export_rows(slot) {
                                    Ok(seg) => seg,
                                    Err(e) => {
                                        let message = format!("kv export failed: {e:#}");
                                        crate::log_error!("replica {rid} {message}");
                                        fail_or_retry(a, &message);
                                        rebuild = Some(message);
                                        continue;
                                    }
                                };
                                if let Err(e) = session.cancel_slot(slot) {
                                    let message =
                                        format!("hand-off failed releasing slot {slot}: {e:#}");
                                    crate::log_error!("replica {rid} {message}");
                                    rebuild = Some(message);
                                }
                                let mut dw = DecodeWork {
                                    item: a.item,
                                    seg,
                                    admitted: a.admitted,
                                    cohort: a.cohort,
                                    prefill_seconds: a.prefill_seconds,
                                    emitted: a.emitted,
                                    text: a.text,
                                };
                                let mut dead: Vec<usize> = Vec::new();
                                loop {
                                    let Some(target) =
                                        router.route_phase(ServePhase::Decode, &dead)
                                    else {
                                        // No decode replica routable right
                                        // now (quarantined or gone): hand
                                        // the segment to the dispatcher,
                                        // which retries the import or
                                        // falls back to re-prefill.
                                        retry_decode(dw, "no decode-capable replica routable");
                                        break;
                                    };
                                    let Some(q) = handoff[target].as_ref() else {
                                        // Decode-capable per the roles but
                                        // no sender wired: treat as dead.
                                        router.complete(target);
                                        dead.push(target);
                                        continue;
                                    };
                                    match q.send(WorkMsg::Decode(dw)) {
                                        Ok(()) => {
                                            // The routed count moved with
                                            // the segment: release ours.
                                            router.complete(rid);
                                            break;
                                        }
                                        Err(SendError(WorkMsg::Decode(returned))) => {
                                            router.complete(target);
                                            dead.push(target);
                                            dw = returned;
                                        }
                                        Err(SendError(returned)) => {
                                            // Unreachable (a Decode send
                                            // returns a Decode); fail the
                                            // request cleanly.
                                            router.complete(target);
                                            fail_item(
                                                returned.into_item(),
                                                ServiceError::AllReplicasDown,
                                            );
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                    }
                    Err(e) => {
                        let message = format!("prefill failed: {e:#}");
                        crate::log_error!("replica {rid} {message}");
                        for slot in slots_used {
                            if let Some(a) = active[slot].take() {
                                fail_or_retry(a, &message);
                            }
                        }
                        // A failed prefill may leave partially-written
                        // slots behind: rebuild so the pool stays clean
                        // (the rebuild block reports the fault).
                        rebuild = Some(message);
                    }
                }
            }
        }

        // ---- one decode iteration for every in-flight row -------------
        // Plain sessions emit one token per active row; a speculative
        // round emits 1 to k+1 per row (in stream order).
        if session.active() > 0 {
            let t0 = Instant::now();
            match session.step() {
                Ok(out) => {
                    if adapt_speeds {
                        // Fold the measured decode throughput (emitted
                        // tokens per second — net of speculation) into the
                        // router's per-replica speed EWMA.
                        let dt = t0.elapsed().as_secs_f64();
                        if dt > 0.0 && !out.tokens.is_empty() {
                            router.observe_rate(rid, out.tokens.len() as f64 / dt);
                        }
                    }
                    for (i, &(slot, tok)) in out.tokens.iter().enumerate() {
                        if let Some(a) = active[slot].as_mut() {
                            // `last` only on the row's final token this
                            // iteration — a speculative round may stream
                            // several for one slot before it retires.
                            let last = out.finished.iter().any(|&(s, _)| s == slot)
                                && !out.tokens[i + 1..].iter().any(|&(s, _)| s == slot);
                            emit_token(a, tok, last);
                        }
                    }
                    for (slot, tokens) in out.finished {
                        if let Some(a) = active[slot].take() {
                            deliver(a, tokens);
                        }
                    }
                }
                Err(e) => {
                    let message = format!("decode failed: {e:#}");
                    crate::log_error!("replica {rid} {message}");
                    rebuild = Some(message);
                }
            }
        }

        // ---- publish per-iteration KV stats as deltas -----------------
        let used = session.kv_blocks_used() as u64;
        if used >= kv_used_last {
            counters.kv_blocks_used.fetch_add(used - kv_used_last, Ordering::Relaxed);
        } else {
            counters.kv_blocks_used.fetch_sub(kv_used_last - used, Ordering::Relaxed);
        }
        kv_used_last = used;
        let hits = session.prefix_cache_hits();
        counters.prefix_cache_hits.fetch_add(hits - kv_hits_last, Ordering::Relaxed);
        kv_hits_last = hits;
        let misses = session.prefix_cache_misses();
        counters.prefix_cache_misses.fetch_add(misses - kv_misses_last, Ordering::Relaxed);
        kv_misses_last = misses;
        let skips = session.prefill_skips() as u64;
        counters.prefill_skips.fetch_add(skips - kv_skips_last, Ordering::Relaxed);
        kv_skips_last = skips;
        let ss = session.spec_stats();
        counters.spec_rounds.fetch_add(ss.rounds - spec_last.rounds, Ordering::Relaxed);
        counters.spec_proposed.fetch_add(ss.proposed - spec_last.proposed, Ordering::Relaxed);
        counters.spec_accepted.fetch_add(ss.accepted - spec_last.accepted, Ordering::Relaxed);
        spec_last = ss;

        let comm = session.take_comm();
        if comm != CommStats::default() {
            let _ = comm_tx.send(comm);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::plan_from_strategy;
    use super::super::server::HttpServer;
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    fn fixture_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ref_demo")
    }

    fn one_replica_config() -> ServiceConfig {
        ServiceConfig {
            artifacts_dir: fixture_dir(),
            backend: BackendKind::Reference,
            replicas: vec![plan_from_strategy(&[1], &[2]).unwrap()],
            batch: BatchPolicy { max_batch: 2, window: Duration::from_millis(5), continuous: true },
            route: RoutePolicy::LeastLoaded,
            speeds: None,
            prefill_speeds: None,
            roles: Vec::new(),
            adapt_speeds: true,
            max_new_tokens: 4,
            stop_token: None,
            kv: KvPolicy::default(),
            spec: None,
            faults: FaultPolicy::default(),
        }
    }

    fn get(addr: std::net::SocketAddr, path: &str) -> String {
        let mut conn = TcpStream::connect(addr).unwrap();
        write!(conn, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        resp
    }

    /// Regression for the mutex-poisoning cascade: a thread that panics
    /// while holding the comm-stat locks must not take down
    /// `comm_stats()` — and with it `/healthz` and `/metrics`, which
    /// run on unrelated handler threads.
    #[test]
    fn panicked_lock_holder_leaves_healthz_and_metrics_serving() {
        let service = Arc::new(HexGenService::start(one_replica_config()).unwrap());

        let svc = service.clone();
        let died = std::thread::spawn(move || {
            // Rank order: comm_rx (20) before comm_total (30).
            let _rx = svc.comm_rx.lock();
            let _total = svc.comm_total.lock();
            panic!("deliberate panic while holding the comm locks");
        })
        .join();
        assert!(died.is_err(), "the helper thread must have panicked");

        // Both locks are now poisoned; comm_stats must recover, not
        // propagate.
        let _ = service.comm_stats();

        let server = HttpServer::serve(service.clone(), "127.0.0.1:0").unwrap();
        for path in ["/healthz", "/metrics"] {
            let resp = get(server.addr(), path);
            assert!(resp.starts_with("HTTP/1.1 200"), "{path} after poison: {resp}");
        }
        server.shutdown();

        // The serving loop itself is also still alive end to end.
        let done = service.generate("the quick brown fox", Some(2)).unwrap();
        assert_eq!(done.tokens.len(), 2);
    }
}
