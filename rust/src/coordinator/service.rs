//! Threaded serving front-end: the real (non-simulated) HexGen service.
//!
//! One worker thread per replica, each owning a thread-confined
//! [`PipelineExecutor`] over its own [`ExecutionBackend`] instance
//! (backends need not be `Send`; PJRT handles are not). The router
//! assigns requests to replicas; each worker batches its queue
//! (Appendix-D simple batching) and replies over per-request channels.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::{make_backend, tokenizer, BackendKind, Manifest, WeightStore};

use super::batcher::{collect_batch, BatchPolicy};
use super::collective::CommStats;

use super::pipeline::{PipelineExecutor, StagePlan};
use super::router::{RoutePolicy, Router};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts_dir: PathBuf,
    /// Execution backend each replica worker constructs for itself.
    pub backend: BackendKind,
    /// One stage plan per replica.
    pub replicas: Vec<Vec<StagePlan>>,
    pub batch: BatchPolicy,
    pub route: RoutePolicy,
    /// Default generation length (≤ max_seq − prompt_len).
    pub max_new_tokens: usize,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Completion {
    pub text: String,
    pub tokens: Vec<i32>,
    /// End-to-end latency (submit → response), seconds.
    pub latency: f64,
    /// Queueing delay before the batch started, seconds.
    pub queued: f64,
    pub replica: usize,
    pub batch_size: usize,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
}

struct WorkItem {
    prompt_tokens: Vec<i32>,
    max_new: usize,
    submitted: Instant,
    reply: Sender<Result<Completion, String>>,
}

/// Handle to a running service.
pub struct HexGenService {
    router: Arc<Router>,
    queues: Vec<Sender<WorkItem>>,
    workers: Vec<JoinHandle<()>>,
    manifest: Manifest,
    cfg: ServiceConfig,
    comm_rx: Receiver<CommStats>,
}

impl HexGenService {
    /// Start worker threads (compiling each replica's executables).
    pub fn start(cfg: ServiceConfig) -> Result<HexGenService> {
        if cfg.replicas.is_empty() {
            bail!("no replicas configured");
        }
        let manifest = Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
        let weights = Arc::new(WeightStore::load(&cfg.artifacts_dir.join("weights.bin"))?);
        let router = Arc::new(Router::new(cfg.route, cfg.replicas.len()));

        let (comm_tx, comm_rx) = channel::<CommStats>();
        let mut queues = Vec::with_capacity(cfg.replicas.len());
        let mut workers = Vec::with_capacity(cfg.replicas.len());
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        for (rid, plan) in cfg.replicas.iter().enumerate() {
            let (tx, rx) = channel::<WorkItem>();
            queues.push(tx);
            let plan = plan.clone();
            let dir = cfg.artifacts_dir.clone();
            let manifest = manifest.clone();
            let weights = weights.clone();
            let batch = cfg.batch;
            let backend = cfg.backend;
            let router = router.clone();
            let comm_tx = comm_tx.clone();
            let ready_tx = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(
                    rid, backend, dir, manifest, weights, plan, batch, rx, router, comm_tx,
                    ready_tx,
                )
            }));
        }
        // Wait until every replica compiled its pipeline (or failed).
        for _ in 0..cfg.replicas.len() {
            ready_rx
                .recv()
                .context("worker died during startup")?
                .map_err(|e| anyhow::anyhow!("replica startup failed: {e}"))?;
        }
        Ok(HexGenService { router, queues, workers, manifest, cfg, comm_rx })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn replicas(&self) -> usize {
        self.queues.len()
    }

    /// Submit a prompt; returns a receiver for the completion.
    pub fn submit(&self, prompt: &str, max_new: Option<usize>) -> Receiver<Result<Completion, String>> {
        let (reply_tx, reply_rx) = channel();
        let tokens = tokenizer::encode(prompt, self.manifest.model.prompt_len);
        let item = WorkItem {
            prompt_tokens: tokens,
            max_new: max_new.unwrap_or(self.cfg.max_new_tokens),
            submitted: Instant::now(),
            reply: reply_tx,
        };
        let replica = self.router.route();
        // Channel send only fails if the worker died; surface as error.
        if self.queues[replica].send(item).is_err() {
            let (etx, erx) = channel();
            let _ = etx.send(Err(format!("replica {replica} is down")));
            return erx;
        }
        reply_rx
    }

    /// Submit and block for the completion.
    pub fn generate(&self, prompt: &str, max_new: Option<usize>) -> Result<Completion> {
        let rx = self.submit(prompt, max_new);
        rx.recv()
            .context("service dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Drain accumulated communication stats from all workers.
    pub fn comm_stats(&self) -> CommStats {
        let mut total = CommStats::default();
        while let Ok(s) = self.comm_rx.try_recv() {
            total.merge(&s);
        }
        total
    }

    /// Shut down: close queues and join workers.
    pub fn shutdown(self) {
        drop(self.queues);
        drop(self.comm_rx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rid: usize,
    backend: BackendKind,
    dir: PathBuf,
    manifest: Manifest,
    weights: Arc<WeightStore>,
    plan: Vec<StagePlan>,
    batch: BatchPolicy,
    rx: Receiver<WorkItem>,
    router: Arc<Router>,
    comm_tx: Sender<CommStats>,
    ready_tx: Sender<Result<(), String>>,
) {
    // Thread-confined backend instance (backends need not be Send).
    let exec = match make_backend(backend, &dir, manifest, weights)
        .and_then(|be| PipelineExecutor::with_backend(be, plan))
    {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return;
        }
    };
    crate::log_info!(
        "replica {rid} ready: backend {} strategy {}",
        exec.backend().name(),
        exec.strategy_string()
    );

    while let Some(items) = collect_batch(&rx, &batch) {
        let batch_size = items.len();
        let started = Instant::now();
        let prompts: Vec<Vec<i32>> = items.iter().map(|i| i.prompt_tokens.clone()).collect();
        let max_new = items.iter().map(|i| i.max_new).max().unwrap_or(1);
        match exec.generate(&prompts, max_new) {
            Ok(result) => {
                let _ = comm_tx.send(result.comm);
                for (i, item) in items.into_iter().enumerate() {
                    let tokens = result.tokens[i].clone();
                    let completion = Completion {
                        text: tokenizer::decode(&tokens),
                        tokens,
                        latency: item.submitted.elapsed().as_secs_f64(),
                        queued: (started - item.submitted).as_secs_f64(),
                        replica: rid,
                        batch_size,
                        prefill_seconds: result.prefill_seconds,
                        decode_seconds: result.decode_seconds,
                    };
                    let _ = item.reply.send(Ok(completion));
                    router.complete(rid);
                }
            }
            Err(e) => {
                let msg = format!("replica {rid} generation failed: {e:#}");
                crate::log_error!("{msg}");
                for item in items {
                    let _ = item.reply.send(Err(msg.clone()));
                    router.complete(rid);
                }
            }
        }
    }
}

/// Convenience: wait on many submissions.
pub fn collect_all(
    rxs: Vec<Receiver<Result<Completion, String>>>,
    timeout: Duration,
) -> Vec<Result<Completion, String>> {
    rxs.into_iter()
        .map(|rx| {
            rx.recv_timeout(timeout)
                .unwrap_or_else(|e| Err(format!("timeout: {e}")))
        })
        .collect()
}
