//! Request router over model replicas.
//!
//! The task coordinator (paper Appendix C) directs each request to a
//! worker group according to the schedule. Policies: round-robin and
//! least-outstanding-work (queue depth weighted by the replica's speed).
//!
//! Speeds are **heterogeneity-aware** and live behind interior
//! mutability so the shared (post-`start`) router can keep them fresh:
//!
//! - [`Router::set_speeds`] seeds relative weights at service start —
//!   the normalized 1/cost estimates of a lowered deployment plan
//!   (Eq. 2), so a replica the scheduler expects to be 4× faster
//!   absorbs proportionally more traffic from the first request on;
//! - [`Router::observe_rate`] folds each replica's *measured* decode
//!   throughput (tokens/s) into an EWMA at runtime. Measured replicas
//!   route by their EWMA; replicas not yet measured route by their seed,
//!   calibrated onto the measured scale (mean measured/seed ratio), so
//!   relative plan estimates and absolute token rates mix consistently.
//!
//! Disaggregated serving prices the two phases **independently**: each
//! replica carries separate prefill-side and decode-side seeds (the
//! per-phase Eq. 2 estimates of a v2 plan) and separate measured EWMAs
//! (prefill tokens/s vs decode steps/s), and [`Router::route_phase`]
//! restricts the candidate set to the replicas whose
//! [`PhaseRole`] can serve the phase. The phase-less entry points
//! ([`Router::route`], [`Router::speeds`], [`Router::observe_rate`])
//! remain the decode-side view — the fused path hybrid deployments use.
//!
//! The router is also where replica **health** lives: a per-replica
//! circuit breaker (`Healthy → Quarantined(until) → HalfOpen`) fed by
//! [`Router::report_fault`]/[`Router::report_success`]. Consecutive
//! faults quarantine a replica (every routing entry point skips it), the
//! quarantine expires into a half-open state that admits exactly one
//! canary request at a time, and the canary's outcome closes or re-opens
//! the breaker. [`Router::health`] snapshots the state machine for
//! `/healthz`, `/metrics`, and `/v1/plan`.

use crate::parallelism::PhaseRole;
use crate::util::sync::{locks, OrderedMutex, OrderedMutexGuard};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// EWMA smoothing factor for measured decode throughput.
const SPEED_EWMA_ALPHA: f64 = 0.2;

/// Wall-clock age a replica's measured EWMA survives without a fresh
/// sample before it starts decaying back toward the plan seed. An idle
/// replica stops reporting, and its last measurement — possibly taken
/// under transient load — would otherwise price it forever.
const SPEED_STALE_AFTER: Duration = Duration::from_secs(10);
/// Time constant (seconds) of the exponential decay a stale measurement
/// follows toward its seed-calibrated anchor: after `dt` seconds of
/// routing activity it has closed `1 − exp(−dt/τ)` of the gap. Together
/// with [`SPEED_STALE_AFTER`] a stale EWMA fully reverts to seed pricing
/// in under a minute of wall-clock time — a quarantined replica
/// returning after 60 s re-enters at seed pricing, not at its pre-fault
/// EWMA.
const SPEED_STALE_TAU: f64 = 10.0;
/// Once a stale measurement is within this fraction of its anchor it is
/// dropped entirely, so the replica prices by its plan seed again (and a
/// later sample restarts the EWMA from scratch).
const SPEED_STALE_SNAP: f64 = 0.01;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Fewest estimated outstanding work units (queue depth ÷ speed).
    LeastLoaded,
}

/// The serving phase a request needs a replica for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServePhase {
    Prefill,
    Decode,
}

impl ServePhase {
    fn served_by(self, role: PhaseRole) -> bool {
        match self {
            ServePhase::Prefill => role.can_prefill(),
            ServePhase::Decode => role.can_decode(),
        }
    }
}

/// One phase's speed accounting: relative seeds and measured EWMAs.
#[derive(Debug)]
struct PhaseSpeeds {
    /// Relative seed weight per replica (1.0 = baseline).
    seed: Vec<f64>,
    /// EWMA of measured throughput; `None` until the replica reports
    /// its first measurement.
    measured: Vec<Option<f64>>,
    /// When the replica last reported a sample; drives the wall-clock
    /// staleness decay of [`Self::tick_at`].
    last_sample: Vec<Option<Instant>>,
    /// When [`Self::tick_at`] last ran, for the decay's elapsed time.
    last_tick: Option<Instant>,
}

impl PhaseSpeeds {
    fn new(replicas: usize) -> PhaseSpeeds {
        PhaseSpeeds {
            seed: vec![1.0; replicas],
            measured: vec![None; replicas],
            last_sample: vec![None; replicas],
            last_tick: None,
        }
    }

    /// Mean measured/seed ratio over measured replicas: the scale that
    /// maps relative plan seeds onto absolute measured rates.
    fn calibration(&self) -> f64 {
        let ratios: Vec<f64> = self
            .measured
            .iter()
            .zip(&self.seed)
            .filter_map(|(m, &s)| m.map(|m| m / s))
            .collect();
        if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        }
    }

    /// Effective speeds: the measured EWMA where available, otherwise
    /// the seed calibrated onto the measured scale (mean measured/seed
    /// ratio over measured replicas).
    fn effective(&self) -> Vec<f64> {
        let calib = self.calibration();
        self.measured.iter().zip(&self.seed).map(|(m, &s)| m.unwrap_or(s * calib)).collect()
    }

    fn observe_at(&mut self, replica: usize, rate: f64, now: Instant) {
        self.measured[replica] = Some(match self.measured[replica] {
            None => rate,
            Some(prev) => (1.0 - SPEED_EWMA_ALPHA) * prev + SPEED_EWMA_ALPHA * rate,
        });
        self.last_sample[replica] = Some(now);
    }

    /// Age every measurement by the wall-clock time since the last tick.
    /// A replica whose last sample is older than [`SPEED_STALE_AFTER`]
    /// decays toward its seed-calibrated anchor (what [`Self::effective`]
    /// would price an *unmeasured* replica at) with time constant
    /// [`SPEED_STALE_TAU`], and snaps back to pure seed pricing once it
    /// gets close — so a replica idled (or quarantined) long enough
    /// routes by the plan estimate again instead of by a measurement
    /// taken under a load pattern that no longer exists.
    fn tick_at(&mut self, now: Instant) {
        let Some(prev) = self.last_tick else {
            self.last_tick = Some(now);
            return;
        };
        self.last_tick = Some(now);
        let dt = now.saturating_duration_since(prev).as_secs_f64();
        if dt <= 0.0 {
            return;
        }
        let gap_closed = 1.0 - (-dt / SPEED_STALE_TAU).exp();
        let calib = self.calibration();
        for i in 0..self.measured.len() {
            let Some(m) = self.measured[i] else { continue };
            let age = self.last_sample[i].map_or(Duration::MAX, |s| now.saturating_duration_since(s));
            if age <= SPEED_STALE_AFTER {
                continue;
            }
            let anchor = self.seed[i] * calib;
            let next = m + (anchor - m) * gap_closed;
            if (next - anchor).abs() <= SPEED_STALE_SNAP * anchor.abs() {
                self.measured[i] = None;
                self.last_sample[i] = None;
            } else {
                self.measured[i] = Some(next);
            }
        }
    }
}

/// Circuit-breaker thresholds for the per-replica health state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerPolicy {
    /// Consecutive faults (no intervening success) that trip a replica
    /// from healthy to quarantined.
    pub consecutive_faults: u32,
    /// How long a tripped replica stays excluded from routing before the
    /// breaker goes half-open.
    pub quarantine: Duration,
    /// How long a half-open breaker waits for its canary's verdict
    /// before admitting a replacement canary (a lost canary — e.g. its
    /// client hung up — must not wedge the replica half-open forever).
    pub probe_timeout: Duration,
}

impl Default for BreakerPolicy {
    fn default() -> BreakerPolicy {
        BreakerPolicy {
            consecutive_faults: 3,
            quarantine: Duration::from_secs(10),
            probe_timeout: Duration::from_secs(60),
        }
    }
}

/// Externally visible breaker state, surfaced in `/healthz`, `/metrics`,
/// and `/v1/plan`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    Healthy,
    Quarantined,
    HalfOpen,
}

impl ReplicaHealth {
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaHealth::Healthy => "healthy",
            ReplicaHealth::Quarantined => "quarantined",
            ReplicaHealth::HalfOpen => "half_open",
        }
    }
}

/// Internal breaker state (behind the router's ranked mutex).
#[derive(Debug, Clone, Copy)]
enum BreakerState {
    /// Serving normally; counts consecutive faults toward the trip
    /// threshold.
    Closed { consecutive: u32 },
    /// Quarantined: excluded from routing until the deadline.
    Open { until: Instant },
    /// Quarantine expired: admit one canary request at a time (`probe`
    /// is when the in-flight canary was routed, `None` if none is out).
    HalfOpen { probe: Option<Instant> },
}

impl BreakerState {
    /// Whether routing may place a request on this replica now, applying
    /// the timed `Open → HalfOpen` transition and the lost-canary
    /// re-arm in passing.
    fn admits(&mut self, now: Instant, policy: &BreakerPolicy) -> bool {
        match *self {
            BreakerState::Closed { .. } => true,
            BreakerState::Open { until } => {
                if now >= until {
                    *self = BreakerState::HalfOpen { probe: None };
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen { probe: None } => true,
            BreakerState::HalfOpen { probe: Some(sent) } => {
                if now.saturating_duration_since(sent) >= policy.probe_timeout {
                    *self = BreakerState::HalfOpen { probe: None };
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record that a request was just routed here: a half-open breaker
    /// marks its canary in flight.
    fn note_routed(&mut self, now: Instant) {
        if let BreakerState::HalfOpen { probe } = self {
            if probe.is_none() {
                *probe = Some(now);
            }
        }
    }

    fn health(&self, now: Instant) -> ReplicaHealth {
        match *self {
            BreakerState::Closed { .. } => ReplicaHealth::Healthy,
            // An expired quarantine *is* half-open — `admits` just has
            // not run yet; report the state routing would see.
            BreakerState::Open { until } => {
                if now >= until {
                    ReplicaHealth::HalfOpen
                } else {
                    ReplicaHealth::Quarantined
                }
            }
            BreakerState::HalfOpen { .. } => ReplicaHealth::HalfOpen,
        }
    }
}

/// Per-replica speed and role accounting (behind the router's ranked
/// mutex).
#[derive(Debug)]
struct SpeedState {
    /// Decode-side speeds — what the phase-less API reads and writes.
    decode: PhaseSpeeds,
    /// Prefill-side speeds.
    prefill: PhaseSpeeds,
    /// Phase role per replica (all-[`PhaseRole::Hybrid`] until
    /// [`Router::set_roles`]).
    roles: Vec<PhaseRole>,
    /// Per-replica circuit breaker.
    breakers: Vec<BreakerState>,
    breaker_policy: BreakerPolicy,
}

/// Shared per-replica load accounting.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    outstanding: Vec<Arc<AtomicUsize>>,
    speeds: OrderedMutex<SpeedState>,
    rr_next: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy, replicas: usize) -> Router {
        assert!(replicas > 0);
        Router {
            policy,
            outstanding: (0..replicas).map(|_| Arc::new(AtomicUsize::new(0))).collect(),
            speeds: OrderedMutex::new(
                locks::ROUTER_SPEEDS,
                "router.speeds",
                SpeedState {
                    decode: PhaseSpeeds::new(replicas),
                    prefill: PhaseSpeeds::new(replicas),
                    roles: vec![PhaseRole::Hybrid; replicas],
                    breakers: vec![BreakerState::Closed { consecutive: 0 }; replicas],
                    breaker_policy: BreakerPolicy::default(),
                },
            ),
            rr_next: AtomicUsize::new(0),
        }
    }

    /// Seed relative speed weights (e.g. normalized 1/cost-estimate per
    /// replica from a lowered deployment plan) for **both** phases — the
    /// fused seeding hybrid deployments use. Callable on the shared
    /// router at any time; measured EWMAs, where present, keep
    /// precedence over seeds.
    pub fn set_speeds(&self, speed: Vec<f64>) {
        assert_eq!(speed.len(), self.outstanding.len());
        assert!(speed.iter().all(|&s| s.is_finite() && s > 0.0));
        let mut st = self.state();
        st.prefill.seed.clone_from(&speed);
        st.decode.seed = speed;
    }

    /// Seed one phase's relative speed weights independently (the
    /// per-phase Eq. 2 estimates of a v2 plan).
    pub fn set_phase_speeds(&self, phase: ServePhase, speed: Vec<f64>) {
        assert_eq!(speed.len(), self.outstanding.len());
        assert!(speed.iter().all(|&s| s.is_finite() && s > 0.0));
        let mut st = self.state();
        match phase {
            ServePhase::Prefill => st.prefill.seed = speed,
            ServePhase::Decode => st.decode.seed = speed,
        }
    }

    /// Declare each replica's phase role. [`Self::route_phase`] skips
    /// replicas that cannot serve the requested phase; the phase-less
    /// [`Self::route`]/[`Self::route_excluding`] ignore roles (the fused
    /// path of an all-hybrid deployment).
    pub fn set_roles(&self, roles: Vec<PhaseRole>) {
        assert_eq!(roles.len(), self.outstanding.len());
        self.state().roles = roles;
    }

    /// Fold a measured **decode** throughput sample (tokens/s) for
    /// `replica` into its EWMA. Non-finite or non-positive samples are
    /// ignored.
    pub fn observe_rate(&self, replica: usize, tokens_per_sec: f64) {
        self.observe_phase_rate(ServePhase::Decode, replica, tokens_per_sec);
    }

    /// Fold a measured throughput sample for one phase (prefill
    /// tokens/s or decode tokens/s) into that phase's EWMA. Non-finite
    /// or non-positive samples are ignored.
    pub fn observe_phase_rate(&self, phase: ServePhase, replica: usize, rate: f64) {
        if !rate.is_finite() || rate <= 0.0 {
            return;
        }
        let now = Instant::now();
        let mut st = self.state();
        match phase {
            ServePhase::Prefill => st.prefill.observe_at(replica, rate, now),
            ServePhase::Decode => st.decode.observe_at(replica, rate, now),
        }
    }

    /// Effective per-replica **decode** speeds the phase-less policy
    /// routes by: the measured EWMA where available, otherwise the seed
    /// calibrated onto the measured scale (mean measured/seed ratio over
    /// measured replicas).
    pub fn speeds(&self) -> Vec<f64> {
        self.phase_speeds(ServePhase::Decode)
    }

    /// Effective per-replica speeds for one phase (same seed/EWMA
    /// blending as [`Self::speeds`], per phase).
    pub fn phase_speeds(&self, phase: ServePhase) -> Vec<f64> {
        let st = self.state();
        match phase {
            ServePhase::Prefill => st.prefill.effective(),
            ServePhase::Decode => st.decode.effective(),
        }
    }

    /// Phase role per replica (all-hybrid until [`Self::set_roles`]).
    pub fn roles(&self) -> Vec<PhaseRole> {
        self.state().roles.clone()
    }

    fn state(&self) -> OrderedMutexGuard<'_, SpeedState> {
        self.speeds.lock()
    }

    pub fn replicas(&self) -> usize {
        self.outstanding.len()
    }

    /// Pick a replica for a new request and record the assignment.
    /// Returns `None` when no replica is admissible (every breaker open)
    /// — the caller must surface that as `AllReplicasDown`, never queue
    /// onto a known-dead replica.
    pub fn route(&self) -> Option<usize> {
        self.route_excluding(&[])
    }

    /// Pick a replica, skipping `excluded` (replicas observed dead by the
    /// caller). Returns `None` when every replica is excluded. The caller
    /// must pair each successful pick with [`Self::complete`] — including
    /// when the hand-off to the replica fails afterwards, or the load
    /// counter leaks and the policy keeps favouring a dead replica.
    /// Roles are ignored: this is the fused path of an all-hybrid
    /// deployment (it prices by decode-side speeds).
    pub fn route_excluding(&self, excluded: &[usize]) -> Option<usize> {
        self.route_filtered(excluded, None)
    }

    /// Pick a replica to serve `phase`, skipping `excluded` and every
    /// replica whose [`PhaseRole`] cannot serve the phase, pricing
    /// candidates by that phase's speeds. Returns `None` when no
    /// eligible replica remains. Pair successful picks with
    /// [`Self::complete`], exactly as with [`Self::route_excluding`].
    pub fn route_phase(&self, phase: ServePhase, excluded: &[usize]) -> Option<usize> {
        self.route_filtered(excluded, Some(phase))
    }

    fn route_filtered(&self, excluded: &[usize], phase: Option<ServePhase>) -> Option<usize> {
        let now = Instant::now();
        let n = self.outstanding.len();
        // One lock section: age the priced phase's measurements (replicas
        // that keep routing without reporting decay back toward their
        // plan seeds, [`PhaseSpeeds::tick_at`]; the phase-less path
        // prices by decode-side speeds, so it ages the decode side) and
        // snapshot what each replica's breaker admits right now.
        let (admit, roles) = {
            let mut st = self.state();
            match phase {
                Some(ServePhase::Prefill) => st.prefill.tick_at(now),
                _ => st.decode.tick_at(now),
            }
            let policy = st.breaker_policy;
            let admit: Vec<bool> =
                (0..n).map(|i| st.breakers[i].admits(now, &policy)).collect();
            let roles = match phase {
                Some(_) => st.roles.clone(),
                None => Vec::new(),
            };
            (admit, roles)
        };
        let eligible = |i: usize| {
            admit[i]
                && !excluded.contains(&i)
                && phase.map_or(true, |p| p.served_by(roles[i]))
        };
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let mut pick = None;
                for _ in 0..n {
                    let c = self.rr_next.fetch_add(1, Ordering::Relaxed) % n;
                    if eligible(c) {
                        pick = Some(c);
                        break;
                    }
                }
                pick?
            }
            RoutePolicy::LeastLoaded => {
                let speed = match phase {
                    Some(p) => self.phase_speeds(p),
                    None => self.speeds(),
                };
                let mut best = None;
                let mut best_cost = f64::INFINITY;
                for (i, o) in self.outstanding.iter().enumerate() {
                    if !eligible(i) {
                        continue;
                    }
                    let cost = (o.load(Ordering::Relaxed) as f64 + 1.0) / speed[i];
                    if cost < best_cost {
                        best_cost = cost;
                        best = Some(i);
                    }
                }
                best?
            }
        };
        // Re-lock to mark a half-open canary in flight. (Two concurrent
        // routes can both see `probe: None` and each send a canary —
        // tolerable: the breaker still re-opens on the first failure.)
        self.state().breakers[r].note_routed(now);
        self.outstanding[r].fetch_add(1, Ordering::Relaxed);
        Some(r)
    }

    /// Per-replica `(outstanding requests, effective speed)` snapshot —
    /// what the serving front-end reports as queue depths and routing
    /// weights on `GET /metrics`.
    pub fn load_snapshot(&self) -> Vec<(usize, f64)> {
        self.speeds()
            .into_iter()
            .zip(&self.outstanding)
            .map(|(s, o)| (o.load(Ordering::Relaxed), s))
            .collect()
    }

    /// Record completion of a request previously routed to `replica`.
    pub fn complete(&self, replica: usize) {
        self.outstanding[replica].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn outstanding(&self, replica: usize) -> usize {
        self.outstanding[replica].load(Ordering::Relaxed)
    }

    /// Replace the circuit-breaker thresholds (existing breaker states
    /// are kept; the new thresholds apply from the next event).
    pub fn set_breaker_policy(&self, policy: BreakerPolicy) {
        self.state().breaker_policy = policy;
    }

    /// Record a replica fault. Consecutive faults reaching the policy
    /// threshold trip the breaker open (quarantine); a fault observed
    /// while half-open — the canary failed — re-opens it immediately.
    pub fn report_fault(&self, replica: usize) {
        let now = Instant::now();
        let mut st = self.state();
        let policy = st.breaker_policy;
        let b = &mut st.breakers[replica];
        match *b {
            BreakerState::Closed { consecutive } => {
                let c = consecutive.saturating_add(1);
                *b = if c >= policy.consecutive_faults {
                    BreakerState::Open { until: now + policy.quarantine }
                } else {
                    BreakerState::Closed { consecutive: c }
                };
            }
            BreakerState::HalfOpen { .. } => {
                *b = BreakerState::Open { until: now + policy.quarantine };
            }
            // Already quarantined (e.g. straggler faults from rows that
            // were in flight when the breaker tripped): keep the
            // original deadline rather than extending it per fault.
            BreakerState::Open { .. } => {}
        }
    }

    /// Record a request served to completion by `replica`: resets the
    /// consecutive-fault count and closes a half-open breaker (the
    /// canary came back healthy).
    pub fn report_success(&self, replica: usize) {
        self.state().breakers[replica] = BreakerState::Closed { consecutive: 0 };
    }

    /// Per-replica breaker state snapshot for `/healthz`, `/metrics`,
    /// and `/v1/plan`.
    pub fn health(&self) -> Vec<ReplicaHealth> {
        let now = Instant::now();
        self.state().breakers.iter().map(|b| b.health(now)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.route().unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = r.route().unwrap();
        let b = r.route().unwrap();
        assert_ne!(a, b, "second request goes to the idle replica");
        r.complete(a);
        assert_eq!(r.route(), Some(a));
    }

    #[test]
    fn least_loaded_respects_speed() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        // replica 0 is 4× faster: it should absorb the first requests
        // before replica 1 gets one ((q+1)/speed tie at the 5th pick).
        let picks: Vec<usize> = (0..5).map(|_| r.route().unwrap()).collect();
        assert!(picks[..4].iter().all(|&p| p == 0), "{picks:?}");
        assert_eq!(picks[4], 1, "{picks:?}");
    }

    #[test]
    fn set_speeds_works_on_the_shared_router() {
        // Regression: set_speeds used to take &mut self, making it
        // uncallable once the router was shared behind an Arc (as the
        // service does after start). Interior mutability fixes that.
        let r = Arc::new(Router::new(RoutePolicy::LeastLoaded, 2));
        let r2 = r.clone();
        r2.set_speeds(vec![2.0, 1.0]);
        assert_eq!(r.speeds(), vec![2.0, 1.0]);
    }

    #[test]
    fn speed_skews_traffic_proportionally() {
        // With nothing completing, queue depths equilibrate to the speed
        // ratio: a 4×-speed replica holds ~4× the outstanding work.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        for _ in 0..20 {
            r.route().unwrap();
        }
        let (fast, slow) = (r.outstanding(0), r.outstanding(1));
        assert_eq!(fast + slow, 20);
        assert!(fast >= 3 * slow, "fast {fast} vs slow {slow}");
        assert!(slow >= 1, "slow replica must not starve outright: {fast}/{slow}");
    }

    #[test]
    fn observed_rates_override_seeds() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.observe_rate(0, 40.0);
        r.observe_rate(1, 10.0);
        let s = r.speeds();
        assert!((s[0] - 40.0).abs() < 1e-9 && (s[1] - 10.0).abs() < 1e-9, "{s:?}");
        // 40 vs 10 tok/s: the fast replica absorbs the first picks.
        let picks: Vec<usize> = (0..4).map(|_| r.route().unwrap()).collect();
        assert!(picks.iter().all(|&p| p == 0), "{picks:?}");
    }

    #[test]
    fn observe_rate_smooths_with_ewma() {
        let r = Router::new(RoutePolicy::LeastLoaded, 1);
        r.observe_rate(0, 10.0);
        r.observe_rate(0, 20.0);
        // 0.8·10 + 0.2·20 = 12
        assert!((r.speeds()[0] - 12.0).abs() < 1e-9, "{:?}", r.speeds());
        // junk samples are ignored
        r.observe_rate(0, f64::INFINITY);
        r.observe_rate(0, -1.0);
        r.observe_rate(0, 0.0);
        assert!((r.speeds()[0] - 12.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_bridges_seeded_and_measured_replicas() {
        // Seeds are relative (2:1); only replica 0 has measured 10 tok/s.
        // The unmeasured replica's seed is scaled by the measured/seed
        // ratio (10/2 = 5), preserving the planned 2:1 relation.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![2.0, 1.0]);
        r.observe_rate(0, 10.0);
        let s = r.speeds();
        assert!((s[0] - 10.0).abs() < 1e-9, "{s:?}");
        assert!((s[1] - 5.0).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn route_excluding_skips_dead_replicas() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..4).map(|_| r.route_excluding(&[1]).unwrap()).collect();
        assert!(picks.iter().all(|&p| p != 1), "{picks:?}");
        assert_eq!(r.route_excluding(&[0, 1, 2]), None);

        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        for _ in 0..3 {
            assert_eq!(r.route_excluding(&[0]), Some(1));
        }
        assert_eq!(r.outstanding(1), 3);
        assert_eq!(r.outstanding(0), 0);
    }

    #[test]
    fn failed_handoff_releases_the_count() {
        // Regression for the dead-replica load leak: a route() whose
        // queue send fails must be paired with complete(), restoring the
        // counter so the policy does not keep favouring the dead replica.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let dead = r.route().unwrap();
        r.complete(dead); // hand-off failed: release
        assert_eq!(r.outstanding(dead), 0);
        let alive = r.route_excluding(&[dead]).unwrap();
        assert_ne!(alive, dead);
    }

    #[test]
    fn load_snapshot_pairs_depth_with_speed() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        r.route().unwrap();
        let snap = r.load_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0], (1, 4.0));
        assert_eq!(snap[1], (0, 1.0));
    }

    #[test]
    fn panicked_holder_does_not_poison_routing() {
        // Regression for the poisoning cascade: a worker thread dying
        // while holding the speed lock must not take the router (and
        // with it every handler thread) down.
        let r = Arc::new(Router::new(RoutePolicy::LeastLoaded, 2));
        let r2 = r.clone();
        let died = std::thread::spawn(move || {
            let _guard = r2.speeds.lock();
            panic!("worker died mid-update");
        })
        .join();
        assert!(died.is_err());
        r.set_speeds(vec![2.0, 1.0]);
        assert_eq!(r.speeds(), vec![2.0, 1.0]);
        let _ = r.route();
    }

    #[test]
    fn route_phase_respects_roles() {
        let r = Router::new(RoutePolicy::LeastLoaded, 3);
        r.set_roles(vec![PhaseRole::Prefill, PhaseRole::Decode, PhaseRole::Hybrid]);
        // Prefill requests never land on the decode-only replica, decode
        // requests never on the prefill-only one; hybrid serves both.
        for _ in 0..6 {
            let p = r.route_phase(ServePhase::Prefill, &[]).unwrap();
            assert_ne!(p, 1, "decode-only replica took a prefill");
            let d = r.route_phase(ServePhase::Decode, &[]).unwrap();
            assert_ne!(d, 0, "prefill-only replica took a decode");
        }
        // Excluding the hybrid leaves exactly one candidate per phase.
        assert_eq!(r.route_phase(ServePhase::Prefill, &[2]), Some(0));
        assert_eq!(r.route_phase(ServePhase::Decode, &[2]), Some(1));
        // No eligible replica left: the pick must fail, not fall back.
        assert_eq!(r.route_phase(ServePhase::Prefill, &[0, 2]), None);

        let rr = Router::new(RoutePolicy::RoundRobin, 2);
        rr.set_roles(vec![PhaseRole::Prefill, PhaseRole::Decode]);
        for _ in 0..4 {
            assert_eq!(rr.route_phase(ServePhase::Decode, &[]), Some(1));
        }
    }

    #[test]
    fn phase_speeds_are_priced_independently() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        // Replica 0 is the fast prefiller, replica 1 the fast decoder.
        r.set_phase_speeds(ServePhase::Prefill, vec![4.0, 1.0]);
        r.set_phase_speeds(ServePhase::Decode, vec![1.0, 4.0]);
        assert_eq!(r.phase_speeds(ServePhase::Prefill), vec![4.0, 1.0]);
        assert_eq!(r.speeds(), vec![1.0, 4.0], "phase-less view is the decode side");
        let p = r.route_phase(ServePhase::Prefill, &[]).unwrap();
        r.complete(p);
        assert_eq!(p, 0, "prefill prices by prefill speeds");
        let d = r.route_phase(ServePhase::Decode, &[]).unwrap();
        r.complete(d);
        assert_eq!(d, 1, "decode prices by decode speeds");

        // Per-phase EWMAs stay separate: a prefill sample must not
        // disturb the decode estimate.
        r.observe_phase_rate(ServePhase::Prefill, 1, 100.0);
        assert_eq!(r.phase_speeds(ServePhase::Prefill)[1], 100.0);
        assert_eq!(r.speeds(), vec![1.0, 4.0]);
    }

    #[test]
    fn set_speeds_seeds_both_phases() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![3.0, 1.0]);
        assert_eq!(r.phase_speeds(ServePhase::Prefill), vec![3.0, 1.0]);
        assert_eq!(r.phase_speeds(ServePhase::Decode), vec![3.0, 1.0]);
        assert_eq!(r.roles(), vec![PhaseRole::Hybrid; 2], "default roles are hybrid");
    }

    #[test]
    fn stale_measurements_decay_back_to_plan_seeds() {
        // Idle-then-resume: replica 1 reports one anomalously slow sample
        // (say, a transient load spike) and then goes quiet for a minute
        // of wall-clock time while replica 0 keeps reporting. Without
        // decay the stale 1 tok/s would price replica 1 forever. Driven
        // at the PhaseSpeeds level with synthetic timestamps so the test
        // does not sleep through the real decay horizon.
        let t0 = Instant::now();
        let mut p = PhaseSpeeds::new(2);
        p.seed = vec![2.0, 1.0];
        p.observe_at(0, 20.0, t0);
        p.observe_at(1, 1.0, t0);
        assert!((p.effective()[1] - 1.0).abs() < 1e-9, "{:?}", p.effective());

        // Within the staleness window the measurement is untouched.
        p.tick_at(t0);
        p.tick_at(t0 + Duration::from_secs(5));
        assert!((p.effective()[1] - 1.0).abs() < 1e-9, "decayed too early: {:?}", p.effective());

        // Past the window it decays toward the seed-calibrated anchor
        // (seed 1 × the 20/2 measured scale of replica 0 = 10 tok/s)
        // and snaps back to pure seed pricing well within two minutes.
        for s in 6..=120 {
            let now = t0 + Duration::from_secs(s);
            p.observe_at(0, 20.0, now); // replica 0 stays fresh
            p.tick_at(now);
        }
        let e = p.effective();
        assert!((e[0] - 20.0).abs() < 1e-9, "fresh replica must not decay: {e:?}");
        assert!((e[1] - 10.0).abs() < 1e-9, "stale replica must revert to its seed: {e:?}");
        assert!(p.measured[1].is_none(), "snapped back to pure seed pricing");

        // Resume: a fresh sample takes over immediately and restarts the
        // EWMA from the new rate, not from the decayed remnant.
        p.observe_at(1, 30.0, t0 + Duration::from_secs(121));
        assert!((p.effective()[1] - 30.0).abs() < 1e-9, "{:?}", p.effective());
    }

    #[test]
    fn decay_is_wall_clock_not_decision_count() {
        // Hundreds of routing decisions inside the staleness window must
        // not move a measurement: only elapsed time does.
        let t0 = Instant::now();
        let mut p = PhaseSpeeds::new(2);
        p.seed = vec![2.0, 1.0];
        p.observe_at(0, 20.0, t0);
        p.observe_at(1, 1.0, t0);
        for _ in 0..500 {
            p.tick_at(t0 + Duration::from_secs(5));
        }
        assert!(
            (p.effective()[1] - 1.0).abs() < 1e-9,
            "decision count aged the EWMA: {:?}",
            p.effective()
        );
    }

    #[test]
    fn staleness_is_tracked_per_phase() {
        // Prefill routing decisions must not age decode measurements:
        // a decode-side sample stays live through any number of
        // prefill-side picks.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![2.0, 1.0]);
        r.observe_rate(1, 1.0);
        for _ in 0..200 {
            let p = r.route_phase(ServePhase::Prefill, &[]).unwrap();
            r.complete(p);
        }
        assert!(
            (r.speeds()[1] - 1.0).abs() < 1e-9,
            "prefill decisions aged the decode EWMA: {:?}",
            r.speeds()
        );
    }

    #[test]
    fn consecutive_faults_quarantine_a_replica() {
        let r = Router::new(RoutePolicy::RoundRobin, 2);
        for _ in 0..3 {
            r.report_fault(1);
        }
        assert_eq!(r.health(), vec![ReplicaHealth::Healthy, ReplicaHealth::Quarantined]);
        for _ in 0..4 {
            assert_eq!(r.route(), Some(0), "quarantined replica must not route");
            r.complete(0);
        }
        // Phase routing respects the breaker too.
        assert_eq!(r.route_phase(ServePhase::Decode, &[0]), None);
    }

    #[test]
    fn success_resets_the_consecutive_fault_count() {
        let r = Router::new(RoutePolicy::RoundRobin, 1);
        r.report_fault(0);
        r.report_fault(0);
        r.report_success(0);
        r.report_fault(0);
        r.report_fault(0);
        assert_eq!(r.health(), vec![ReplicaHealth::Healthy]);
        assert_eq!(r.route(), Some(0));
    }

    #[test]
    fn all_replicas_quarantined_routes_none() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        for replica in 0..2 {
            for _ in 0..3 {
                r.report_fault(replica);
            }
        }
        assert_eq!(r.route(), None);
        assert_eq!(r.route_excluding(&[]), None);
        assert_eq!(r.route_phase(ServePhase::Decode, &[]), None);
    }

    #[test]
    fn half_open_admits_one_canary_then_closes_or_reopens() {
        let r = Router::new(RoutePolicy::RoundRobin, 2);
        r.set_breaker_policy(BreakerPolicy {
            consecutive_faults: 1,
            quarantine: Duration::from_millis(0), // expires immediately
            probe_timeout: Duration::from_secs(60),
        });
        r.report_fault(1);
        // Quarantine already expired: half-open, one canary admitted.
        assert_eq!(r.route_excluding(&[0]), Some(1));
        assert_eq!(r.health()[1], ReplicaHealth::HalfOpen);
        // While the canary is in flight no second probe is admitted.
        assert_eq!(r.route_excluding(&[0]), None);
        // Canary succeeds: the breaker closes and traffic resumes.
        r.complete(1);
        r.report_success(1);
        assert_eq!(r.health()[1], ReplicaHealth::Healthy);
        assert_eq!(r.route_excluding(&[0]), Some(1));
        r.complete(1);

        // A failed canary re-opens the breaker for a full quarantine.
        r.set_breaker_policy(BreakerPolicy {
            consecutive_faults: 1,
            quarantine: Duration::from_secs(3600),
            probe_timeout: Duration::from_secs(60),
        });
        r.report_fault(1);
        assert_eq!(r.health()[1], ReplicaHealth::Quarantined);
        assert_eq!(r.route_excluding(&[0]), None);
    }

    #[test]
    fn lost_canary_rearms_after_probe_timeout() {
        let r = Router::new(RoutePolicy::RoundRobin, 1);
        r.set_breaker_policy(BreakerPolicy {
            consecutive_faults: 1,
            quarantine: Duration::from_millis(0),
            probe_timeout: Duration::from_millis(200),
        });
        r.report_fault(0);
        assert_eq!(r.route(), Some(0), "half-open admits the first canary");
        r.complete(0);
        assert_eq!(r.route(), None, "second canary denied while the first is out");
        // The canary never reported back (lost worker, hung client):
        // after probe_timeout a replacement canary is admitted instead of
        // wedging the replica half-open forever.
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(r.route(), Some(0));
    }

    #[test]
    fn outstanding_tracks() {
        let r = Router::new(RoutePolicy::LeastLoaded, 1);
        assert_eq!(r.outstanding(0), 0);
        r.route().unwrap();
        r.route().unwrap();
        assert_eq!(r.outstanding(0), 2);
        r.complete(0);
        assert_eq!(r.outstanding(0), 1);
    }
}
