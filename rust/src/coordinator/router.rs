//! Request router over model replicas.
//!
//! The task coordinator (paper Appendix C) directs each request to a
//! worker group according to the schedule. Policies: round-robin and
//! least-outstanding-work (queue depth weighted by the replica's speed).
//!
//! Speeds are **heterogeneity-aware** and live behind interior
//! mutability so the shared (post-`start`) router can keep them fresh:
//!
//! - [`Router::set_speeds`] seeds relative weights at service start —
//!   the normalized 1/cost estimates of a lowered deployment plan
//!   (Eq. 2), so a replica the scheduler expects to be 4× faster
//!   absorbs proportionally more traffic from the first request on;
//! - [`Router::observe_rate`] folds each replica's *measured* decode
//!   throughput (tokens/s) into an EWMA at runtime. Measured replicas
//!   route by their EWMA; replicas not yet measured route by their seed,
//!   calibrated onto the measured scale (mean measured/seed ratio), so
//!   relative plan estimates and absolute token rates mix consistently.

use crate::util::sync::{locks, OrderedMutex, OrderedMutexGuard};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// EWMA smoothing factor for measured decode throughput.
const SPEED_EWMA_ALPHA: f64 = 0.2;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Fewest estimated outstanding work units (queue depth ÷ speed).
    LeastLoaded,
}

/// Per-replica speed accounting (behind the router's ranked mutex).
#[derive(Debug)]
struct SpeedState {
    /// Relative seed weight per replica (1.0 = baseline).
    seed: Vec<f64>,
    /// EWMA of measured decode throughput (tokens/s); `None` until the
    /// replica reports its first measurement.
    measured: Vec<Option<f64>>,
}

/// Shared per-replica load accounting.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    outstanding: Vec<Arc<AtomicUsize>>,
    speeds: OrderedMutex<SpeedState>,
    rr_next: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy, replicas: usize) -> Router {
        assert!(replicas > 0);
        Router {
            policy,
            outstanding: (0..replicas).map(|_| Arc::new(AtomicUsize::new(0))).collect(),
            speeds: OrderedMutex::new(
                locks::ROUTER_SPEEDS,
                "router.speeds",
                SpeedState { seed: vec![1.0; replicas], measured: vec![None; replicas] },
            ),
            rr_next: AtomicUsize::new(0),
        }
    }

    /// Seed relative speed weights (e.g. normalized 1/cost-estimate per
    /// replica from a lowered deployment plan). Callable on the shared
    /// router at any time; measured EWMAs, where present, keep
    /// precedence over seeds.
    pub fn set_speeds(&self, speed: Vec<f64>) {
        assert_eq!(speed.len(), self.outstanding.len());
        assert!(speed.iter().all(|&s| s.is_finite() && s > 0.0));
        self.state().seed = speed;
    }

    /// Fold a measured decode throughput sample (tokens/s) for `replica`
    /// into its EWMA. Non-finite or non-positive samples are ignored.
    pub fn observe_rate(&self, replica: usize, tokens_per_sec: f64) {
        if !tokens_per_sec.is_finite() || tokens_per_sec <= 0.0 {
            return;
        }
        let mut st = self.state();
        st.measured[replica] = Some(match st.measured[replica] {
            None => tokens_per_sec,
            Some(prev) => (1.0 - SPEED_EWMA_ALPHA) * prev + SPEED_EWMA_ALPHA * tokens_per_sec,
        });
    }

    /// Effective per-replica speeds the policy routes by: the measured
    /// EWMA where available, otherwise the seed calibrated onto the
    /// measured scale (mean measured/seed ratio over measured replicas).
    pub fn speeds(&self) -> Vec<f64> {
        let st = self.state();
        let ratios: Vec<f64> = st
            .measured
            .iter()
            .zip(&st.seed)
            .filter_map(|(m, &s)| m.map(|m| m / s))
            .collect();
        let calib = if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        };
        st.measured.iter().zip(&st.seed).map(|(m, &s)| m.unwrap_or(s * calib)).collect()
    }

    fn state(&self) -> OrderedMutexGuard<'_, SpeedState> {
        self.speeds.lock()
    }

    pub fn replicas(&self) -> usize {
        self.outstanding.len()
    }

    /// Pick a replica for a new request and record the assignment.
    pub fn route(&self) -> usize {
        match self.route_excluding(&[]) {
            Some(r) => r,
            // Unreachable with nothing excluded (`new` asserts replicas
            // > 0), but a panic here would kill a handler thread; fall
            // back to replica 0 and keep the complete() pairing intact.
            None => {
                self.outstanding[0].fetch_add(1, Ordering::Relaxed);
                0
            }
        }
    }

    /// Pick a replica, skipping `excluded` (replicas observed dead by the
    /// caller). Returns `None` when every replica is excluded. The caller
    /// must pair each successful pick with [`Self::complete`] — including
    /// when the hand-off to the replica fails afterwards, or the load
    /// counter leaks and the policy keeps favouring a dead replica.
    pub fn route_excluding(&self, excluded: &[usize]) -> Option<usize> {
        let n = self.outstanding.len();
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let mut pick = None;
                for _ in 0..n {
                    let c = self.rr_next.fetch_add(1, Ordering::Relaxed) % n;
                    if !excluded.contains(&c) {
                        pick = Some(c);
                        break;
                    }
                }
                pick?
            }
            RoutePolicy::LeastLoaded => {
                let speed = self.speeds();
                let mut best = None;
                let mut best_cost = f64::INFINITY;
                for (i, o) in self.outstanding.iter().enumerate() {
                    if excluded.contains(&i) {
                        continue;
                    }
                    let cost = (o.load(Ordering::Relaxed) as f64 + 1.0) / speed[i];
                    if cost < best_cost {
                        best_cost = cost;
                        best = Some(i);
                    }
                }
                best?
            }
        };
        self.outstanding[r].fetch_add(1, Ordering::Relaxed);
        Some(r)
    }

    /// Per-replica `(outstanding requests, effective speed)` snapshot —
    /// what the serving front-end reports as queue depths and routing
    /// weights on `GET /metrics`.
    pub fn load_snapshot(&self) -> Vec<(usize, f64)> {
        self.speeds()
            .into_iter()
            .zip(&self.outstanding)
            .map(|(s, o)| (o.load(Ordering::Relaxed), s))
            .collect()
    }

    /// Record completion of a request previously routed to `replica`.
    pub fn complete(&self, replica: usize) {
        self.outstanding[replica].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn outstanding(&self, replica: usize) -> usize {
        self.outstanding[replica].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = r.route();
        let b = r.route();
        assert_ne!(a, b, "second request goes to the idle replica");
        r.complete(a);
        assert_eq!(r.route(), a);
    }

    #[test]
    fn least_loaded_respects_speed() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        // replica 0 is 4× faster: it should absorb the first requests
        // before replica 1 gets one ((q+1)/speed tie at the 5th pick).
        let picks: Vec<usize> = (0..5).map(|_| r.route()).collect();
        assert!(picks[..4].iter().all(|&p| p == 0), "{picks:?}");
        assert_eq!(picks[4], 1, "{picks:?}");
    }

    #[test]
    fn set_speeds_works_on_the_shared_router() {
        // Regression: set_speeds used to take &mut self, making it
        // uncallable once the router was shared behind an Arc (as the
        // service does after start). Interior mutability fixes that.
        let r = Arc::new(Router::new(RoutePolicy::LeastLoaded, 2));
        let r2 = r.clone();
        r2.set_speeds(vec![2.0, 1.0]);
        assert_eq!(r.speeds(), vec![2.0, 1.0]);
    }

    #[test]
    fn speed_skews_traffic_proportionally() {
        // With nothing completing, queue depths equilibrate to the speed
        // ratio: a 4×-speed replica holds ~4× the outstanding work.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        for _ in 0..20 {
            r.route();
        }
        let (fast, slow) = (r.outstanding(0), r.outstanding(1));
        assert_eq!(fast + slow, 20);
        assert!(fast >= 3 * slow, "fast {fast} vs slow {slow}");
        assert!(slow >= 1, "slow replica must not starve outright: {fast}/{slow}");
    }

    #[test]
    fn observed_rates_override_seeds() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.observe_rate(0, 40.0);
        r.observe_rate(1, 10.0);
        let s = r.speeds();
        assert!((s[0] - 40.0).abs() < 1e-9 && (s[1] - 10.0).abs() < 1e-9, "{s:?}");
        // 40 vs 10 tok/s: the fast replica absorbs the first picks.
        let picks: Vec<usize> = (0..4).map(|_| r.route()).collect();
        assert!(picks.iter().all(|&p| p == 0), "{picks:?}");
    }

    #[test]
    fn observe_rate_smooths_with_ewma() {
        let r = Router::new(RoutePolicy::LeastLoaded, 1);
        r.observe_rate(0, 10.0);
        r.observe_rate(0, 20.0);
        // 0.8·10 + 0.2·20 = 12
        assert!((r.speeds()[0] - 12.0).abs() < 1e-9, "{:?}", r.speeds());
        // junk samples are ignored
        r.observe_rate(0, f64::INFINITY);
        r.observe_rate(0, -1.0);
        r.observe_rate(0, 0.0);
        assert!((r.speeds()[0] - 12.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_bridges_seeded_and_measured_replicas() {
        // Seeds are relative (2:1); only replica 0 has measured 10 tok/s.
        // The unmeasured replica's seed is scaled by the measured/seed
        // ratio (10/2 = 5), preserving the planned 2:1 relation.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![2.0, 1.0]);
        r.observe_rate(0, 10.0);
        let s = r.speeds();
        assert!((s[0] - 10.0).abs() < 1e-9, "{s:?}");
        assert!((s[1] - 5.0).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn route_excluding_skips_dead_replicas() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..4).map(|_| r.route_excluding(&[1]).unwrap()).collect();
        assert!(picks.iter().all(|&p| p != 1), "{picks:?}");
        assert_eq!(r.route_excluding(&[0, 1, 2]), None);

        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        for _ in 0..3 {
            assert_eq!(r.route_excluding(&[0]), Some(1));
        }
        assert_eq!(r.outstanding(1), 3);
        assert_eq!(r.outstanding(0), 0);
    }

    #[test]
    fn failed_handoff_releases_the_count() {
        // Regression for the dead-replica load leak: a route() whose
        // queue send fails must be paired with complete(), restoring the
        // counter so the policy does not keep favouring the dead replica.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let dead = r.route();
        r.complete(dead); // hand-off failed: release
        assert_eq!(r.outstanding(dead), 0);
        let alive = r.route_excluding(&[dead]).unwrap();
        assert_ne!(alive, dead);
    }

    #[test]
    fn load_snapshot_pairs_depth_with_speed() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        r.route();
        let snap = r.load_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0], (1, 4.0));
        assert_eq!(snap[1], (0, 1.0));
    }

    #[test]
    fn panicked_holder_does_not_poison_routing() {
        // Regression for the poisoning cascade: a worker thread dying
        // while holding the speed lock must not take the router (and
        // with it every handler thread) down.
        let r = Arc::new(Router::new(RoutePolicy::LeastLoaded, 2));
        let r2 = r.clone();
        let died = std::thread::spawn(move || {
            let _guard = r2.speeds.lock();
            panic!("worker died mid-update");
        })
        .join();
        assert!(died.is_err());
        r.set_speeds(vec![2.0, 1.0]);
        assert_eq!(r.speeds(), vec![2.0, 1.0]);
        let _ = r.route();
    }

    #[test]
    fn outstanding_tracks() {
        let r = Router::new(RoutePolicy::LeastLoaded, 1);
        assert_eq!(r.outstanding(0), 0);
        r.route();
        r.route();
        assert_eq!(r.outstanding(0), 2);
        r.complete(0);
        assert_eq!(r.outstanding(0), 1);
    }
}
