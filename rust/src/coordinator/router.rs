//! Request router over model replicas.
//!
//! The task coordinator (paper Appendix C) directs each request to a
//! worker group according to the schedule. Policies: round-robin and
//! least-outstanding-work (queue depth weighted by the replica's speed).
//!
//! Speeds are **heterogeneity-aware** and live behind interior
//! mutability so the shared (post-`start`) router can keep them fresh:
//!
//! - [`Router::set_speeds`] seeds relative weights at service start —
//!   the normalized 1/cost estimates of a lowered deployment plan
//!   (Eq. 2), so a replica the scheduler expects to be 4× faster
//!   absorbs proportionally more traffic from the first request on;
//! - [`Router::observe_rate`] folds each replica's *measured* decode
//!   throughput (tokens/s) into an EWMA at runtime. Measured replicas
//!   route by their EWMA; replicas not yet measured route by their seed,
//!   calibrated onto the measured scale (mean measured/seed ratio), so
//!   relative plan estimates and absolute token rates mix consistently.
//!
//! Disaggregated serving prices the two phases **independently**: each
//! replica carries separate prefill-side and decode-side seeds (the
//! per-phase Eq. 2 estimates of a v2 plan) and separate measured EWMAs
//! (prefill tokens/s vs decode steps/s), and [`Router::route_phase`]
//! restricts the candidate set to the replicas whose
//! [`PhaseRole`] can serve the phase. The phase-less entry points
//! ([`Router::route`], [`Router::speeds`], [`Router::observe_rate`])
//! remain the decode-side view — the fused path hybrid deployments use.

use crate::parallelism::PhaseRole;
use crate::util::sync::{locks, OrderedMutex, OrderedMutexGuard};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// EWMA smoothing factor for measured decode throughput.
const SPEED_EWMA_ALPHA: f64 = 0.2;

/// Routing decisions a replica's measured EWMA survives without a fresh
/// sample before it starts decaying back toward the plan seed. An idle
/// replica stops reporting, and its last measurement — possibly taken
/// under transient load — would otherwise price it forever.
const SPEED_STALE_AFTER: u32 = 64;
/// Fraction a stale measurement moves toward its seed-calibrated anchor
/// on each further routing decision.
const SPEED_STALE_DECAY: f64 = 0.05;
/// Once a stale measurement is within this fraction of its anchor it is
/// dropped entirely, so the replica prices by its plan seed again (and a
/// later sample restarts the EWMA from scratch).
const SPEED_STALE_SNAP: f64 = 0.01;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Fewest estimated outstanding work units (queue depth ÷ speed).
    LeastLoaded,
}

/// The serving phase a request needs a replica for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServePhase {
    Prefill,
    Decode,
}

impl ServePhase {
    fn served_by(self, role: PhaseRole) -> bool {
        match self {
            ServePhase::Prefill => role.can_prefill(),
            ServePhase::Decode => role.can_decode(),
        }
    }
}

/// One phase's speed accounting: relative seeds and measured EWMAs.
#[derive(Debug)]
struct PhaseSpeeds {
    /// Relative seed weight per replica (1.0 = baseline).
    seed: Vec<f64>,
    /// EWMA of measured throughput; `None` until the replica reports
    /// its first measurement.
    measured: Vec<Option<f64>>,
    /// Routing decisions since the replica's last sample; drives the
    /// staleness decay of [`Self::tick`].
    stale: Vec<u32>,
}

impl PhaseSpeeds {
    fn new(replicas: usize) -> PhaseSpeeds {
        PhaseSpeeds {
            seed: vec![1.0; replicas],
            measured: vec![None; replicas],
            stale: vec![0; replicas],
        }
    }

    /// Mean measured/seed ratio over measured replicas: the scale that
    /// maps relative plan seeds onto absolute measured rates.
    fn calibration(&self) -> f64 {
        let ratios: Vec<f64> = self
            .measured
            .iter()
            .zip(&self.seed)
            .filter_map(|(m, &s)| m.map(|m| m / s))
            .collect();
        if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        }
    }

    /// Effective speeds: the measured EWMA where available, otherwise
    /// the seed calibrated onto the measured scale (mean measured/seed
    /// ratio over measured replicas).
    fn effective(&self) -> Vec<f64> {
        let calib = self.calibration();
        self.measured.iter().zip(&self.seed).map(|(m, &s)| m.unwrap_or(s * calib)).collect()
    }

    fn observe(&mut self, replica: usize, rate: f64) {
        self.measured[replica] = Some(match self.measured[replica] {
            None => rate,
            Some(prev) => (1.0 - SPEED_EWMA_ALPHA) * prev + SPEED_EWMA_ALPHA * rate,
        });
        self.stale[replica] = 0;
    }

    /// Age every measurement by one routing decision. A replica that has
    /// not reported for [`SPEED_STALE_AFTER`] decisions decays toward
    /// its seed-calibrated anchor (what [`Self::effective`] would price
    /// an *unmeasured* replica at), and snaps back to pure seed pricing
    /// once it gets close — so a replica idled long enough routes by the
    /// plan estimate again instead of by a measurement taken under a
    /// load pattern that no longer exists.
    fn tick(&mut self) {
        let calib = self.calibration();
        for i in 0..self.measured.len() {
            let Some(m) = self.measured[i] else { continue };
            self.stale[i] = self.stale[i].saturating_add(1);
            if self.stale[i] <= SPEED_STALE_AFTER {
                continue;
            }
            let anchor = self.seed[i] * calib;
            let next = (1.0 - SPEED_STALE_DECAY) * m + SPEED_STALE_DECAY * anchor;
            if (next - anchor).abs() <= SPEED_STALE_SNAP * anchor.abs() {
                self.measured[i] = None;
                self.stale[i] = 0;
            } else {
                self.measured[i] = Some(next);
            }
        }
    }
}

/// Per-replica speed and role accounting (behind the router's ranked
/// mutex).
#[derive(Debug)]
struct SpeedState {
    /// Decode-side speeds — what the phase-less API reads and writes.
    decode: PhaseSpeeds,
    /// Prefill-side speeds.
    prefill: PhaseSpeeds,
    /// Phase role per replica (all-[`PhaseRole::Hybrid`] until
    /// [`Router::set_roles`]).
    roles: Vec<PhaseRole>,
}

/// Shared per-replica load accounting.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    outstanding: Vec<Arc<AtomicUsize>>,
    speeds: OrderedMutex<SpeedState>,
    rr_next: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy, replicas: usize) -> Router {
        assert!(replicas > 0);
        Router {
            policy,
            outstanding: (0..replicas).map(|_| Arc::new(AtomicUsize::new(0))).collect(),
            speeds: OrderedMutex::new(
                locks::ROUTER_SPEEDS,
                "router.speeds",
                SpeedState {
                    decode: PhaseSpeeds::new(replicas),
                    prefill: PhaseSpeeds::new(replicas),
                    roles: vec![PhaseRole::Hybrid; replicas],
                },
            ),
            rr_next: AtomicUsize::new(0),
        }
    }

    /// Seed relative speed weights (e.g. normalized 1/cost-estimate per
    /// replica from a lowered deployment plan) for **both** phases — the
    /// fused seeding hybrid deployments use. Callable on the shared
    /// router at any time; measured EWMAs, where present, keep
    /// precedence over seeds.
    pub fn set_speeds(&self, speed: Vec<f64>) {
        assert_eq!(speed.len(), self.outstanding.len());
        assert!(speed.iter().all(|&s| s.is_finite() && s > 0.0));
        let mut st = self.state();
        st.prefill.seed.clone_from(&speed);
        st.decode.seed = speed;
    }

    /// Seed one phase's relative speed weights independently (the
    /// per-phase Eq. 2 estimates of a v2 plan).
    pub fn set_phase_speeds(&self, phase: ServePhase, speed: Vec<f64>) {
        assert_eq!(speed.len(), self.outstanding.len());
        assert!(speed.iter().all(|&s| s.is_finite() && s > 0.0));
        let mut st = self.state();
        match phase {
            ServePhase::Prefill => st.prefill.seed = speed,
            ServePhase::Decode => st.decode.seed = speed,
        }
    }

    /// Declare each replica's phase role. [`Self::route_phase`] skips
    /// replicas that cannot serve the requested phase; the phase-less
    /// [`Self::route`]/[`Self::route_excluding`] ignore roles (the fused
    /// path of an all-hybrid deployment).
    pub fn set_roles(&self, roles: Vec<PhaseRole>) {
        assert_eq!(roles.len(), self.outstanding.len());
        self.state().roles = roles;
    }

    /// Fold a measured **decode** throughput sample (tokens/s) for
    /// `replica` into its EWMA. Non-finite or non-positive samples are
    /// ignored.
    pub fn observe_rate(&self, replica: usize, tokens_per_sec: f64) {
        self.observe_phase_rate(ServePhase::Decode, replica, tokens_per_sec);
    }

    /// Fold a measured throughput sample for one phase (prefill
    /// tokens/s or decode tokens/s) into that phase's EWMA. Non-finite
    /// or non-positive samples are ignored.
    pub fn observe_phase_rate(&self, phase: ServePhase, replica: usize, rate: f64) {
        if !rate.is_finite() || rate <= 0.0 {
            return;
        }
        let mut st = self.state();
        match phase {
            ServePhase::Prefill => st.prefill.observe(replica, rate),
            ServePhase::Decode => st.decode.observe(replica, rate),
        }
    }

    /// Effective per-replica **decode** speeds the phase-less policy
    /// routes by: the measured EWMA where available, otherwise the seed
    /// calibrated onto the measured scale (mean measured/seed ratio over
    /// measured replicas).
    pub fn speeds(&self) -> Vec<f64> {
        self.phase_speeds(ServePhase::Decode)
    }

    /// Effective per-replica speeds for one phase (same seed/EWMA
    /// blending as [`Self::speeds`], per phase).
    pub fn phase_speeds(&self, phase: ServePhase) -> Vec<f64> {
        let st = self.state();
        match phase {
            ServePhase::Prefill => st.prefill.effective(),
            ServePhase::Decode => st.decode.effective(),
        }
    }

    /// Phase role per replica (all-hybrid until [`Self::set_roles`]).
    pub fn roles(&self) -> Vec<PhaseRole> {
        self.state().roles.clone()
    }

    fn state(&self) -> OrderedMutexGuard<'_, SpeedState> {
        self.speeds.lock()
    }

    pub fn replicas(&self) -> usize {
        self.outstanding.len()
    }

    /// Pick a replica for a new request and record the assignment.
    pub fn route(&self) -> usize {
        match self.route_excluding(&[]) {
            Some(r) => r,
            // Unreachable with nothing excluded (`new` asserts replicas
            // > 0), but a panic here would kill a handler thread; fall
            // back to replica 0 and keep the complete() pairing intact.
            None => {
                self.outstanding[0].fetch_add(1, Ordering::Relaxed);
                0
            }
        }
    }

    /// Pick a replica, skipping `excluded` (replicas observed dead by the
    /// caller). Returns `None` when every replica is excluded. The caller
    /// must pair each successful pick with [`Self::complete`] — including
    /// when the hand-off to the replica fails afterwards, or the load
    /// counter leaks and the policy keeps favouring a dead replica.
    /// Roles are ignored: this is the fused path of an all-hybrid
    /// deployment (it prices by decode-side speeds).
    pub fn route_excluding(&self, excluded: &[usize]) -> Option<usize> {
        self.route_filtered(excluded, None)
    }

    /// Pick a replica to serve `phase`, skipping `excluded` and every
    /// replica whose [`PhaseRole`] cannot serve the phase, pricing
    /// candidates by that phase's speeds. Returns `None` when no
    /// eligible replica remains. Pair successful picks with
    /// [`Self::complete`], exactly as with [`Self::route_excluding`].
    pub fn route_phase(&self, phase: ServePhase, excluded: &[usize]) -> Option<usize> {
        self.route_filtered(excluded, Some(phase))
    }

    fn route_filtered(&self, excluded: &[usize], phase: Option<ServePhase>) -> Option<usize> {
        // Every routing decision ages the priced phase's measurements:
        // replicas that keep routing without reporting decay back toward
        // their plan seeds ([`PhaseSpeeds::tick`]). The phase-less path
        // prices by decode-side speeds, so it ages the decode side.
        {
            let mut st = self.state();
            match phase {
                Some(ServePhase::Prefill) => st.prefill.tick(),
                _ => st.decode.tick(),
            }
        }
        let n = self.outstanding.len();
        let roles = match phase {
            Some(_) => self.state().roles.clone(),
            None => Vec::new(),
        };
        let eligible = |i: usize| match phase {
            Some(p) => !excluded.contains(&i) && p.served_by(roles[i]),
            None => !excluded.contains(&i),
        };
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let mut pick = None;
                for _ in 0..n {
                    let c = self.rr_next.fetch_add(1, Ordering::Relaxed) % n;
                    if eligible(c) {
                        pick = Some(c);
                        break;
                    }
                }
                pick?
            }
            RoutePolicy::LeastLoaded => {
                let speed = match phase {
                    Some(p) => self.phase_speeds(p),
                    None => self.speeds(),
                };
                let mut best = None;
                let mut best_cost = f64::INFINITY;
                for (i, o) in self.outstanding.iter().enumerate() {
                    if !eligible(i) {
                        continue;
                    }
                    let cost = (o.load(Ordering::Relaxed) as f64 + 1.0) / speed[i];
                    if cost < best_cost {
                        best_cost = cost;
                        best = Some(i);
                    }
                }
                best?
            }
        };
        self.outstanding[r].fetch_add(1, Ordering::Relaxed);
        Some(r)
    }

    /// Per-replica `(outstanding requests, effective speed)` snapshot —
    /// what the serving front-end reports as queue depths and routing
    /// weights on `GET /metrics`.
    pub fn load_snapshot(&self) -> Vec<(usize, f64)> {
        self.speeds()
            .into_iter()
            .zip(&self.outstanding)
            .map(|(s, o)| (o.load(Ordering::Relaxed), s))
            .collect()
    }

    /// Record completion of a request previously routed to `replica`.
    pub fn complete(&self, replica: usize) {
        self.outstanding[replica].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn outstanding(&self, replica: usize) -> usize {
        self.outstanding[replica].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = r.route();
        let b = r.route();
        assert_ne!(a, b, "second request goes to the idle replica");
        r.complete(a);
        assert_eq!(r.route(), a);
    }

    #[test]
    fn least_loaded_respects_speed() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        // replica 0 is 4× faster: it should absorb the first requests
        // before replica 1 gets one ((q+1)/speed tie at the 5th pick).
        let picks: Vec<usize> = (0..5).map(|_| r.route()).collect();
        assert!(picks[..4].iter().all(|&p| p == 0), "{picks:?}");
        assert_eq!(picks[4], 1, "{picks:?}");
    }

    #[test]
    fn set_speeds_works_on_the_shared_router() {
        // Regression: set_speeds used to take &mut self, making it
        // uncallable once the router was shared behind an Arc (as the
        // service does after start). Interior mutability fixes that.
        let r = Arc::new(Router::new(RoutePolicy::LeastLoaded, 2));
        let r2 = r.clone();
        r2.set_speeds(vec![2.0, 1.0]);
        assert_eq!(r.speeds(), vec![2.0, 1.0]);
    }

    #[test]
    fn speed_skews_traffic_proportionally() {
        // With nothing completing, queue depths equilibrate to the speed
        // ratio: a 4×-speed replica holds ~4× the outstanding work.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        for _ in 0..20 {
            r.route();
        }
        let (fast, slow) = (r.outstanding(0), r.outstanding(1));
        assert_eq!(fast + slow, 20);
        assert!(fast >= 3 * slow, "fast {fast} vs slow {slow}");
        assert!(slow >= 1, "slow replica must not starve outright: {fast}/{slow}");
    }

    #[test]
    fn observed_rates_override_seeds() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.observe_rate(0, 40.0);
        r.observe_rate(1, 10.0);
        let s = r.speeds();
        assert!((s[0] - 40.0).abs() < 1e-9 && (s[1] - 10.0).abs() < 1e-9, "{s:?}");
        // 40 vs 10 tok/s: the fast replica absorbs the first picks.
        let picks: Vec<usize> = (0..4).map(|_| r.route()).collect();
        assert!(picks.iter().all(|&p| p == 0), "{picks:?}");
    }

    #[test]
    fn observe_rate_smooths_with_ewma() {
        let r = Router::new(RoutePolicy::LeastLoaded, 1);
        r.observe_rate(0, 10.0);
        r.observe_rate(0, 20.0);
        // 0.8·10 + 0.2·20 = 12
        assert!((r.speeds()[0] - 12.0).abs() < 1e-9, "{:?}", r.speeds());
        // junk samples are ignored
        r.observe_rate(0, f64::INFINITY);
        r.observe_rate(0, -1.0);
        r.observe_rate(0, 0.0);
        assert!((r.speeds()[0] - 12.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_bridges_seeded_and_measured_replicas() {
        // Seeds are relative (2:1); only replica 0 has measured 10 tok/s.
        // The unmeasured replica's seed is scaled by the measured/seed
        // ratio (10/2 = 5), preserving the planned 2:1 relation.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![2.0, 1.0]);
        r.observe_rate(0, 10.0);
        let s = r.speeds();
        assert!((s[0] - 10.0).abs() < 1e-9, "{s:?}");
        assert!((s[1] - 5.0).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn route_excluding_skips_dead_replicas() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..4).map(|_| r.route_excluding(&[1]).unwrap()).collect();
        assert!(picks.iter().all(|&p| p != 1), "{picks:?}");
        assert_eq!(r.route_excluding(&[0, 1, 2]), None);

        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        for _ in 0..3 {
            assert_eq!(r.route_excluding(&[0]), Some(1));
        }
        assert_eq!(r.outstanding(1), 3);
        assert_eq!(r.outstanding(0), 0);
    }

    #[test]
    fn failed_handoff_releases_the_count() {
        // Regression for the dead-replica load leak: a route() whose
        // queue send fails must be paired with complete(), restoring the
        // counter so the policy does not keep favouring the dead replica.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let dead = r.route();
        r.complete(dead); // hand-off failed: release
        assert_eq!(r.outstanding(dead), 0);
        let alive = r.route_excluding(&[dead]).unwrap();
        assert_ne!(alive, dead);
    }

    #[test]
    fn load_snapshot_pairs_depth_with_speed() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        r.route();
        let snap = r.load_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0], (1, 4.0));
        assert_eq!(snap[1], (0, 1.0));
    }

    #[test]
    fn panicked_holder_does_not_poison_routing() {
        // Regression for the poisoning cascade: a worker thread dying
        // while holding the speed lock must not take the router (and
        // with it every handler thread) down.
        let r = Arc::new(Router::new(RoutePolicy::LeastLoaded, 2));
        let r2 = r.clone();
        let died = std::thread::spawn(move || {
            let _guard = r2.speeds.lock();
            panic!("worker died mid-update");
        })
        .join();
        assert!(died.is_err());
        r.set_speeds(vec![2.0, 1.0]);
        assert_eq!(r.speeds(), vec![2.0, 1.0]);
        let _ = r.route();
    }

    #[test]
    fn route_phase_respects_roles() {
        let r = Router::new(RoutePolicy::LeastLoaded, 3);
        r.set_roles(vec![PhaseRole::Prefill, PhaseRole::Decode, PhaseRole::Hybrid]);
        // Prefill requests never land on the decode-only replica, decode
        // requests never on the prefill-only one; hybrid serves both.
        for _ in 0..6 {
            let p = r.route_phase(ServePhase::Prefill, &[]).unwrap();
            assert_ne!(p, 1, "decode-only replica took a prefill");
            let d = r.route_phase(ServePhase::Decode, &[]).unwrap();
            assert_ne!(d, 0, "prefill-only replica took a decode");
        }
        // Excluding the hybrid leaves exactly one candidate per phase.
        assert_eq!(r.route_phase(ServePhase::Prefill, &[2]), Some(0));
        assert_eq!(r.route_phase(ServePhase::Decode, &[2]), Some(1));
        // No eligible replica left: the pick must fail, not fall back.
        assert_eq!(r.route_phase(ServePhase::Prefill, &[0, 2]), None);

        let rr = Router::new(RoutePolicy::RoundRobin, 2);
        rr.set_roles(vec![PhaseRole::Prefill, PhaseRole::Decode]);
        for _ in 0..4 {
            assert_eq!(rr.route_phase(ServePhase::Decode, &[]), Some(1));
        }
    }

    #[test]
    fn phase_speeds_are_priced_independently() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        // Replica 0 is the fast prefiller, replica 1 the fast decoder.
        r.set_phase_speeds(ServePhase::Prefill, vec![4.0, 1.0]);
        r.set_phase_speeds(ServePhase::Decode, vec![1.0, 4.0]);
        assert_eq!(r.phase_speeds(ServePhase::Prefill), vec![4.0, 1.0]);
        assert_eq!(r.speeds(), vec![1.0, 4.0], "phase-less view is the decode side");
        let p = r.route_phase(ServePhase::Prefill, &[]).unwrap();
        r.complete(p);
        assert_eq!(p, 0, "prefill prices by prefill speeds");
        let d = r.route_phase(ServePhase::Decode, &[]).unwrap();
        r.complete(d);
        assert_eq!(d, 1, "decode prices by decode speeds");

        // Per-phase EWMAs stay separate: a prefill sample must not
        // disturb the decode estimate.
        r.observe_phase_rate(ServePhase::Prefill, 1, 100.0);
        assert_eq!(r.phase_speeds(ServePhase::Prefill)[1], 100.0);
        assert_eq!(r.speeds(), vec![1.0, 4.0]);
    }

    #[test]
    fn set_speeds_seeds_both_phases() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![3.0, 1.0]);
        assert_eq!(r.phase_speeds(ServePhase::Prefill), vec![3.0, 1.0]);
        assert_eq!(r.phase_speeds(ServePhase::Decode), vec![3.0, 1.0]);
        assert_eq!(r.roles(), vec![PhaseRole::Hybrid; 2], "default roles are hybrid");
    }

    #[test]
    fn stale_measurements_decay_back_to_plan_seeds() {
        // Idle-then-resume: replica 1 reports one anomalously slow sample
        // (say, a transient load spike) and then goes quiet while the
        // router keeps deciding and replica 0 keeps reporting. Without
        // decay the stale 1 tok/s would price replica 1 forever.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![2.0, 1.0]);
        r.observe_rate(0, 20.0);
        r.observe_rate(1, 1.0);
        assert!((r.speeds()[1] - 1.0).abs() < 1e-9, "{:?}", r.speeds());

        // Within the staleness window the measurement is untouched.
        for _ in 0..SPEED_STALE_AFTER {
            let p = r.route();
            r.complete(p);
        }
        assert!((r.speeds()[1] - 1.0).abs() < 1e-9, "decayed too early: {:?}", r.speeds());

        // Past the window it decays toward the seed-calibrated anchor
        // (seed 1 × the 20/2 measured scale of replica 0 = 10 tok/s)
        // and eventually snaps back to pure seed pricing.
        for _ in 0..500 {
            let p = r.route();
            r.complete(p);
            r.observe_rate(0, 20.0); // replica 0 stays fresh
        }
        let s = r.speeds();
        assert!((s[0] - 20.0).abs() < 1e-9, "fresh replica must not decay: {s:?}");
        assert!((s[1] - 10.0).abs() < 1e-9, "stale replica must revert to its seed: {s:?}");

        // Resume: a fresh sample takes over immediately and restarts the
        // EWMA from the new rate, not from the decayed remnant.
        r.observe_rate(1, 30.0);
        assert!((r.speeds()[1] - 30.0).abs() < 1e-9, "{:?}", r.speeds());
    }

    #[test]
    fn staleness_is_tracked_per_phase() {
        // Prefill routing decisions must not age decode measurements:
        // a decode-side sample stays live through any number of
        // prefill-side picks.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![2.0, 1.0]);
        r.observe_rate(1, 1.0);
        for _ in 0..(SPEED_STALE_AFTER + 200) {
            let p = r.route_phase(ServePhase::Prefill, &[]).unwrap();
            r.complete(p);
        }
        assert!(
            (r.speeds()[1] - 1.0).abs() < 1e-9,
            "prefill decisions aged the decode EWMA: {:?}",
            r.speeds()
        );
    }

    #[test]
    fn outstanding_tracks() {
        let r = Router::new(RoutePolicy::LeastLoaded, 1);
        assert_eq!(r.outstanding(0), 0);
        r.route();
        r.route();
        assert_eq!(r.outstanding(0), 2);
        r.complete(0);
        assert_eq!(r.outstanding(0), 1);
    }
}
