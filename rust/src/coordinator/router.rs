//! Request router over model replicas.
//!
//! The task coordinator (paper Appendix C) directs each request to a
//! worker group according to the schedule. Policies: round-robin and
//! least-outstanding-work (queue depth weighted by the replica's measured
//! speed).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Fewest estimated outstanding work units (queue depth ÷ speed).
    LeastLoaded,
}

/// Shared per-replica load accounting.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    outstanding: Vec<Arc<AtomicUsize>>,
    /// Relative speed weight per replica (1.0 = baseline; higher = faster).
    speed: Vec<f64>,
    rr_next: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy, replicas: usize) -> Router {
        assert!(replicas > 0);
        Router {
            policy,
            outstanding: (0..replicas).map(|_| Arc::new(AtomicUsize::new(0))).collect(),
            speed: vec![1.0; replicas],
            rr_next: AtomicUsize::new(0),
        }
    }

    /// Set relative speed weights (e.g. 1/measured-latency per replica).
    pub fn set_speeds(&mut self, speed: Vec<f64>) {
        assert_eq!(speed.len(), self.outstanding.len());
        assert!(speed.iter().all(|&s| s > 0.0));
        self.speed = speed;
    }

    pub fn replicas(&self) -> usize {
        self.outstanding.len()
    }

    /// Pick a replica for a new request and record the assignment.
    pub fn route(&self) -> usize {
        self.route_excluding(&[]).expect("router has at least one replica")
    }

    /// Pick a replica, skipping `excluded` (replicas observed dead by the
    /// caller). Returns `None` when every replica is excluded. The caller
    /// must pair each successful pick with [`Self::complete`] — including
    /// when the hand-off to the replica fails afterwards, or the load
    /// counter leaks and the policy keeps favouring a dead replica.
    pub fn route_excluding(&self, excluded: &[usize]) -> Option<usize> {
        let n = self.outstanding.len();
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let mut pick = None;
                for _ in 0..n {
                    let c = self.rr_next.fetch_add(1, Ordering::Relaxed) % n;
                    if !excluded.contains(&c) {
                        pick = Some(c);
                        break;
                    }
                }
                pick?
            }
            RoutePolicy::LeastLoaded => {
                let mut best = None;
                let mut best_cost = f64::INFINITY;
                for (i, o) in self.outstanding.iter().enumerate() {
                    if excluded.contains(&i) {
                        continue;
                    }
                    let cost = (o.load(Ordering::Relaxed) as f64 + 1.0) / self.speed[i];
                    if cost < best_cost {
                        best_cost = cost;
                        best = Some(i);
                    }
                }
                best?
            }
        };
        self.outstanding[r].fetch_add(1, Ordering::Relaxed);
        Some(r)
    }

    /// Record completion of a request previously routed to `replica`.
    pub fn complete(&self, replica: usize) {
        self.outstanding[replica].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn outstanding(&self, replica: usize) -> usize {
        self.outstanding[replica].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances() {
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let a = r.route();
        let b = r.route();
        assert_ne!(a, b, "second request goes to the idle replica");
        r.complete(a);
        assert_eq!(r.route(), a);
    }

    #[test]
    fn least_loaded_respects_speed() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2);
        r.set_speeds(vec![4.0, 1.0]);
        // replica 0 is 4× faster: it should absorb the first requests
        // before replica 1 gets one ((q+1)/speed tie at the 5th pick).
        let picks: Vec<usize> = (0..5).map(|_| r.route()).collect();
        assert!(picks[..4].iter().all(|&p| p == 0), "{picks:?}");
        assert_eq!(picks[4], 1, "{picks:?}");
    }

    #[test]
    fn route_excluding_skips_dead_replicas() {
        let r = Router::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..4).map(|_| r.route_excluding(&[1]).unwrap()).collect();
        assert!(picks.iter().all(|&p| p != 1), "{picks:?}");
        assert_eq!(r.route_excluding(&[0, 1, 2]), None);

        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        for _ in 0..3 {
            assert_eq!(r.route_excluding(&[0]), Some(1));
        }
        assert_eq!(r.outstanding(1), 3);
        assert_eq!(r.outstanding(0), 0);
    }

    #[test]
    fn failed_handoff_releases_the_count() {
        // Regression for the dead-replica load leak: a route() whose
        // queue send fails must be paired with complete(), restoring the
        // counter so the policy does not keep favouring the dead replica.
        let r = Router::new(RoutePolicy::LeastLoaded, 2);
        let dead = r.route();
        r.complete(dead); // hand-off failed: release
        assert_eq!(r.outstanding(dead), 0);
        let alive = r.route_excluding(&[dead]).unwrap();
        assert_ne!(alive, dead);
    }

    #[test]
    fn outstanding_tracks() {
        let r = Router::new(RoutePolicy::LeastLoaded, 1);
        assert_eq!(r.outstanding(0), 0);
        r.route();
        r.route();
        assert_eq!(r.outstanding(0), 2);
        r.complete(0);
        assert_eq!(r.outstanding(0), 1);
    }
}
