//! The serving request API: an explicit, streamable, cancellable request
//! lifecycle over the threaded service (paper §4's open-loop coordinator
//! needs request *identity*, not one-shot calls).
//!
//! A [`GenRequest`] submitted to
//! [`HexGenService::submit`](super::service::HexGenService::submit) is
//! identified by a [`RequestId`] and observed through a
//! [`RequestHandle`] — a typed stream of [`RequestEvent`]s:
//!
//! ```text
//! submit ─▶ Queued ─▶ Admitted{replica, batch_size}
//!                         │
//!                         ▼
//!           Token{0} ─ Token{1} ─ … ─┬─▶ Done(Completion)
//!                                    ├─▶ Failed(ServiceError)
//!                                    └─▶ Retrying{replica, attempt}
//!                                          │ (failover: re-queued on a
//!                                          ▼  healthy replica)
//!                                     Admitted{…} ─ Token{…} ─ …
//! ```
//!
//! `Token{0}` is the token argmaxed from the prefill logits; every later
//! `Token{i}` is one decode iteration, emitted the moment the step
//! retires — so a consumer sees tokens while the row is still decoding.
//! Exactly one terminal event (`Done` or `Failed`) is ever sent. A
//! replica fault mid-request emits the non-terminal `Retrying` and the
//! lifecycle re-enters at `Admitted` on another replica; already-sent
//! `Token` events are never re-sent (the retry resumes exactly where the
//! stream left off).
//!
//! **Cancellation.** [`RequestHandle::cancel`] (or dropping the handle
//! before a terminal event — e.g. an HTTP client hanging up mid-stream)
//! flips a shared flag the replica worker honours at the next
//! decode-step boundary: the row's KV-cache slot is freed for admission
//! ([`DecodeSession::cancel_slot`](super::pipeline::DecodeSession::cancel_slot)),
//! the router's load count is released, and the request terminates with
//! [`ServiceError::Cancelled`]. A request cancelled while still queued
//! never runs at all.

use std::cell::Cell;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Unique id of a submitted request (monotonic per service instance).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

impl fmt::Display for RequestId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "req-{}", self.0)
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    /// Per-request generation limit; `None` falls back to
    /// [`ServiceConfig::max_new_tokens`](super::service::ServiceConfig::max_new_tokens).
    pub max_new: Option<usize>,
    /// Per-request stop token; `None` falls back to
    /// [`ServiceConfig::stop_token`](super::service::ServiceConfig::stop_token).
    pub stop: Option<i32>,
    /// Per-request deadline, milliseconds from submission. Enforced
    /// *where work happens*: checked at every admission and decode-step
    /// boundary next to the cancel flag, so an expired request frees its
    /// KV blocks and router count instead of burning decode steps. The
    /// request terminates with [`ServiceError::DeadlineExceeded`].
    pub deadline_ms: Option<u64>,
}

impl GenRequest {
    pub fn new(prompt: impl Into<String>) -> GenRequest {
        GenRequest { prompt: prompt.into(), max_new: None, stop: None, deadline_ms: None }
    }

    pub fn with_max_new(mut self, max_new: usize) -> GenRequest {
        self.max_new = Some(max_new);
        self
    }

    pub fn with_stop(mut self, stop: i32) -> GenRequest {
        self.stop = Some(stop);
        self
    }

    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> GenRequest {
        self.deadline_ms = Some(deadline_ms);
        self
    }
}

/// Typed failure modes of the serving path (replaces the stringly
/// `Result<Completion, String>` the coordinator API used to expose).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// Rejected before admission (bad parameters).
    InvalidRequest(String),
    /// Every configured replica is down.
    AllReplicasDown,
    /// The replica serving the request failed mid-flight.
    ReplicaFailed { replica: usize, message: String },
    /// Cancelled via [`RequestHandle::cancel`] or handle drop.
    Cancelled,
    /// The request's own `deadline_ms` expired; its KV blocks and router
    /// count were freed at the admission/decode-step boundary.
    DeadlineExceeded,
    /// The service (or its worker) dropped the request channel.
    Disconnected,
    /// A caller-imposed deadline expired while waiting.
    Timeout,
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            ServiceError::AllReplicasDown => write!(f, "all replicas are down"),
            ServiceError::ReplicaFailed { replica, message } => {
                write!(f, "replica {replica} failed: {message}")
            }
            ServiceError::Cancelled => write!(f, "request cancelled"),
            ServiceError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            ServiceError::Disconnected => write!(f, "service dropped the request"),
            ServiceError::Timeout => write!(f, "timed out waiting for the request"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// A completed generation.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: RequestId,
    pub text: String,
    pub tokens: Vec<i32>,
    /// Prompt tokens actually placed in the model context
    /// (≤ the artifact `prompt_len`).
    pub prompt_tokens: usize,
    /// True when the prompt exceeded the artifact `prompt_len` and its
    /// oldest tokens were dropped (left truncation) — previously a
    /// silent data loss.
    pub truncated: bool,
    /// End-to-end latency (submit → response), seconds.
    pub latency: f64,
    /// Queueing delay before this request was admitted into a slot,
    /// seconds.
    pub queued: f64,
    pub replica: usize,
    /// Rows in flight on the replica when this request was admitted
    /// (including itself).
    pub batch_size: usize,
    /// Wall time of this request's prefill pass, seconds.
    pub prefill_seconds: f64,
    /// Wall time from this request's prefill to its retirement, seconds.
    pub decode_seconds: f64,
    /// Decode iterations this request participated in
    /// (`tokens.len() - 1`; the first token comes from prefill).
    pub decode_steps: usize,
}

/// One step of a request's lifecycle, streamed through a
/// [`RequestHandle`].
#[derive(Debug, Clone)]
pub enum RequestEvent {
    /// Accepted and routed; waiting for a KV-cache slot.
    Queued,
    /// Admitted into a decode-session slot on `replica`, co-batched with
    /// `batch_size - 1` other rows.
    Admitted { replica: usize, batch_size: usize },
    /// One generated token. `index` 0 comes from the prefill logits;
    /// each later index is one decode iteration. `text_delta` is the
    /// newly decodable text: the byte-level vocab emits multi-byte UTF-8
    /// characters one token at a time, so the worker buffers incomplete
    /// sequences ([`Utf8Stream`](crate::runtime::Utf8Stream)) and a
    /// delta may be empty mid-character. The request's final token
    /// flushes the buffer, so the concatenation of all deltas equals
    /// [`Completion::text`] exactly.
    Token { index: usize, token: i32, text_delta: String },
    /// Non-terminal: the replica serving the request faulted and the
    /// request was re-queued for another replica (`attempt` counts
    /// retries, starting at 1). The stream continues with a fresh
    /// `Admitted` and resumes token emission exactly where it left off —
    /// already-streamed tokens are never re-sent.
    Retrying { replica: usize, attempt: u32 },
    /// Terminal: the request finished.
    Done(Completion),
    /// Terminal: the request failed (including cancellation).
    Failed(ServiceError),
}

impl RequestEvent {
    /// True for `Done` / `Failed` — the last event a request ever emits.
    pub fn is_terminal(&self) -> bool {
        matches!(self, RequestEvent::Done(_) | RequestEvent::Failed(_))
    }
}

/// Shared cancellation flag between a [`RequestHandle`] and the replica
/// worker serving the request.
#[derive(Debug, Default)]
pub struct CancelFlag(AtomicBool);

impl CancelFlag {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Caller's view of an in-flight request: the event stream plus
/// cancellation. Dropping the handle before a terminal event cancels the
/// request (a departed caller must not keep burning decode slots).
#[derive(Debug)]
pub struct RequestHandle {
    id: RequestId,
    rx: Receiver<RequestEvent>,
    cancel: Arc<CancelFlag>,
    /// Set once a terminal event was observed (drop then skips cancel).
    terminal: Cell<bool>,
}

impl RequestHandle {
    pub(crate) fn new(
        id: RequestId,
        rx: Receiver<RequestEvent>,
        cancel: Arc<CancelFlag>,
    ) -> RequestHandle {
        RequestHandle { id, rx, cancel, terminal: Cell::new(false) }
    }

    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Ask the service to stop this request at the next decode-step
    /// boundary. Idempotent; a no-op once the request finished.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    fn observe(&self, ev: RequestEvent) -> RequestEvent {
        if ev.is_terminal() {
            self.terminal.set(true);
        }
        ev
    }

    /// Block for the next lifecycle event.
    pub fn next_event(&self) -> Result<RequestEvent, ServiceError> {
        match self.rx.recv() {
            Ok(ev) => Ok(self.observe(ev)),
            Err(_) => {
                self.terminal.set(true);
                Err(ServiceError::Disconnected)
            }
        }
    }

    /// Block for the next event until `deadline`.
    pub fn next_event_before(&self, deadline: Instant) -> Result<RequestEvent, ServiceError> {
        let left = deadline.saturating_duration_since(Instant::now());
        match self.rx.recv_timeout(left) {
            Ok(ev) => Ok(self.observe(ev)),
            Err(RecvTimeoutError::Timeout) => Err(ServiceError::Timeout),
            Err(RecvTimeoutError::Disconnected) => {
                self.terminal.set(true);
                Err(ServiceError::Disconnected)
            }
        }
    }

    /// Non-blocking poll for the next event.
    pub fn try_event(&self) -> Result<Option<RequestEvent>, ServiceError> {
        match self.rx.try_recv() {
            Ok(ev) => Ok(Some(self.observe(ev))),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                self.terminal.set(true);
                Err(ServiceError::Disconnected)
            }
        }
    }

    /// Drain events until the terminal one; the blocking convenience
    /// `generate()` is a thin wrapper over this.
    pub fn wait(&self) -> Result<Completion, ServiceError> {
        loop {
            match self.next_event()? {
                RequestEvent::Done(c) => return Ok(c),
                RequestEvent::Failed(e) => return Err(e),
                _ => {}
            }
        }
    }

    /// [`Self::wait`] bounded by an absolute deadline. On
    /// [`ServiceError::Timeout`] the request is still in flight — drop
    /// the handle to cancel it, or keep waiting.
    pub fn wait_deadline(&self, deadline: Instant) -> Result<Completion, ServiceError> {
        loop {
            match self.next_event_before(deadline)? {
                RequestEvent::Done(c) => return Ok(c),
                RequestEvent::Failed(e) => return Err(e),
                _ => {}
            }
        }
    }
}

impl Drop for RequestHandle {
    fn drop(&mut self) {
        if !self.terminal.get() {
            self.cancel.cancel();
        }
    }
}

/// Wait on many submissions under **one shared deadline**: every handle
/// gets at most `timeout` from *now*, not `timeout` each (the old
/// per-`recv_timeout` form let N requests wait up to N×timeout).
/// Handles that time out are dropped — which cancels them.
pub fn collect_all(
    handles: Vec<RequestHandle>,
    timeout: Duration,
) -> Vec<Result<Completion, ServiceError>> {
    let deadline = Instant::now() + timeout;
    handles.into_iter().map(|h| h.wait_deadline(deadline)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn handle() -> (std::sync::mpsc::Sender<RequestEvent>, RequestHandle) {
        let (tx, rx) = channel();
        (tx, RequestHandle::new(RequestId(7), rx, Arc::new(CancelFlag::default())))
    }

    fn completion(id: RequestId) -> Completion {
        Completion {
            id,
            text: String::new(),
            tokens: vec![1, 2],
            prompt_tokens: 2,
            truncated: false,
            latency: 0.0,
            queued: 0.0,
            replica: 0,
            batch_size: 1,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: 1,
        }
    }

    #[test]
    fn wait_drains_to_done() {
        let (tx, h) = handle();
        tx.send(RequestEvent::Queued).unwrap();
        tx.send(RequestEvent::Admitted { replica: 0, batch_size: 1 }).unwrap();
        tx.send(RequestEvent::Token { index: 0, token: 1, text_delta: String::new() }).unwrap();
        tx.send(RequestEvent::Done(completion(RequestId(7)))).unwrap();
        let c = h.wait().unwrap();
        assert_eq!(c.id, RequestId(7));
        assert_eq!(c.tokens, vec![1, 2]);
    }

    #[test]
    fn wait_surfaces_failure() {
        let (tx, h) = handle();
        tx.send(RequestEvent::Failed(ServiceError::Cancelled)).unwrap();
        assert_eq!(h.wait(), Err(ServiceError::Cancelled));
    }

    #[test]
    fn disconnected_channel_is_an_error() {
        let (tx, h) = handle();
        drop(tx);
        assert_eq!(h.wait(), Err(ServiceError::Disconnected));
    }

    #[test]
    fn drop_before_terminal_cancels() {
        let (_tx, h) = handle();
        let flag = h.cancel.clone();
        assert!(!flag.is_cancelled());
        drop(h);
        assert!(flag.is_cancelled());
    }

    #[test]
    fn drop_after_terminal_does_not_cancel() {
        let (tx, h) = handle();
        tx.send(RequestEvent::Done(completion(RequestId(7)))).unwrap();
        let flag = h.cancel.clone();
        h.wait().unwrap();
        drop(h);
        assert!(!flag.is_cancelled());
    }

    #[test]
    fn collect_all_shares_one_deadline() {
        // Regression for the timeout-compounding bug: 5 handles that never
        // resolve must collectively miss one 100ms deadline, not wait
        // 5 × 100ms back to back.
        let (senders, handles): (Vec<_>, Vec<_>) = (0..5).map(|_| handle()).unzip();
        let t0 = Instant::now();
        let results = collect_all(handles, Duration::from_millis(100));
        let elapsed = t0.elapsed();
        drop(senders);
        assert!(results.iter().all(|r| r == &Err(ServiceError::Timeout)), "{results:?}");
        assert!(
            elapsed < Duration::from_millis(400),
            "shared deadline must not compound: waited {elapsed:?}"
        );
    }

    #[test]
    fn try_event_polls_without_blocking() {
        let (tx, h) = handle();
        assert!(h.try_event().unwrap().is_none());
        tx.send(RequestEvent::Queued).unwrap();
        assert!(matches!(h.try_event().unwrap(), Some(RequestEvent::Queued)));
    }

    #[test]
    fn request_id_formats() {
        assert_eq!(RequestId(42).to_string(), "req-42");
    }

    #[test]
    fn gen_request_builder() {
        let r = GenRequest::new("hi").with_max_new(3).with_stop(9);
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new, Some(3));
        assert_eq!(r.stop, Some(9));
    }
}
