//! Asymmetric pipeline executor: runs a generation batch through a chain
//! of stages with per-stage TP degrees (paper §3.2), calling the stage
//! executables through an [`ExecutionBackend`] (pure-Rust reference or
//! PJRT) and performing the leader-side collectives in Rust.
//!
//! The execution scheme per transformer layer is Megatron's:
//!
//! ```text
//! x ─┬─ shard₀: attn_partial ─┐
//!    ├─ shard₁: attn_partial ─┼─ AllReduce(sum) ─ +x ─┬─ shard₀: mlp ─┐
//!    └─ …                     ┘                       └─ …            ┴─ AllReduce ─ +h
//! ```
//!
//! with the KV caches held per (layer, shard) between decode steps.
//!
//! Serving runs **continuous (iteration-level) batching** through a
//! persistent [`DecodeSession`]: paged KV caches sized to an artifact
//! bucket, with [`DecodeSession::prefill_into_slots`] admitting requests
//! into free slots at any decode-step boundary and
//! [`DecodeSession::decode_step`] retiring rows the moment they hit
//! their own `max_new` or emit their stop token. The monolithic
//! [`PipelineExecutor::generate`] remains as a thin run-to-completion
//! wrapper over a session.
//!
//! **Paged KV backing.** The storage of record is a block store: per
//! (stage, layer, shard) tensors of `[pool_blocks, nhs, block_tokens,
//! dh]` whose dim-0 rows are fixed-size physical blocks handed out by a
//! [`BlockPool`] and mapped per sequence through [`BlockTable`]s (see
//! [`crate::runtime::kvcache`]). Admission reserves a row's worst-case
//! block budget up front (deferral instead of mid-decode exhaustion),
//! prompts resolve chunk-by-chunk against a [`PrefixCache`] so
//! concurrent requests with a common prefix share its blocks refcounted
//! (copy-on-write on the first divergent append), and retire/cancel
//! return every block — cache memory tracks what requests actually use,
//! not `bucket × max_seq`.
//!
//! The execution kernels are untouched by paging: their contract is a
//! dense `[b, nhs, max_seq, dh]` cache per shard, so every decode step
//! runs over dense **step scratch** at the smallest manifest bucket
//! covering the live rows. Each active row's block-backed prefix is
//! gathered into its scratch row, the step executes in place there, and
//! only the newly appended KV entry scatters back into the row's tail
//! block. Per-row residency tracking ([`StepScratch`]) skips the gather
//! when a row's prefix is already in place from the previous step, so
//! the steady state pays one row of copy per step — and row results are
//! bit-identical to the dense backing (gathers replay exact bytes, and
//! per-row computation is independent of batch padding).
//!
//! **Decode hot path.** Three properties keep the per-token loop lean
//! (see rust/README.md §Performance):
//!
//! * KV caches are updated **in place** through
//!   [`ExecutionBackend::execute_attn_decode_inplace`] — a decode step
//!   writes each row's one new `[head_dim]` K/V slice per (layer, shard)
//!   instead of cloning and re-materializing whole caches;
//! * TP shards of a layer execute **concurrently** under
//!   `std::thread::scope` whenever the backend is shareable
//!   ([`ExecutionBackend::sync_view`]); shard order is preserved at the
//!   AllReduce, so results are bit-identical to serial execution;
//! * decode steps are **active-row-aware**: each step runs at the
//!   smallest manifest bucket covering the live rows — a session
//!   draining from 8 rows to 1 stops paying 8-row attention, MLP, and
//!   lm_head cost.
//!
//! All artifact and shard-weight name strings are precomputed at
//! executor construction ([`NameCache`]); the steady-state loop performs
//! no name formatting.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::kvcache::{plan_append, AppendOp, PREFIX_HASH_SEED};
use crate::runtime::{
    tokenizer, AttnShardWeights, BackendKind, BlockPool, BlockTable, DecodePositions,
    ExecutionBackend, InputArg, KvPolicy, PrefixCache, Tensor, WeightStore,
};

use super::collective::{add_residual, all_reduce_sum, record_kv_transfer, record_pp_send, CommStats};

/// One stage of the serving plan: a contiguous layer range at a TP degree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StagePlan {
    pub layer_start: usize,
    pub layer_count: usize,
    pub tp: usize,
}

impl StagePlan {
    pub fn layers(&self) -> std::ops::Range<usize> {
        self.layer_start..self.layer_start + self.layer_count
    }
}

/// Build a plan from TP-degree + layer-count lists (Appendix-F notation,
/// e.g. `tp=[2,1]`, `layers=[4,2]`).
pub fn plan_from_strategy(tps: &[usize], layers: &[usize]) -> Result<Vec<StagePlan>> {
    if tps.len() != layers.len() || tps.is_empty() {
        bail!("strategy lists must be equal-length and non-empty");
    }
    let mut start = 0;
    let mut out = Vec::with_capacity(tps.len());
    for (&tp, &lc) in tps.iter().zip(layers) {
        if lc == 0 {
            bail!("zero-layer stage");
        }
        out.push(StagePlan { layer_start: start, layer_count: lc, tp });
        start += lc;
    }
    Ok(out)
}

/// Result of one generation batch.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// Generated tokens per request row (pad rows removed).
    pub tokens: Vec<Vec<i32>>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    /// True decode iterations only — the token argmaxed from the prefill
    /// logits is *not* counted here (see [`Self::prefill_tokens`]), so
    /// `decode_steps / decode_seconds` is an honest decode rate.
    pub decode_steps: usize,
    /// Tokens produced by the prefill pass itself (one per request row).
    pub prefill_tokens: usize,
    pub comm: CommStats,
    /// Batch bucket actually executed (≥ the real batch).
    pub bucket: usize,
}

/// KV caches for one stage: `[layer][shard] -> (k, v)`.
type StageCaches = Vec<Vec<(Tensor, Tensor)>>;

/// Precomputed artifact and weight-name strings: every name the steady
/// state needs, built once at executor construction so the per-token
/// loop allocates no strings (the per-step `format!`/`shard_name` calls
/// used to dominate small-model decode profiles).
struct NameCache {
    /// The manifest's batch buckets, in manifest order; the per-bucket
    /// vectors below are indexed by position in this list.
    buckets: Vec<usize>,
    embed_prefill: Vec<String>,
    embed_decode: Vec<String>,
    lm_head_prefill: Vec<String>,
    lm_head_decode: Vec<String>,
    stages: Vec<StageNameCache>,
}

struct StageNameCache {
    attn_prefill: Vec<String>,
    attn_decode: Vec<String>,
    mlp_prefill: Vec<String>,
    mlp_decode: Vec<String>,
    /// Indexed by layer offset within the stage.
    layers: Vec<LayerNameCache>,
}

struct LayerNameCache {
    ln1: String,
    ln2: String,
    /// Indexed by TP rank.
    shards: Vec<ShardNameCache>,
}

struct ShardNameCache {
    wq: String,
    wk: String,
    wv: String,
    wo: String,
    w1: String,
    w2: String,
}

impl NameCache {
    fn new(buckets: Vec<usize>, stages: &[StagePlan]) -> NameCache {
        let stage_names = stages
            .iter()
            .map(|stage| {
                let tp = stage.tp;
                StageNameCache {
                    attn_prefill: buckets
                        .iter()
                        .map(|b| format!("attn_prefill_tp{tp}_b{b}"))
                        .collect(),
                    attn_decode: buckets
                        .iter()
                        .map(|b| format!("attn_decode_tp{tp}_b{b}"))
                        .collect(),
                    mlp_prefill: buckets
                        .iter()
                        .map(|b| format!("mlp_prefill_tp{tp}_b{b}"))
                        .collect(),
                    mlp_decode: buckets
                        .iter()
                        .map(|b| format!("mlp_decode_tp{tp}_b{b}"))
                        .collect(),
                    layers: stage
                        .layers()
                        .map(|layer| LayerNameCache {
                            ln1: format!("layers.{layer}.ln1"),
                            ln2: format!("layers.{layer}.ln2"),
                            shards: (0..tp)
                                .map(|r| ShardNameCache {
                                    wq: WeightStore::shard_name(layer, "wq", tp, r),
                                    wk: WeightStore::shard_name(layer, "wk", tp, r),
                                    wv: WeightStore::shard_name(layer, "wv", tp, r),
                                    wo: WeightStore::shard_name(layer, "wo", tp, r),
                                    w1: WeightStore::shard_name(layer, "w1", tp, r),
                                    w2: WeightStore::shard_name(layer, "w2", tp, r),
                                })
                                .collect(),
                        })
                        .collect(),
                }
            })
            .collect();
        NameCache {
            embed_prefill: buckets.iter().map(|b| format!("embed_prefill_b{b}")).collect(),
            embed_decode: buckets.iter().map(|b| format!("embed_decode_b{b}")).collect(),
            lm_head_prefill: buckets.iter().map(|b| format!("lm_head_prefill_b{b}")).collect(),
            lm_head_decode: buckets.iter().map(|b| format!("lm_head_decode_b{b}")).collect(),
            buckets,
            stages: stage_names,
        }
    }

    fn bucket_idx(&self, bucket: usize) -> Result<usize> {
        self.buckets
            .iter()
            .position(|&b| b == bucket)
            .with_context(|| format!("bucket {bucket} not in manifest buckets {:?}", self.buckets))
    }
}

/// Executes generation through an asymmetric TP×PP plan.
pub struct PipelineExecutor {
    backend: Box<dyn ExecutionBackend>,
    stages: Vec<StagePlan>,
    names: NameCache,
}

impl PipelineExecutor {
    /// Load the default backend for this build (PJRT when the `pjrt`
    /// feature is enabled, pure-Rust reference otherwise) from
    /// `artifacts_dir` and validate the plan against the manifest.
    pub fn new(artifacts_dir: &Path, stages: Vec<StagePlan>) -> Result<PipelineExecutor> {
        let backend = crate::runtime::load_backend(BackendKind::default(), artifacts_dir)?;
        Self::with_backend(backend, stages)
    }

    /// Wrap an already-constructed backend (what per-replica worker
    /// threads do), validating the plan against its manifest (layer
    /// coverage, supported TP degrees).
    pub fn with_backend(
        backend: Box<dyn ExecutionBackend>,
        stages: Vec<StagePlan>,
    ) -> Result<PipelineExecutor> {
        let names = {
            let m = backend.manifest();
            let total: usize = stages.iter().map(|s| s.layer_count).sum();
            if total != m.model.layers {
                bail!("plan covers {total} layers, model has {}", m.model.layers);
            }
            let mut next = 0;
            for s in &stages {
                if s.layer_start != next {
                    bail!("stages not contiguous at layer {next}");
                }
                next += s.layer_count;
                if !m.tp_degrees.contains(&s.tp) {
                    bail!("tp={} has no artifacts (available {:?})", s.tp, m.tp_degrees);
                }
            }
            NameCache::new(m.batch_buckets.clone(), &stages)
        };
        Ok(PipelineExecutor { backend, stages, names })
    }

    pub fn stages(&self) -> &[StagePlan] {
        &self.stages
    }

    /// The execution backend this pipeline runs on.
    pub fn backend(&self) -> &dyn ExecutionBackend {
        self.backend.as_ref()
    }

    /// The artifact catalog + model architecture being served.
    pub fn manifest(&self) -> &crate::runtime::Manifest {
        self.backend.manifest()
    }

    /// Strategy string in the paper's Appendix-F notation, e.g. `[2,1]`.
    pub fn strategy_string(&self) -> String {
        let v: Vec<String> = self.stages.iter().map(|s| s.tp.to_string()).collect();
        format!("[{}]", v.join(","))
    }

    /// The backend as a shareable trait object when this stage's TP
    /// fan-out should use threads; `None` runs shards serially (tp=1, or
    /// a thread-confined backend such as PJRT).
    fn sync_backend_for(&self, tp: usize) -> Option<&(dyn ExecutionBackend + Sync)> {
        if tp > 1 {
            self.backend.sync_view()
        } else {
            None
        }
    }

    /// Allocate zeroed per-stage/layer/shard KV caches with `bucket`
    /// dim-0 slots.
    fn alloc_caches(&self, bucket: usize) -> Result<Vec<StageCaches>> {
        let info = &self.backend.manifest().model;
        let mut caches: Vec<StageCaches> = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            if stage.tp == 0 || info.heads % stage.tp != 0 {
                bail!("tp={} does not divide {} heads", stage.tp, info.heads);
            }
            let nhs = info.heads / stage.tp;
            let dims = vec![bucket, nhs, info.max_seq, info.head_dim];
            let n = bucket * nhs * info.max_seq * info.head_dim;
            let mut stage_caches: StageCaches = Vec::with_capacity(stage.layer_count);
            for _ in 0..stage.layer_count {
                let shards: Vec<(Tensor, Tensor)> = (0..stage.tp)
                    .map(|_| {
                        (
                            Tensor { dims: dims.clone(), data: vec![0.0; n] },
                            Tensor { dims: dims.clone(), data: vec![0.0; n] },
                        )
                    })
                    .collect();
                stage_caches.push(shards);
            }
            caches.push(stage_caches);
        }
        Ok(caches)
    }

    /// Allocate the zeroed paged-KV block store: per stage/layer/shard
    /// tensors of `[pool_blocks, nhs, block_tokens, dh]`. Dim 0 is the
    /// physical block id — one [`BlockPool`] id addresses the matching
    /// row of every (stage, layer, shard) tensor, so a single logical
    /// block table per sequence covers the whole model.
    fn alloc_block_store(
        &self,
        pool_blocks: usize,
        block_tokens: usize,
    ) -> Result<Vec<StageCaches>> {
        let info = &self.backend.manifest().model;
        let mut caches: Vec<StageCaches> = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            if stage.tp == 0 || info.heads % stage.tp != 0 {
                bail!("tp={} does not divide {} heads", stage.tp, info.heads);
            }
            let nhs = info.heads / stage.tp;
            let dims = vec![pool_blocks, nhs, block_tokens, info.head_dim];
            let n = pool_blocks * nhs * block_tokens * info.head_dim;
            let mut stage_caches: StageCaches = Vec::with_capacity(stage.layer_count);
            for _ in 0..stage.layer_count {
                let shards: Vec<(Tensor, Tensor)> = (0..stage.tp)
                    .map(|_| {
                        (
                            Tensor { dims: dims.clone(), data: vec![0.0; n] },
                            Tensor { dims: dims.clone(), data: vec![0.0; n] },
                        )
                    })
                    .collect();
                stage_caches.push(shards);
            }
            caches.push(stage_caches);
        }
        Ok(caches)
    }

    /// Open a persistent decode session with `bucket` KV-cache slots
    /// (`bucket` must be one of the manifest's batch buckets) and the
    /// default paged-KV policy: [`kvcache::DEFAULT_BLOCK_TOKENS`]-row
    /// blocks and a pool matching the dense capacity, so nothing the
    /// dense backing would have admitted is ever deferred. Requests are
    /// admitted with [`DecodeSession::prefill_into_slots`].
    ///
    /// [`kvcache::DEFAULT_BLOCK_TOKENS`]: crate::runtime::kvcache::DEFAULT_BLOCK_TOKENS
    pub fn new_session(&self, bucket: usize) -> Result<DecodeSession<'_>> {
        self.new_session_with(bucket, KvPolicy::default())
    }

    /// Open a decode session with an explicit paged-KV policy
    /// ([`KvPolicy`]): `block_tokens` KV rows per physical block and a
    /// pool of `pool_blocks` blocks shared by all slots. The pool must
    /// hold at least one full sequence (`ceil(max_seq / block_tokens)`
    /// blocks); admission reserves each row's worst-case budget and
    /// defers when the pool cannot cover it.
    pub fn new_session_with(&self, bucket: usize, kv: KvPolicy) -> Result<DecodeSession<'_>> {
        let m = self.backend.manifest();
        if !m.batch_buckets.contains(&bucket) {
            bail!("session bucket {bucket} not in manifest buckets {:?}", m.batch_buckets);
        }
        let info = &m.model;
        let block_tokens = kv.resolve_block_tokens(info.max_seq);
        let blocks_per_seq = info.max_seq.div_ceil(block_tokens);
        let pool_blocks = kv.pool_blocks.unwrap_or(bucket * blocks_per_seq);
        if pool_blocks < blocks_per_seq {
            bail!(
                "kv pool of {pool_blocks} blocks cannot hold one full sequence \
                 ({blocks_per_seq} blocks of {block_tokens} tokens)"
            );
        }
        let block_store = self.alloc_block_store(pool_blocks, block_tokens)?;
        Ok(DecodeSession {
            exec: self,
            bucket,
            block_tokens,
            block_store,
            pool: BlockPool::new(pool_blocks, block_tokens)?,
            tables: (0..bucket).map(|_| BlockTable::with_block_capacity(blocks_per_seq)).collect(),
            prefix: PrefixCache::new(pool_blocks, block_tokens),
            step_caches: Vec::new(),
            slots: (0..bucket).map(|_| None).collect(),
            comm: CommStats::default(),
            decode_steps: 0,
            prefill_tokens: 0,
            prefill_skips: 0,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            scratch_active: Vec::with_capacity(bucket),
            scratch_tokens: Vec::with_capacity(bucket),
            scratch_positions: Vec::with_capacity(bucket),
            scratch_prompt: Vec::with_capacity(bucket * info.prompt_len),
            scratch_miss: Vec::with_capacity(bucket * info.prompt_len.div_ceil(block_tokens)),
            scratch_keys: Vec::with_capacity(bucket),
            scratch_compute: Vec::with_capacity(bucket),
        })
    }

    /// Generate up to `max_new` tokens for a batch of prompts (each
    /// exactly `prompt_len` tokens; see [`crate::runtime::tokenizer`]).
    /// Greedy decoding. Thin run-to-completion wrapper over a
    /// [`DecodeSession`]; each row still stops at its own limit.
    pub fn generate(&self, prompts: &[Vec<i32>], max_new: usize) -> Result<GenerationResult> {
        self.generate_with_limits(prompts, &vec![max_new; prompts.len()])
    }

    /// Like [`Self::generate`] but with a per-request `max_new`: row `i`
    /// receives exactly `max_new[i]` tokens (clamped to the cache), no
    /// matter what its co-batched neighbours asked for.
    pub fn generate_with_limits(
        &self,
        prompts: &[Vec<i32>],
        max_new: &[usize],
    ) -> Result<GenerationResult> {
        let b_real = prompts.len();
        if b_real == 0 {
            bail!("empty batch");
        }
        if max_new.len() != b_real {
            bail!("{} max_new limits for {b_real} prompts", max_new.len());
        }
        let bucket = self.backend.manifest().bucket_for(b_real)?;
        let mut session = self.new_session(bucket)?;
        let reqs: Vec<(usize, SlotRequest)> = prompts
            .iter()
            .zip(max_new)
            .enumerate()
            .map(|(i, (p, &mn))| {
                (i, SlotRequest { prompt: p.clone(), max_new: mn, stop: None })
            })
            .collect();
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); b_real];
        for (slot, toks) in session.prefill_into_slots(reqs)?.finished {
            out[slot] = toks;
        }
        while session.active() > 0 {
            for (slot, toks) in session.decode_step()?.finished {
                out[slot] = toks;
            }
        }
        Ok(session.into_result(out))
    }

    // ---- stage pieces ---------------------------------------------------

    fn embed(&self, tokens: &[i32], bucket: usize, s: usize, prefill: bool, bidx: usize) -> Result<Tensor> {
        let name = if prefill {
            self.names.embed_prefill[bidx].as_str()
        } else {
            self.names.embed_decode[bidx].as_str()
        };
        let mut outs = self.backend.execute(
            name,
            &[InputArg::I32(tokens, vec![bucket, s]), InputArg::Weight("embed")],
        )?;
        Ok(outs.remove(0))
    }

    fn lm_head(&self, x: &Tensor, prefill: bool, bidx: usize) -> Result<Tensor> {
        let name = if prefill {
            self.names.lm_head_prefill[bidx].as_str()
        } else {
            self.names.lm_head_decode[bidx].as_str()
        };
        let mut outs = self.backend.execute(
            name,
            &[InputArg::F32(x), InputArg::Weight("final_ln"), InputArg::Weight("lm_head")],
        )?;
        Ok(outs.remove(0))
    }

    /// Surface a TP shard thread's panic payload as a typed error so the
    /// worker loop can fail the batch and rebuild its session, instead of
    /// the panic tearing down the whole replica (and poisoning whatever
    /// locks the worker held).
    fn shard_panic_error(payload: &(dyn std::any::Any + Send)) -> anyhow::Error {
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
            .unwrap_or("opaque panic payload");
        anyhow!("TP shard thread panicked: {msg}")
    }

    /// Run `f` once per TP rank — concurrently under `std::thread::scope`
    /// when the backend is shareable, serially otherwise — returning the
    /// results in rank order (which keeps the downstream AllReduce
    /// deterministic). Shard executions that need per-rank `&mut` state
    /// (decode's cache pair) have their own fan-out in
    /// [`Self::layer_decode`].
    fn tp_fan_out<T, F>(&self, tp: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&dyn ExecutionBackend, usize) -> Result<T> + Sync,
    {
        match self.sync_backend_for(tp) {
            Some(be) => {
                let joined: Result<Vec<T>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..tp)
                        .map(|rank| {
                            let run = &f;
                            scope.spawn(move || run(be, rank))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| match h.join() {
                            Ok(res) => res,
                            Err(payload) => Err(Self::shard_panic_error(payload.as_ref())),
                        })
                        .collect()
                });
                joined
            }
            None => (0..tp).map(|rank| f(self.backend.as_ref(), rank)).collect(),
        }
    }

    /// Execute one MLP per TP shard (threaded when the backend allows)
    /// and return the partials in rank order.
    fn mlp_partials(
        &self,
        h: &Tensor,
        tp: usize,
        layer_names: &LayerNameCache,
        mlp_name: &str,
    ) -> Result<Vec<Tensor>> {
        self.tp_fan_out(tp, |be: &dyn ExecutionBackend, rank: usize| -> Result<Tensor> {
            let sh = &layer_names.shards[rank];
            let mut outs = be.execute(
                mlp_name,
                &[
                    InputArg::F32(h),
                    InputArg::Weight(&layer_names.ln2),
                    InputArg::Weight(&sh.w1),
                    InputArg::Weight(&sh.w2),
                ],
            )?;
            Ok(outs.remove(0))
        })
    }

    /// One prefill layer: TP-sharded attention + MLP with host AllReduce.
    /// Shards execute concurrently when the backend is shareable; their
    /// partials are reduced in rank order either way, so the result is
    /// identical to serial execution. Returns (new hidden state,
    /// per-shard (k, v) caches).
    fn layer_prefill(
        &self,
        x: &Tensor,
        si: usize,
        li: usize,
        bidx: usize,
        comm: &mut CommStats,
    ) -> Result<(Tensor, Vec<(Tensor, Tensor)>)> {
        let tp = self.stages[si].tp;
        let stage_names = &self.names.stages[si];
        let layer_names = &stage_names.layers[li];
        let attn_name = stage_names.attn_prefill[bidx].as_str();

        let shard_outs: Vec<(Tensor, Tensor, Tensor)> = self.tp_fan_out(
            tp,
            |be: &dyn ExecutionBackend, rank: usize| -> Result<(Tensor, Tensor, Tensor)> {
                let sh = &layer_names.shards[rank];
                let mut outs = be.execute(
                    attn_name,
                    &[
                        InputArg::F32(x),
                        InputArg::Weight(&layer_names.ln1),
                        InputArg::Weight(&sh.wq),
                        InputArg::Weight(&sh.wk),
                        InputArg::Weight(&sh.wv),
                        InputArg::Weight(&sh.wo),
                    ],
                )?;
                let v_cache = outs.pop().context("missing v_cache")?;
                let k_cache = outs.pop().context("missing k_cache")?;
                let partial = outs.pop().context("missing partial")?;
                Ok((partial, k_cache, v_cache))
            },
        )?;
        let mut partials = Vec::with_capacity(tp);
        let mut layer_caches = Vec::with_capacity(tp);
        for (partial, kc, vc) in shard_outs {
            partials.push(partial);
            layer_caches.push((kc, vc));
        }
        // Reduce the attention partials first and add the residual into
        // the reduction's buffer: identical bits (f32 addition of two
        // operands commutes), one tensor clone fewer per layer.
        let mut h = all_reduce_sum(partials, comm);
        add_residual(&mut h, x);

        let mlp = self.mlp_partials(&h, tp, layer_names, stage_names.mlp_prefill[bidx].as_str())?;
        let reduced = all_reduce_sum(mlp, comm);
        add_residual(&mut h, &reduced);
        Ok((h, layer_caches))
    }

    /// One decode layer; updates the per-shard caches **in place**
    /// through [`ExecutionBackend::execute_attn_decode_inplace`] — no
    /// cache clones or copies on this path. `positions[row]` is where
    /// that row's new KV entry lands (its cache depth); a uniform batch
    /// lowers to the scalar-position artifact signature, mixed depths
    /// (continuous batching) to a per-row vector. Shards execute
    /// concurrently when the backend is shareable, each owning its own
    /// `&mut` cache pair.
    #[allow(clippy::too_many_arguments)]
    fn layer_decode(
        &self,
        x: &Tensor,
        si: usize,
        li: usize,
        bidx: usize,
        positions: &[i32],
        caches: &mut [(Tensor, Tensor)],
        comm: &mut CommStats,
    ) -> Result<Tensor> {
        let tp = self.stages[si].tp;
        let stage_names = &self.names.stages[si];
        let layer_names = &stage_names.layers[li];
        let attn_name = stage_names.attn_decode[bidx].as_str();
        let uniform = positions.windows(2).all(|w| w[0] == w[1]);

        let exec_attn = |be: &dyn ExecutionBackend,
                         rank: usize,
                         k_cache: &mut Tensor,
                         v_cache: &mut Tensor|
         -> Result<Tensor> {
            let sh = &layer_names.shards[rank];
            let pos = if uniform {
                DecodePositions::Scalar(positions[0])
            } else {
                DecodePositions::PerRow(positions)
            };
            let w = AttnShardWeights {
                ln1: &layer_names.ln1,
                wq: &sh.wq,
                wk: &sh.wk,
                wv: &sh.wv,
                wo: &sh.wo,
            };
            be.execute_attn_decode_inplace(attn_name, x, k_cache, v_cache, pos, &w)
        };
        let partials: Vec<Tensor> = match self.sync_backend_for(tp) {
            Some(be) => {
                let joined: Result<Vec<_>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = caches
                        .iter_mut()
                        .enumerate()
                        .map(|(rank, (k_cache, v_cache))| {
                            let run = &exec_attn;
                            scope.spawn(move || run(be, rank, k_cache, v_cache))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| match h.join() {
                            Ok(res) => res,
                            Err(payload) => Err(Self::shard_panic_error(payload.as_ref())),
                        })
                        .collect()
                });
                joined?
            }
            None => caches
                .iter_mut()
                .enumerate()
                .map(|(rank, (k_cache, v_cache))| {
                    exec_attn(self.backend.as_ref(), rank, k_cache, v_cache)
                })
                .collect::<Result<Vec<_>>>()?,
        };
        // Same clone-free residual as layer_prefill: reduce, then add x
        // into the reduction's buffer.
        let mut h = all_reduce_sum(partials, comm);
        add_residual(&mut h, x);

        let mlp = self.mlp_partials(&h, tp, layer_names, stage_names.mlp_decode[bidx].as_str())?;
        let reduced = all_reduce_sum(mlp, comm);
        add_residual(&mut h, &reduced);
        Ok(h)
    }

    /// One verify layer for speculative decoding: [`Self::layer_decode`]
    /// over a `[b, s, h]` proposal batch, writing each row's `s` new KV
    /// entries in place through
    /// [`ExecutionBackend::execute_attn_score_inplace`]. `positions[row]`
    /// is the row's cache depth before the call — where its first new
    /// entry lands. Shard fan-out, rank-order reduction, and the
    /// residual adds mirror the decode layer exactly, so a verify pass
    /// is bit-identical to running the proposal token-by-token.
    #[allow(clippy::too_many_arguments)]
    fn layer_score(
        &self,
        x: &Tensor,
        si: usize,
        li: usize,
        bidx: usize,
        positions: &[i32],
        caches: &mut [(Tensor, Tensor)],
        comm: &mut CommStats,
    ) -> Result<Tensor> {
        let tp = self.stages[si].tp;
        let stage_names = &self.names.stages[si];
        let layer_names = &stage_names.layers[li];
        let attn_name = stage_names.attn_decode[bidx].as_str();
        let uniform = positions.windows(2).all(|w| w[0] == w[1]);

        let exec_attn = |be: &dyn ExecutionBackend,
                         rank: usize,
                         k_cache: &mut Tensor,
                         v_cache: &mut Tensor|
         -> Result<Tensor> {
            let sh = &layer_names.shards[rank];
            let pos = if uniform {
                DecodePositions::Scalar(positions[0])
            } else {
                DecodePositions::PerRow(positions)
            };
            let w = AttnShardWeights {
                ln1: &layer_names.ln1,
                wq: &sh.wq,
                wk: &sh.wk,
                wv: &sh.wv,
                wo: &sh.wo,
            };
            be.execute_attn_score_inplace(attn_name, x, k_cache, v_cache, pos, &w)
        };
        let partials: Vec<Tensor> = match self.sync_backend_for(tp) {
            Some(be) => {
                let joined: Result<Vec<_>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = caches
                        .iter_mut()
                        .enumerate()
                        .map(|(rank, (k_cache, v_cache))| {
                            let run = &exec_attn;
                            scope.spawn(move || run(be, rank, k_cache, v_cache))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| match h.join() {
                            Ok(res) => res,
                            Err(payload) => Err(Self::shard_panic_error(payload.as_ref())),
                        })
                        .collect()
                });
                joined?
            }
            None => caches
                .iter_mut()
                .enumerate()
                .map(|(rank, (k_cache, v_cache))| {
                    exec_attn(self.backend.as_ref(), rank, k_cache, v_cache)
                })
                .collect::<Result<Vec<_>>>()?,
        };
        // Same clone-free residual as layer_decode: reduce, then add x
        // into the reduction's buffer.
        let mut h = all_reduce_sum(partials, comm);
        add_residual(&mut h, x);

        let mlp = self.mlp_partials(&h, tp, layer_names, stage_names.mlp_decode[bidx].as_str())?;
        let reduced = all_reduce_sum(mlp, comm);
        add_residual(&mut h, &reduced);
        Ok(h)
    }
}

/// Result of one session step — an admission
/// ([`DecodeSession::prefill_into_slots`]) or a decode iteration
/// ([`DecodeSession::decode_step`]). `tokens` reports **every** row's new
/// token for the step (the serving loop streams these as
/// [`RequestEvent::Token`](super::api::RequestEvent) events while rows
/// are still decoding); `finished` the subset that retired.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// One `(slot, token)` per row that produced a token this step, in
    /// slot order.
    pub tokens: Vec<(usize, i32)>,
    /// Rows that retired this step: `(slot, full generated sequence)`.
    /// Their slots are freed (KV blocks released back to the pool) and
    /// admissible again.
    pub finished: Vec<(usize, Vec<i32>)>,
}

/// A serialized KV hand-off for one request: the populated cache rows
/// `[0, pos)` exported from a prefill replica's slot
/// ([`DecodeSession::export_rows`]) and imported into a decode replica's
/// fresh slot ([`DecodeSession::import_rows`]) — the block-granular
/// transfer that disaggregated prefill/decode serving ships between
/// phase roles. The layout is plan-agnostic: one `(k, v)` pair of
/// `[1, heads, pos, head_dim]` tensors per model layer, with every TP
/// shard's head window assembled in head order, so the exporting and
/// importing replicas may run different TP/PP plans over the same model.
#[derive(Debug, Clone)]
pub struct KvSegment {
    /// Populated KV rows (the request's cache depth at hand-off).
    pub pos: usize,
    /// The token the prefill pass produced — the decode side's first
    /// input token and the head of its `generated` sequence.
    pub first_token: i32,
    /// Per-model-layer `(k, v)` tensors of `[1, heads, pos, head_dim]`.
    pub layers: Vec<(Tensor, Tensor)>,
}

impl KvSegment {
    /// Bytes this segment ships between replicas (f32 storage), the
    /// quantity metered as `kv_transfer_bytes`.
    pub fn num_bytes(&self) -> f64 {
        self.layers
            .iter()
            .map(|(k, v)| ((k.data.len() + v.data.len()) * 4) as f64)
            .sum()
    }
}

/// A request to admit into a [`DecodeSession`] slot.
#[derive(Debug, Clone)]
pub struct SlotRequest {
    /// Exactly `prompt_len` tokens (see [`crate::runtime::tokenizer`]).
    pub prompt: Vec<i32>,
    /// Per-request generation limit (clamped to `max_seq - prompt_len`).
    pub max_new: usize,
    /// Optional stop token: the row retires as soon as it emits this.
    pub stop: Option<i32>,
}

/// Per-slot decode state.
struct SlotState {
    max_new: usize,
    stop: Option<i32>,
    /// Tokens generated so far (the first came from prefill logits).
    generated: Vec<i32>,
    /// Next input token for the coming decode step.
    next: i32,
    /// Cache depth = where the next KV entry is written.
    pos: usize,
}

/// Read-only snapshot of one occupied slot's decode state
/// ([`DecodeSession::slot_view`]) — what a speculation driver
/// coordinating two sessions needs to size a round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotView {
    /// Tokens generated so far.
    pub generated: usize,
    /// The row's generation limit (already clamped to the cache).
    pub max_new: usize,
    /// The row's stop token, if any.
    pub stop: Option<i32>,
    /// Next input token for the coming step.
    pub next: i32,
    /// Cache depth = where the next KV entry is written.
    pub pos: usize,
}

/// Persistent step-granular decode state over a [`PipelineExecutor`]:
/// `bucket` KV-cache slots shared by all in-flight rows. The serving
/// loop interleaves [`Self::prefill_into_slots`] (admission) with
/// [`Self::decode_step`] (one token for every active row), so a late
/// request joins an in-flight batch at the next step boundary instead of
/// waiting behind it, and every row stops at its own `max_new`/stop
/// token — continuous (iteration-level) batching.
pub struct DecodeSession<'a> {
    exec: &'a PipelineExecutor,
    bucket: usize,
    /// KV rows per physical block.
    block_tokens: usize,
    /// Paged KV storage of record: `[stage][layer][shard] -> (k, v)`,
    /// each `[pool_blocks, nhs, block_tokens, dh]` with dim 0 the
    /// physical block id (the same id addresses every tensor).
    block_store: Vec<StageCaches>,
    /// Physical block allocator: free list, refcounts (prefix sharing),
    /// and the admission reservation ledger.
    pool: BlockPool,
    /// Per-slot logical-position → physical-block maps.
    tables: Vec<BlockTable>,
    /// Hashed prompt-chunk → block cache backing prefix sharing.
    prefix: PrefixCache,
    /// Dense decode scratch (the kernel contract is `[b, nhs, max_seq,
    /// dh]` per shard), one per bucket a step has run at, allocated
    /// lazily. Gathers are skipped per row when its residency already
    /// matches — see [`StepScratch`].
    step_caches: Vec<StepScratch>,
    slots: Vec<Option<SlotState>>,
    comm: CommStats,
    decode_steps: usize,
    prefill_tokens: usize,
    /// Admissions whose forward pass was skipped: every prompt chunk hit
    /// the prefix cache and the full-prompt chain carried a memoized
    /// first token, so the row was served from cached KV alone.
    prefill_skips: usize,
    prefill_seconds: f64,
    decode_seconds: f64,
    // Step-scoped scratch, reused across calls so the `lint: hot-path`
    // regions in decode_step / prefill_into_slots stay allocation-free
    // in steady state (capacity is reserved once at session creation).
    /// Indices of the active slots for the step in flight.
    scratch_active: Vec<usize>,
    /// Per-row input tokens for a decode step.
    scratch_tokens: Vec<i32>,
    /// Per-row cache depths for a decode step.
    scratch_positions: Vec<i32>,
    /// Flattened, padded prompt batch for an admission prefill.
    scratch_prompt: Vec<i32>,
    /// Flattened `[admitted row][prompt chunk]` prefix-cache miss mask
    /// for an admission: marks the blocks prefill must hand KV off to.
    scratch_miss: Vec<bool>,
    /// Per-admitted-row final prompt-chain keys (full-prompt identity
    /// for the first-token memo).
    scratch_keys: Vec<u64>,
    /// Original indices of the admitted rows that need the forward pass
    /// (rows absent here were full-prefix hits with a memoized token).
    scratch_compute: Vec<usize>,
}

/// Dense per-bucket decode scratch with per-row residency. `resident[r]
/// == Some((slot, depth))` records that scratch row `r` holds exactly
/// rows `[0, depth)` of `slot`'s KV — matching rows skip the gather, so
/// a steady-state step's block traffic is one scattered row per active
/// slot. Entries are invalidated whenever their slot releases its
/// blocks (retire/cancel/rollback) and for pad rows each step (the
/// kernel writes the filler position into them).
struct StepScratch {
    bucket: usize,
    /// `[stage][layer][shard] -> (k, v)`, each `[bucket, nhs, max_seq, dh]`.
    caches: Vec<StageCaches>,
    resident: Vec<Option<(usize, usize)>>,
}

impl<'a> DecodeSession<'a> {
    /// Cache slots in this session (an artifact bucket).
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// The artifact catalog + model architecture this session serves.
    pub fn manifest(&self) -> &crate::runtime::Manifest {
        self.exec.backend.manifest()
    }

    /// Rows currently decoding.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Slots available for admission.
    pub fn free_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    /// KV rows per physical block in this session.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Physical blocks in the session's KV pool.
    pub fn kv_blocks_total(&self) -> usize {
        self.pool.num_blocks()
    }

    /// Blocks currently referenced by in-flight rows.
    pub fn kv_blocks_used(&self) -> usize {
        self.pool.used_blocks()
    }

    /// High-water mark of [`Self::kv_blocks_used`] over the session's
    /// lifetime — what a right-sized pool would have needed.
    pub fn kv_blocks_peak(&self) -> usize {
        self.pool.peak_used_blocks()
    }

    /// Free blocks not yet promised to an admitted row: the budget the
    /// service's admission gate spends against.
    pub fn free_block_budget(&self) -> usize {
        self.pool.available()
    }

    /// Worst-case blocks an admission with this `max_new` must reserve:
    /// the prompt rows plus every decode append, before any prefix
    /// sharing (shared full blocks hand their reservation back at
    /// admission). This is exactly what
    /// [`Self::prefill_into_slots`] reserves, so gating admission on it
    /// against [`Self::free_block_budget`] never over-commits.
    pub fn blocks_needed(&self, max_new: usize) -> usize {
        let prompt_len = self.exec.backend.manifest().model.prompt_len;
        self.blocks_needed_at(prompt_len, max_new)
    }

    /// [`Self::blocks_needed`] for a row whose cache is already `pos`
    /// rows deep — what [`Self::import_rows`] reserves for a handed-off
    /// KV segment, so a decode-role serving loop can gate imports on it
    /// against [`Self::free_block_budget`].
    pub fn blocks_needed_at(&self, pos: usize, max_new: usize) -> usize {
        let info = &self.exec.backend.manifest().model;
        let mn = max_new.min(info.max_seq.saturating_sub(pos)).max(1);
        // The final generated token is returned without a KV append, so
        // a row's deepest written position is pos + mn - 2.
        self.pool.blocks_for(pos + mn - 1)
    }

    /// Prefix-cache chunk hits since session creation.
    pub fn prefix_cache_hits(&self) -> u64 {
        self.prefix.hits()
    }

    /// Prefix-cache chunk misses since session creation.
    pub fn prefix_cache_misses(&self) -> u64 {
        self.prefix.misses()
    }

    /// True when no block is referenced and no reservation is
    /// outstanding — every retire/cancel/rollback path returned its
    /// blocks (the leak-check invariant for a drained session).
    pub fn kv_pool_fully_free(&self) -> bool {
        self.pool.is_fully_free()
    }

    /// True decode iterations executed so far.
    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    /// Admissions served without a forward pass: every prompt chunk hit
    /// the prefix cache and the full-prompt chain had a memoized first
    /// token (greedy prefill is deterministic, so the cached rows and
    /// token are exactly what the pass would have produced).
    pub fn prefill_skips(&self) -> usize {
        self.prefill_skips
    }

    pub fn prefill_seconds(&self) -> f64 {
        self.prefill_seconds
    }

    pub fn decode_seconds(&self) -> f64 {
        self.decode_seconds
    }

    /// Drain the communication counters accumulated since the last call.
    pub fn take_comm(&mut self) -> CommStats {
        std::mem::take(&mut self.comm)
    }

    /// Admit requests into free slots: reserve each row's worst-case
    /// block budget, resolve its prompt chunk-by-chunk against the
    /// prefix cache (shared chunks reuse live blocks refcounted), run
    /// the prefill (at the smallest bucket that fits the admission
    /// batch), and hand the resulting KV rows directly off into the
    /// freshly allocated blocks — shared chunks are not copied at all.
    /// Callable between any two decode steps; in-flight rows are
    /// untouched. The outcome's `tokens` carry each admitted row's
    /// prefill-produced token; `finished` the rows that already
    /// completed at prefill (`max_new == 1` or stop token emitted),
    /// whose slots and blocks are freed again.
    ///
    /// Errors release everything the failed admission acquired: block
    /// exhaustion (the caller should gate on [`Self::blocks_needed`] /
    /// [`Self::free_block_budget`] and defer instead) and model failures
    /// both leave the pool exactly as it was, with in-flight rows
    /// untouched.
    ///
    /// Admitting while other rows are mid-decode leaves rows at different
    /// cache depths, which requires
    /// [`ExecutionBackend::supports_rowwise_decode_positions`]; on
    /// scalar-position backends (the AOT artifact signature) only admit
    /// into an idle session, as the service loop does.
    pub fn prefill_into_slots(&mut self, reqs: Vec<(usize, SlotRequest)>) -> Result<StepOutcome> {
        if reqs.is_empty() {
            return Ok(StepOutcome::default());
        }
        // lint: hot-path — admission runs at every step boundary; no
        // allocations beyond growth into the session's reserved scratch.
        let exec = self.exec;
        let info = &exec.backend.manifest().model;
        for (i, (slot, r)) in reqs.iter().enumerate() {
            if *slot >= self.bucket {
                bail!("slot {slot} outside session bucket {}", self.bucket);
            }
            if self.slots[*slot].is_some() || reqs[..i].iter().any(|(s, _)| s == slot) {
                bail!("slot {slot} is already occupied");
            }
            if r.prompt.len() != info.prompt_len {
                bail!("prompt must be exactly {} tokens, got {}", info.prompt_len, r.prompt.len());
            }
            if r.max_new == 0 {
                bail!("max_new must be >= 1");
            }
        }
        // Validates the admission count fits a manifest bucket even when
        // every row ends up skipping the forward pass below.
        exec.backend.manifest().bucket_for(reqs.len())?;
        let t0 = Instant::now();

        // Phase 1 — logical admission: reserve block budgets and build
        // block tables against the prefix cache, before any model work.
        // `miss[row * cpp + chunk]` marks the blocks phase 2 must fill.
        // Rows whose every chunk hit *and* whose full-prompt chain has a
        // memoized first token skip the forward pass entirely: the
        // chained verified lookups prove this exact prompt was prefilled
        // before, and greedy decoding is deterministic.
        let cpp = info.prompt_len.div_ceil(self.block_tokens);
        let mut miss = std::mem::take(&mut self.scratch_miss);
        miss.clear();
        miss.resize(reqs.len() * cpp, false);
        let mut keys = std::mem::take(&mut self.scratch_keys);
        keys.clear();
        let mut compute = std::mem::take(&mut self.scratch_compute);
        compute.clear();
        for (ri, (slot, r)) in reqs.iter().enumerate() {
            match self.admit_row(*slot, r, ri, cpp, &mut miss) {
                Ok((key, all_hit)) => {
                    keys.push(key);
                    if !(all_hit && self.prefix.first_token(key).is_some()) {
                        compute.push(ri);
                    }
                }
                Err(e) => {
                    self.rollback_admission(&reqs[..=ri])?;
                    return Err(e);
                }
            }
        }

        // Phase 2 — model prefill over the rows that need computing,
        // handing each one's missed chunks straight off into its blocks
        // (shared chunks copy nothing; skipped rows have none). The
        // batch runs at the smallest bucket covering the computed rows,
        // and is elided entirely when every row was a full-prefix hit.
        let next = if compute.is_empty() {
            None
        } else {
            let pb = exec.backend.manifest().bucket_for(compute.len())?;
            let bidx = exec.names.bucket_idx(pb)?;
            match self.prefill_run(&reqs, &compute, pb, bidx, &miss, cpp) {
                Ok(logits) => Some(argmax_rows(&logits, info.vocab)),
                Err(e) => {
                    self.rollback_admission(&reqs)?;
                    return Err(e);
                }
            }
        };
        self.prefill_seconds += t0.elapsed().as_secs_f64();
        self.prefill_tokens += reqs.len();

        // Phase 3 — commit slot states; rows done at prefill free their
        // blocks immediately. Computed rows memoize their first token on
        // their full-prompt chain; skipped rows replay the memo.
        let max_decode = info.max_seq - info.prompt_len;
        let mut out = StepOutcome::default();
        let mut next_i = 0usize;
        for (row, (slot, r)) in reqs.into_iter().enumerate() {
            let tok = if compute.get(next_i) == Some(&row) {
                let toks = next
                    .as_ref()
                    .ok_or_else(|| anyhow!("internal: missing prefill logits for computed rows"))?;
                let tok = toks[next_i];
                next_i += 1;
                self.prefix.memo_first_token(keys[row], tok);
                tok
            } else {
                self.prefill_skips += 1;
                self.prefix
                    .first_token(keys[row])
                    .ok_or_else(|| anyhow!("internal: full-prefix skip lost its memoized token"))?
            };
            out.tokens.push((slot, tok));
            let mut st = SlotState {
                max_new: r.max_new.min(max_decode).max(1),
                stop: r.stop,
                generated: Vec::new(),
                next: tok,
                pos: info.prompt_len,
            };
            st.generated.push(tok);
            if st.generated.len() >= st.max_new || Some(tok) == st.stop {
                self.release_slot_blocks(slot)?;
                out.finished.push((slot, st.generated));
            } else {
                self.slots[slot] = Some(st);
            }
        }
        self.scratch_miss = miss;
        self.scratch_keys = keys;
        self.scratch_compute = compute;
        Ok(out)
        // lint: hot-path-end
    }

    /// Phase 1 of admission for one row: reserve its worst-case block
    /// budget ([`Self::blocks_needed`]) and resolve its prompt chunks
    /// against the prefix cache, building its block table. Marks freshly
    /// allocated chunks in `miss` for the prefill hand-off. Returns the
    /// final chain key (the full prompt's verified identity) and whether
    /// every chunk hit — the inputs to the prefill-compute skip. On
    /// error the row's partial state is released by the caller's
    /// rollback.
    fn admit_row(
        &mut self,
        slot: usize,
        r: &SlotRequest,
        row_idx: usize,
        cpp: usize,
        miss: &mut [bool],
    ) -> Result<(u64, bool)> {
        let need = self.blocks_needed(r.max_new);
        if !self.pool.try_reserve(need) {
            bail!(
                "kv block pool exhausted admitting slot {slot}: need {need} blocks, {} available",
                self.pool.available()
            );
        }
        if let Err(e) = self.tables[slot].begin(need) {
            self.pool.release_reservation(need)?;
            return Err(e);
        }
        let mut chain = PREFIX_HASH_SEED;
        let mut parent: Option<usize> = None;
        let mut all_hit = true;
        for (ci, chunk) in r.prompt.chunks(self.block_tokens).enumerate() {
            let key = PrefixCache::chain_key(chain, ci, chunk);
            if let Some(bid) = self.prefix.lookup(key, parent, chunk) {
                self.pool.retain(bid)?;
                self.tables[slot].push(bid);
                self.tables[slot].use_reservation()?;
                if chunk.len() == self.block_tokens {
                    // Shared full blocks are never written again: hand
                    // the reservation straight back to the admission
                    // budget.
                    self.pool.release_reservation(1)?;
                } else {
                    // Shared partial tail: pledge the reservation to the
                    // block as a copy-on-write credit. *Either* sharer —
                    // including the row that materialized the block,
                    // whose own budget is exactly sized — may be the
                    // first to append into it, and the first divergence
                    // spends this credit ([`BlockPool::alloc_cow`]).
                    self.pool.earmark_cow(bid)?;
                }
                parent = Some(bid);
            } else {
                self.tables[slot].use_reservation()?;
                let bid = self.pool.alloc_reserved()?;
                self.tables[slot].push(bid);
                self.prefix.insert(key, bid, parent, chunk);
                miss[row_idx * cpp + ci] = true;
                all_hit = false;
                parent = Some(bid);
            }
            chain = key;
        }
        Ok((chain, all_hit))
    }

    /// Undo phase-1 admissions after a failure: release every listed
    /// row's blocks and reservations. Rows that never reached phase 1
    /// (empty tables) are no-ops, so the slice may include the row that
    /// failed mid-way.
    fn rollback_admission(&mut self, reqs: &[(usize, SlotRequest)]) -> Result<()> {
        for (slot, _) in reqs {
            self.release_slot_blocks(*slot)?;
        }
        Ok(())
    }

    /// Phase 2 of admission: run the model prefill over the padded
    /// batch of computed rows (`rows` indexes into `reqs`; full-prefix
    /// skipped rows are excluded and batch row `i` is `reqs[rows[i]]`)
    /// and hand each row's freshly-allocated (missed) chunks off into
    /// its blocks as each layer's caches materialize. Shared chunks
    /// (prefix-cache hits) already hold identical bytes — causal
    /// attention makes a position's KV a function of the tokens at and
    /// before it — so they are skipped entirely; that is the prefill
    /// cache hand-off that makes shared-prefix admission cheaper than
    /// dense copying. Returns the prefill logits (one row per entry of
    /// `rows`, then padding).
    fn prefill_run(
        &mut self,
        reqs: &[(usize, SlotRequest)],
        rows: &[usize],
        pb: usize,
        bidx: usize,
        miss: &[bool],
        cpp: usize,
    ) -> Result<Tensor> {
        let exec = self.exec;
        let info = &exec.backend.manifest().model;
        let mut tokens = std::mem::take(&mut self.scratch_prompt);
        tokens.clear();
        tokens.reserve(pb * info.prompt_len);
        for &ri in rows {
            tokens.extend_from_slice(&reqs[ri].1.prompt);
        }
        tokens.resize(pb * info.prompt_len, tokenizer::PAD);

        let bt = self.block_tokens;
        let mut x = exec.embed(&tokens, pb, info.prompt_len, true, bidx)?;
        for (si, stage) in exec.stages.iter().enumerate() {
            for li in 0..stage.layer_count {
                let (h, layer_caches) = exec.layer_prefill(&x, si, li, bidx, &mut self.comm)?;
                x = h;
                for (shard, (kc, vc)) in layer_caches.iter().enumerate() {
                    let (dst_k, dst_v) = &mut self.block_store[si][li][shard];
                    for (bri, &ri) in rows.iter().enumerate() {
                        let slot = reqs[ri].0;
                        for (ci, &bid) in self.tables[slot].blocks().iter().enumerate() {
                            if !miss[ri * cpp + ci] {
                                continue;
                            }
                            let start = ci * bt;
                            let n = (info.prompt_len - start).min(bt);
                            dst_k.copy_cache_rows_between(bid, 0, kc, bri, start, n)?;
                            dst_v.copy_cache_rows_between(bid, 0, vc, bri, start, n)?;
                        }
                    }
                }
            }
            if si + 1 < exec.stages.len() {
                record_pp_send(&x, &mut self.comm);
            }
        }
        self.scratch_prompt = tokens;
        exec.lm_head(&x, true, bidx)
    }

    /// Run one decode iteration for every active row, reporting each
    /// row's new token in the outcome's `tokens`. Rows that hit their own
    /// `max_new` or stop token retire into `finished`: their slots are
    /// freed (KV blocks released) and their full token sequences
    /// returned. A no-op returning an empty outcome when nothing is
    /// active.
    ///
    /// The step is **active-row-aware**: it executes at the smallest
    /// manifest bucket covering the live rows, with active rows packed
    /// into scratch rows `[0, n)` — so a draining session's attention,
    /// MLP, and lm_head cost tracks its live rows, not its slot count.
    /// The kernels run over dense per-bucket scratch (their contract);
    /// each row's block-backed prefix is gathered in (skipped when its
    /// residency already matches from the previous step) and only the
    /// newly appended KV entry scatters back into the row's tail block.
    /// Row results are bit-identical to a dense backing: gathers replay
    /// exact bytes and every per-row computation is independent of batch
    /// padding and row index.
    pub fn decode_step(&mut self) -> Result<StepOutcome> {
        if self.active() == 0 {
            return Ok(StepOutcome::default());
        }
        // lint: hot-path — the per-token loop; allocation-free in steady
        // state (scratch buffers are reserved at session creation).
        let exec = self.exec;
        let info = &exec.backend.manifest().model;
        let t0 = Instant::now();

        let mut active_slots = std::mem::take(&mut self.scratch_active);
        active_slots.clear();
        for (i, s) in self.slots.iter().enumerate() {
            if s.is_some() {
                active_slots.push(i);
            }
        }
        let sb = exec.backend.manifest().bucket_for(active_slots.len())?.min(self.bucket);
        let bidx = exec.names.bucket_idx(sb)?;
        let ci = self.gather_step_caches(&active_slots, sb)?;

        // Row layout: active rows pack into scratch rows [0, n).
        let mut tok_batch = std::mem::take(&mut self.scratch_tokens);
        tok_batch.clear();
        tok_batch.resize(sb, tokenizer::PAD);
        let mut positions = std::mem::take(&mut self.scratch_positions);
        positions.clear();
        positions.resize(sb, 0i32);
        let mut filler_pos = 0i32;
        for (row, &slot) in active_slots.iter().enumerate() {
            let Some(st) = self.slots[slot].as_ref() else {
                bail!("internal: active slot {slot} lost its state mid-step");
            };
            tok_batch[row] = st.next;
            positions[row] = st.pos as i32;
            filler_pos = st.pos as i32;
        }
        // Pad rows mirror an active row's position so a uniform batch
        // keeps the scalar-position artifact signature available.
        for row in active_slots.len()..sb {
            positions[row] = filler_pos;
        }

        let mut x = exec.embed(&tok_batch, sb, 1, false, bidx)?;
        for (si, stage) in exec.stages.iter().enumerate() {
            for li in 0..stage.layer_count {
                let caches = &mut self.step_caches[ci].caches[si][li];
                x = exec.layer_decode(&x, si, li, bidx, &positions, caches, &mut self.comm)?;
            }
            if si + 1 < exec.stages.len() {
                record_pp_send(&x, &mut self.comm);
            }
        }
        self.scatter_step_caches(&active_slots, ci)?;
        let logits = exec.lm_head(&x, false, bidx)?;
        let next = argmax_rows(&logits, info.vocab);
        self.decode_steps += 1;
        self.decode_seconds += t0.elapsed().as_secs_f64();

        let mut out = StepOutcome::default();
        for (row, &slot) in active_slots.iter().enumerate() {
            let done = {
                let Some(st) = self.slots[slot].as_mut() else {
                    bail!("internal: active slot {slot} lost its state mid-step");
                };
                let tok = next[row];
                st.generated.push(tok);
                st.next = tok;
                st.pos += 1;
                out.tokens.push((slot, tok));
                st.generated.len() >= st.max_new || Some(tok) == st.stop
            };
            if done {
                let Some(st) = self.slots[slot].take() else {
                    bail!("internal: active slot {slot} lost its state mid-step");
                };
                self.release_slot_blocks(slot)?;
                out.finished.push((slot, st.generated));
            }
        }
        self.scratch_active = active_slots;
        self.scratch_tokens = tok_batch;
        self.scratch_positions = positions;
        Ok(out)
        // lint: hot-path-end
    }

    /// Score `tokens` for the row in `slot` in **one batched forward** —
    /// the target-model half of a speculative round. The caller feeds
    /// the row's pending input token followed by the draft's proposals;
    /// the pass writes their KV entries at `pos .. pos + tokens.len()`
    /// (scattered into the row's tail blocks exactly as that many
    /// sequential decode steps would) and returns the greedy (argmax)
    /// token **per fed position** — what plain decode would have emitted
    /// after each of the fed tokens. Unlike [`Self::decode_step`] it
    /// commits no token state: the caller compares the returned tokens
    /// against the proposals, rolls the cache back past the rejected
    /// tail ([`Self::truncate_rows`]), and commits the accepted tokens
    /// ([`Self::commit_tokens`]).
    pub fn verify_step(&mut self, slot: usize, tokens: &[i32]) -> Result<Vec<i32>> {
        let exec = self.exec;
        let info = &exec.backend.manifest().model;
        let s = tokens.len();
        if s == 0 {
            bail!("verify step needs at least one token");
        }
        let Some(st) = self.slots.get(slot).and_then(Option::as_ref) else {
            bail!("verify step on free slot {slot}");
        };
        let pos = st.pos;
        if pos + s > info.max_seq {
            bail!("verifying {s} tokens at depth {pos} overruns max_seq {}", info.max_seq);
        }
        let t0 = Instant::now();
        let sb = exec.backend.manifest().bucket_for(1)?.min(self.bucket);
        let bidx = exec.names.bucket_idx(sb)?;
        let active = [slot];
        let ci = self.gather_step_caches(&active, sb)?;

        // Row layout: the verified row is scratch row 0; pad rows mirror
        // its position so a uniform batch keeps the scalar-position
        // artifact signature available.
        let mut tok_batch = std::mem::take(&mut self.scratch_tokens);
        tok_batch.clear();
        tok_batch.resize(sb * s, tokenizer::PAD);
        tok_batch[..s].copy_from_slice(tokens);
        let mut positions = std::mem::take(&mut self.scratch_positions);
        positions.clear();
        positions.resize(sb, pos as i32);

        let mut x = exec.embed(&tok_batch, sb, s, false, bidx)?;
        for (si, stage) in exec.stages.iter().enumerate() {
            for li in 0..stage.layer_count {
                let caches = &mut self.step_caches[ci].caches[si][li];
                x = exec.layer_score(&x, si, li, bidx, &positions, caches, &mut self.comm)?;
            }
            if si + 1 < exec.stages.len() {
                record_pp_send(&x, &mut self.comm);
            }
        }
        self.scatter_score_rows(slot, ci, pos, s)?;

        // Per-position greedy tokens: the lm_head artifact reads only
        // the last position of its input, so slice each position out as
        // a [sb, 1, h] view. (A [sb*s, 1, h] reshape would break the
        // artifact's bucket check.)
        let h = info.hidden;
        let mut out = Vec::with_capacity(s);
        let mut xi = Tensor { dims: vec![sb, 1, h], data: vec![0.0; sb * h] };
        for i in 0..s {
            for bi in 0..sb {
                let src = (bi * s + i) * h;
                xi.data[bi * h..(bi + 1) * h].copy_from_slice(&x.data[src..src + h]);
            }
            let logits = exec.lm_head(&xi, false, bidx)?;
            out.push(argmax_rows(&logits, info.vocab)[0]);
        }
        match self.slots.get_mut(slot).and_then(Option::as_mut) {
            Some(st) => st.pos += s,
            None => bail!("internal: verified slot {slot} lost its state mid-step"),
        }
        self.decode_steps += 1;
        self.decode_seconds += t0.elapsed().as_secs_f64();
        self.scratch_tokens = tok_batch;
        self.scratch_positions = positions;
        Ok(out)
    }

    /// Scatter the `s` KV entries a verify pass appended for `slot`
    /// (scratch row 0 of scratch `ci`, positions `pos .. pos + s`) back
    /// into the row's tail blocks, planning each append through the
    /// block table exactly as sequential decode steps would — fresh
    /// blocks at block boundaries, copy-on-write on a shared tail.
    /// Residency advances to `(slot, pos + s)`.
    fn scatter_score_rows(&mut self, slot: usize, ci: usize, pos: usize, s: usize) -> Result<()> {
        let DecodeSession { step_caches, block_store, tables, pool, .. } = self;
        let scratch = &mut step_caches[ci];
        // lint: hot-path — the verify scatter loop: O(1) bookkeeping per
        // appended position plus in-place block copies, no allocation.
        for i in 0..s {
            let p = pos + i;
            let op = plan_append(pool, &mut tables[slot], p)?;
            let (block, block_row) = match op {
                AppendOp::Write { block, row: block_row } => (block, block_row),
                AppendOp::CowWrite { src, block, copy_rows, row: block_row } => {
                    for stage_caches in block_store.iter_mut() {
                        for layer in stage_caches.iter_mut() {
                            for (bk, bv) in layer.iter_mut() {
                                bk.copy_cache_rows_within(block, src, copy_rows)?;
                                bv.copy_cache_rows_within(block, src, copy_rows)?;
                            }
                        }
                    }
                    (block, block_row)
                }
            };
            for (si, stage_caches) in block_store.iter_mut().enumerate() {
                for (li, layer) in stage_caches.iter_mut().enumerate() {
                    for (shard, (bk, bv)) in layer.iter_mut().enumerate() {
                        let (sk, sv) = &scratch.caches[si][li][shard];
                        bk.copy_cache_rows_between(block, block_row, sk, 0, p, 1)?;
                        bv.copy_cache_rows_between(block, block_row, sv, 0, p, 1)?;
                    }
                }
            }
        }
        scratch.resident[0] = Some((slot, pos + s));
        Ok(())
        // lint: hot-path-end
    }

    /// Roll the row in `slot` back to cache depth `depth` (its next KV
    /// entry will land at `depth`): the paged-KV rollback half of a
    /// speculative round, discarding the entries of rejected proposal
    /// tokens. Tail blocks past the kept region pop back to the free
    /// list with the row's own block budget restored
    /// ([`BlockTable::pop_tail_reclaim`] →
    /// [`BlockPool::reclaim_reservation`]), so the row can still grow to
    /// its admission-time worst case; the kept tail block's deeper rows
    /// stay in place as dead bytes (attention reads `[0, pos)`) that the
    /// next append overwrites. Shared prompt blocks are never popped:
    /// `depth` is at or past the prompt, and generated-region blocks are
    /// private to the row (appends copy-on-write a shared tail before
    /// writing, and generated blocks are never published to the prefix
    /// cache).
    ///
    /// Token state (`generated`, `next`) is the caller's to fix up via
    /// [`Self::commit_tokens`]; this method moves only the cache.
    pub fn truncate_rows(&mut self, slot: usize, depth: usize) -> Result<()> {
        let prompt_len = self.exec.backend.manifest().model.prompt_len;
        let Some(st) = self.slots.get(slot).and_then(Option::as_ref) else {
            bail!("truncating free slot {slot}");
        };
        if depth < prompt_len || depth > st.pos {
            bail!("truncating slot {slot} to depth {depth} outside [{prompt_len}, {}]", st.pos);
        }
        let keep = depth.div_ceil(self.block_tokens);
        let DecodeSession { pool, prefix, tables, step_caches, slots, .. } = self;
        let table = &mut tables[slot];
        // lint: hot-path — the rollback loop: pop → release → reclaim
        // per rejected tail block, O(1) bookkeeping each.
        while table.len() > keep {
            let Some(block) = table.pop_tail_reclaim() else {
                bail!("internal: rollback of slot {slot} popped an empty table");
            };
            if pool.release(block)? {
                prefix.forget(block);
            }
            pool.reclaim_reservation(1)?;
        }
        // Scratch rows holding this slot deeper than `depth` still
        // byte-match rows [0, depth) (gathers and appends never touch
        // shallower rows), so clamp residency instead of dropping it —
        // the next gather at this depth is then a no-op.
        for sc in step_caches.iter_mut() {
            for r in sc.resident.iter_mut() {
                if let Some((rslot, rd)) = *r {
                    if rslot == slot && rd > depth {
                        *r = Some((slot, depth));
                    }
                }
            }
        }
        // lint: hot-path-end
        match slots[slot].as_mut() {
            Some(st) => st.pos = depth,
            None => bail!("internal: truncated slot {slot} lost its state"),
        }
        Ok(())
    }

    /// Replace the row's speculated token tail: truncate `generated` to
    /// its first `keep` tokens and extend it with `accepted` — the
    /// commit half of a speculative round, once the cache has been
    /// rolled back / advanced to `prompt_len + keep + accepted.len() -
    /// 1` (enforced here; a mismatch means the driver desynchronized
    /// tokens from cache). The last accepted token becomes the row's
    /// next input. Rows that hit their `max_new` or end on their stop
    /// token retire exactly like [`Self::decode_step`] rows: blocks
    /// released, slot freed, full sequence returned. `None` means the
    /// row is still decoding.
    pub fn commit_tokens(
        &mut self,
        slot: usize,
        keep: usize,
        accepted: &[i32],
    ) -> Result<Option<Vec<i32>>> {
        let prompt_len = self.exec.backend.manifest().model.prompt_len;
        let done = {
            let Some(st) = self.slots.get_mut(slot).and_then(Option::as_mut) else {
                bail!("committing tokens into free slot {slot}");
            };
            if accepted.is_empty() {
                bail!("a speculative round must commit at least one token");
            }
            if keep > st.generated.len() {
                bail!("keeping {keep} of {} generated tokens", st.generated.len());
            }
            st.generated.truncate(keep);
            st.generated.extend_from_slice(accepted);
            let last = accepted[accepted.len() - 1];
            st.next = last;
            if st.pos != prompt_len + st.generated.len() - 1 {
                bail!(
                    "slot {slot} cache depth {} disagrees with {} committed tokens",
                    st.pos,
                    st.generated.len()
                );
            }
            st.generated.len() >= st.max_new || Some(last) == st.stop
        };
        if done {
            let Some(st) = self.slots.get_mut(slot).and_then(Option::take) else {
                bail!("internal: committed slot {slot} lost its state");
            };
            self.release_slot_blocks(slot)?;
            return Ok(Some(st.generated));
        }
        Ok(None)
    }

    /// Read-only snapshot of the row in `slot`, or `None` when the slot
    /// is free.
    pub fn slot_view(&self, slot: usize) -> Option<SlotView> {
        self.slots.get(slot).and_then(Option::as_ref).map(|st| SlotView {
            generated: st.generated.len(),
            max_new: st.max_new,
            stop: st.stop,
            next: st.next,
            pos: st.pos,
        })
    }

    /// Overwrite the row's pending token — its last generated token and
    /// next step input — without touching the cache. A speculation
    /// driver uses it right after admitting the draft row to align the
    /// draft's prefill token with the target's (the emitted stream is
    /// the target's; the draft merely proposes continuations of it). The
    /// rewritten token's KV entry has not been written yet (`pos` still
    /// points at it), so no cache state is invalidated.
    pub fn force_next(&mut self, slot: usize, token: i32) -> Result<()> {
        let Some(st) = self.slots.get_mut(slot).and_then(Option::as_mut) else {
            bail!("forcing next token on free slot {slot}");
        };
        let Some(last) = st.generated.last_mut() else {
            bail!("forcing next token on slot {slot} with no generated tokens");
        };
        *last = token;
        st.next = token;
        Ok(())
    }

    /// Cancel the request occupying `slot`: drop its decode state,
    /// release its KV blocks back to the pool, and free the slot for
    /// admission. Returns the tokens generated so far, or `None` when
    /// the slot was already free (the request may have retired in the
    /// same step it was cancelled). An `Err` means the block pool's
    /// bookkeeping is corrupt — the serving loop surfaces it as a
    /// replica error and rebuilds the session (previously eviction
    /// failures were silently swallowed). The serving loop calls this at
    /// decode-step boundaries, so cancellation never tears a step in
    /// half.
    pub fn cancel_slot(&mut self, slot: usize) -> Result<Option<Vec<i32>>> {
        let Some(st) = self.slots.get_mut(slot).and_then(Option::take) else {
            return Ok(None);
        };
        self.release_slot_blocks(slot)?;
        Ok(Some(st.generated))
    }

    /// Serialize the populated KV rows `[0, pos)` of the request in
    /// `slot` into a plan-agnostic [`KvSegment`] — the prefill side of a
    /// disaggregated hand-off. Each model layer's TP-sharded block rows
    /// are assembled into one `[1, heads, pos, head_dim]` tensor per
    /// `(k, v)`, so a decode replica running a different TP/PP plan can
    /// land them. The slot stays intact (the caller retires it with
    /// [`Self::cancel_slot`] once the segment is safely handed off), and
    /// the shipped bytes are metered into the session's comm counters as
    /// a KV transfer.
    pub fn export_rows(&mut self, slot: usize) -> Result<KvSegment> {
        let exec = self.exec;
        let info = &exec.backend.manifest().model;
        let Some(st) = self.slots.get(slot).and_then(Option::as_ref) else {
            bail!("exporting KV from free slot {slot}");
        };
        let (pos, first_token) = (st.pos, st.next);
        if pos == 0 {
            bail!("slot {slot} has no populated KV rows to export");
        }
        let (heads, dh) = (info.heads, info.head_dim);
        let bt = self.block_tokens;
        let dims = vec![1, heads, pos, dh];
        let elems = heads * pos * dh;
        let mut layers: Vec<(Tensor, Tensor)> = (0..info.layers)
            .map(|_| {
                (
                    Tensor { dims: dims.clone(), data: vec![0.0; elems] },
                    Tensor { dims: dims.clone(), data: vec![0.0; elems] },
                )
            })
            .collect();
        let table = &self.tables[slot];
        for (si, stage) in exec.stages.iter().enumerate() {
            let nhs = heads / stage.tp;
            for li in 0..stage.layer_count {
                let (seg_k, seg_v) = &mut layers[stage.layer_start + li];
                for (shard, (bk, bv)) in self.block_store[si][li].iter().enumerate() {
                    let h0 = shard * nhs;
                    for (bi, &bid) in table.blocks().iter().enumerate() {
                        let start = bi * bt;
                        if start >= pos {
                            break;
                        }
                        let n = (pos - start).min(bt);
                        seg_k.copy_cache_head_rows(0, h0, start, bk, bid, 0, 0, nhs, n)?;
                        seg_v.copy_cache_head_rows(0, h0, start, bv, bid, 0, 0, nhs, n)?;
                    }
                }
            }
        }
        let seg = KvSegment { pos, first_token, layers };
        record_kv_transfer(seg.num_bytes(), &mut self.comm);
        Ok(seg)
    }

    /// Land a handed-off [`KvSegment`] into the free `slot`, admitting
    /// it as a decode-ready row — the decode side of a disaggregated
    /// hand-off. Reserves the row's worst-case block budget
    /// ([`Self::blocks_needed_at`]`(seg.pos, max_new)` — gate on it
    /// against [`Self::free_block_budget`] to defer instead of failing),
    /// copies each prompt chunk into freshly allocated blocks, and
    /// commits a slot state whose `generated` already holds the prefill
    /// side's first token. Imported blocks are deliberately **not**
    /// published to the prefix cache: a segment carries no verifiable
    /// token identity, so its blocks stay private to this row. Errors
    /// release everything the partial import acquired.
    pub fn import_rows(
        &mut self,
        slot: usize,
        seg: &KvSegment,
        max_new: usize,
        stop: Option<i32>,
    ) -> Result<()> {
        let info = &self.exec.backend.manifest().model;
        if slot >= self.bucket {
            bail!("slot {slot} outside session bucket {}", self.bucket);
        }
        if self.slots[slot].is_some() {
            bail!("importing KV into occupied slot {slot}");
        }
        if max_new == 0 {
            bail!("max_new must be >= 1");
        }
        if seg.pos == 0 || seg.pos >= info.max_seq {
            bail!(
                "segment depth {} leaves no room to decode within max_seq {}",
                seg.pos,
                info.max_seq
            );
        }
        if seg.layers.len() != info.layers {
            bail!("segment has {} layers, model has {}", seg.layers.len(), info.layers);
        }
        let want = [1, info.heads, seg.pos, info.head_dim];
        for (li, (k, v)) in seg.layers.iter().enumerate() {
            for t in [k, v] {
                if t.dims != want {
                    bail!(
                        "segment layer {li} has shape {:?}, serving model expects {:?}",
                        t.dims,
                        want
                    );
                }
            }
        }
        let mn = max_new.min(info.max_seq - seg.pos).max(1);
        let need = self.blocks_needed_at(seg.pos, max_new);
        if !self.pool.try_reserve(need) {
            bail!(
                "kv block pool exhausted importing into slot {slot}: need {need} blocks, {} available",
                self.pool.available()
            );
        }
        if let Err(e) = self.tables[slot].begin(need) {
            self.pool.release_reservation(need)?;
            return Err(e);
        }
        if let Err(e) = self.import_rows_inner(slot, seg) {
            self.release_slot_blocks(slot)?;
            return Err(e);
        }
        self.slots[slot] = Some(SlotState {
            max_new: mn,
            stop,
            generated: vec![seg.first_token],
            next: seg.first_token,
            pos: seg.pos,
        });
        Ok(())
    }

    /// Block allocation and row landing for [`Self::import_rows`],
    /// separated so a mid-copy failure can be rolled back by releasing
    /// the slot's partial table.
    fn import_rows_inner(&mut self, slot: usize, seg: &KvSegment) -> Result<()> {
        let exec = self.exec;
        let heads = exec.backend.manifest().model.heads;
        let bt = self.block_tokens;
        for ci in 0..seg.pos.div_ceil(bt) {
            self.tables[slot].use_reservation()?;
            let bid = self.pool.alloc_reserved()?;
            self.tables[slot].push(bid);
            let start = ci * bt;
            let n = (seg.pos - start).min(bt);
            for (si, stage) in exec.stages.iter().enumerate() {
                let nhs = heads / stage.tp;
                for li in 0..stage.layer_count {
                    let (seg_k, seg_v) = &seg.layers[stage.layer_start + li];
                    for (shard, (bk, bv)) in self.block_store[si][li].iter_mut().enumerate() {
                        let h0 = shard * nhs;
                        bk.copy_cache_head_rows(bid, 0, 0, seg_k, 0, h0, start, nhs, n)?;
                        bv.copy_cache_head_rows(bid, 0, 0, seg_v, 0, h0, start, nhs, n)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Ensure dense step scratch exists for bucket `sb` and gather each
    /// active row's block-backed prefix `[0, pos)` into its scratch row
    /// — skipped per row when the residency entry already records
    /// exactly `(slot, pos)` from the previous step's scatter, which is
    /// the steady state. The scratch persists across steps and is never
    /// zeroed: every cache row a step reads is gathered (or resident)
    /// first, and pad rows' leftover contents are never observed
    /// (per-row attention reads only that row's entries, and pad-row
    /// outputs are discarded).
    fn gather_step_caches(&mut self, active_slots: &[usize], sb: usize) -> Result<usize> {
        let ci = match self.step_caches.iter().position(|s| s.bucket == sb) {
            Some(i) => i,
            None => {
                // Scratch pool refill — outside the marked hot regions.
                let fresh = self.exec.alloc_caches(sb)?;
                self.step_caches.push(StepScratch {
                    bucket: sb,
                    caches: fresh,
                    resident: vec![None; sb],
                });
                self.step_caches.len() - 1
            }
        };
        let bt = self.block_tokens;
        let DecodeSession { step_caches, block_store, tables, slots, .. } = self;
        let scratch = &mut step_caches[ci];
        // The kernel writes the filler position into pad rows, so any
        // residency they carried is stale after this step.
        for r in scratch.resident[active_slots.len()..].iter_mut() {
            *r = None;
        }
        for (row, &slot) in active_slots.iter().enumerate() {
            let Some(st) = slots[slot].as_ref() else {
                bail!("internal: gathering inactive slot {slot}");
            };
            let depth = st.pos;
            if scratch.resident[row] == Some((slot, depth)) {
                continue;
            }
            scratch.resident[row] = None;
            let table = &tables[slot];
            for (si, stage_caches) in block_store.iter().enumerate() {
                for (li, layer) in stage_caches.iter().enumerate() {
                    for (shard, (bk, bv)) in layer.iter().enumerate() {
                        let (dk, dv) = &mut scratch.caches[si][li][shard];
                        for (bi, &bid) in table.blocks().iter().enumerate() {
                            let start = bi * bt;
                            if start >= depth {
                                break;
                            }
                            let n = (depth - start).min(bt);
                            dk.copy_cache_rows_between(row, start, bk, bid, 0, n)?;
                            dv.copy_cache_rows_between(row, start, bv, bid, 0, n)?;
                        }
                    }
                }
            }
            scratch.resident[row] = Some((slot, depth));
        }
        Ok(ci)
    }

    /// Write each active row's newly appended cache entry (at its `pos`)
    /// back into its tail block, planning the append through the block
    /// table: extend with a fresh block at a block boundary, or
    /// copy-on-write a shared tail before the first divergent write
    /// (which copies the tail's `[0, pos % block_tokens)` rows across
    /// every storage tensor — the sibling sequence keeps the original
    /// block untouched). A decode step mutates nothing else: the rest of
    /// the scratch row is byte-identical to what gather copied in, so
    /// residency advances to `(slot, pos + 1)`.
    fn scatter_step_caches(&mut self, active_slots: &[usize], ci: usize) -> Result<()> {
        let DecodeSession { step_caches, block_store, tables, slots, pool, .. } = self;
        let scratch = &mut step_caches[ci];
        for (row, &slot) in active_slots.iter().enumerate() {
            let Some(st) = slots[slot].as_ref() else {
                bail!("internal: scattering inactive slot {slot}");
            };
            let pos = st.pos;
            let op = plan_append(pool, &mut tables[slot], pos)?;
            let (block, block_row) = match op {
                AppendOp::Write { block, row: block_row } => (block, block_row),
                AppendOp::CowWrite { src, block, copy_rows, row: block_row } => {
                    for stage_caches in block_store.iter_mut() {
                        for layer in stage_caches.iter_mut() {
                            for (bk, bv) in layer.iter_mut() {
                                bk.copy_cache_rows_within(block, src, copy_rows)?;
                                bv.copy_cache_rows_within(block, src, copy_rows)?;
                            }
                        }
                    }
                    (block, block_row)
                }
            };
            for (si, stage_caches) in block_store.iter_mut().enumerate() {
                for (li, layer) in stage_caches.iter_mut().enumerate() {
                    for (shard, (bk, bv)) in layer.iter_mut().enumerate() {
                        let (sk, sv) = &scratch.caches[si][li][shard];
                        bk.copy_cache_rows_between(block, block_row, sk, row, pos, 1)?;
                        bv.copy_cache_rows_between(block, block_row, sv, row, pos, 1)?;
                    }
                }
            }
            scratch.resident[row] = Some((slot, pos + 1));
        }
        Ok(())
    }

    /// Release every block a slot's table references (freed blocks drop
    /// their prefix-cache entries), hand its unused reservation back to
    /// the admission budget, and invalidate its step-scratch residency.
    /// Errors are surfaced, not swallowed: a failed release means the
    /// pool's refcounts are corrupt, and the serving loop must fail the
    /// replica's rows and rebuild the session rather than keep decoding
    /// over a leaking pool.
    fn release_slot_blocks(&mut self, slot: usize) -> Result<()> {
        let DecodeSession { pool, prefix, tables, step_caches, .. } = self;
        let table = &mut tables[slot];
        for &bid in table.blocks() {
            if pool.release(bid).with_context(|| format!("evicting slot {slot}"))? {
                prefix.forget(bid);
            }
        }
        let left = table.finish();
        pool.release_reservation(left).with_context(|| format!("evicting slot {slot}"))?;
        for sc in step_caches.iter_mut() {
            for r in sc.resident.iter_mut() {
                if r.is_some_and(|(s, _)| s == slot) {
                    *r = None;
                }
            }
        }
        Ok(())
    }

    /// Fold the session's counters into a [`GenerationResult`].
    fn into_result(mut self, tokens: Vec<Vec<i32>>) -> GenerationResult {
        GenerationResult {
            tokens,
            prefill_seconds: self.prefill_seconds,
            decode_seconds: self.decode_seconds,
            decode_steps: self.decode_steps,
            prefill_tokens: self.prefill_tokens,
            comm: std::mem::take(&mut self.comm),
            bucket: self.bucket,
        }
    }
}

/// Row-wise argmax over a `[b, vocab]` tensor.
pub fn argmax_rows(logits: &Tensor, vocab: usize) -> Vec<i32> {
    assert_eq!(logits.dims.len(), 2);
    assert_eq!(logits.dims[1], vocab);
    logits
        .data
        .chunks_exact(vocab)
        .map(|row| {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in row.iter().enumerate() {
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_from_strategy_builds_ranges() {
        let p = plan_from_strategy(&[2, 1], &[4, 2]).unwrap();
        assert_eq!(p[0], StagePlan { layer_start: 0, layer_count: 4, tp: 2 });
        assert_eq!(p[1], StagePlan { layer_start: 4, layer_count: 2, tp: 1 });
        assert_eq!(p[1].layers(), 4..6);
    }

    #[test]
    fn plan_validation_errors() {
        assert!(plan_from_strategy(&[2], &[4, 2]).is_err());
        assert!(plan_from_strategy(&[], &[]).is_err());
        assert!(plan_from_strategy(&[1], &[0]).is_err());
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor { dims: vec![2, 3], data: vec![0.1, 0.9, 0.3, 5.0, -1.0, 2.0] };
        assert_eq!(argmax_rows(&t, 3), vec![1, 0]);
    }

    #[test]
    fn name_cache_precomputes_all_names() {
        let stages = plan_from_strategy(&[2, 1], &[1, 1]).unwrap();
        let names = NameCache::new(vec![1, 4], &stages);
        assert_eq!(names.bucket_idx(4).unwrap(), 1);
        assert!(names.bucket_idx(2).is_err());
        assert_eq!(names.embed_decode[0], "embed_decode_b1");
        assert_eq!(names.lm_head_prefill[1], "lm_head_prefill_b4");
        assert_eq!(names.stages[0].attn_decode[1], "attn_decode_tp2_b4");
        assert_eq!(names.stages[1].mlp_prefill[0], "mlp_prefill_tp1_b1");
        assert_eq!(names.stages[0].layers[0].ln1, "layers.0.ln1");
        assert_eq!(names.stages[0].layers[0].shards[1].wq, "layers.0.wq.tp2.r1");
        assert_eq!(names.stages[1].layers[0].ln2, "layers.1.ln2");
        assert_eq!(names.stages[1].layers[0].shards[0].w1, "layers.1.w1");
    }
}
