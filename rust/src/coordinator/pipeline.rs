//! Asymmetric pipeline executor: runs a generation batch through a chain
//! of stages with per-stage TP degrees (paper §3.2), calling the stage
//! executables through an [`ExecutionBackend`] (pure-Rust reference or
//! PJRT) and performing the leader-side collectives in Rust.
//!
//! The execution scheme per transformer layer is Megatron's:
//!
//! ```text
//! x ─┬─ shard₀: attn_partial ─┐
//!    ├─ shard₁: attn_partial ─┼─ AllReduce(sum) ─ +x ─┬─ shard₀: mlp ─┐
//!    └─ …                     ┘                       └─ …            ┴─ AllReduce ─ +h
//! ```
//!
//! with the KV caches held per (layer, shard) between decode steps.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::{BackendKind, ExecutionBackend, InputArg, Tensor, WeightStore};

use super::collective::{add_residual, all_reduce_sum, record_pp_send, CommStats};

/// One stage of the serving plan: a contiguous layer range at a TP degree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StagePlan {
    pub layer_start: usize,
    pub layer_count: usize,
    pub tp: usize,
}

impl StagePlan {
    pub fn layers(&self) -> std::ops::Range<usize> {
        self.layer_start..self.layer_start + self.layer_count
    }
}

/// Build a plan from TP-degree + layer-count lists (Appendix-F notation,
/// e.g. `tp=[2,1]`, `layers=[4,2]`).
pub fn plan_from_strategy(tps: &[usize], layers: &[usize]) -> Result<Vec<StagePlan>> {
    if tps.len() != layers.len() || tps.is_empty() {
        bail!("strategy lists must be equal-length and non-empty");
    }
    let mut start = 0;
    let mut out = Vec::with_capacity(tps.len());
    for (&tp, &lc) in tps.iter().zip(layers) {
        if lc == 0 {
            bail!("zero-layer stage");
        }
        out.push(StagePlan { layer_start: start, layer_count: lc, tp });
        start += lc;
    }
    Ok(out)
}

/// Result of one generation batch.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// Generated tokens per request row (pad rows removed).
    pub tokens: Vec<Vec<i32>>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub decode_steps: usize,
    pub comm: CommStats,
    /// Batch bucket actually executed (≥ the real batch).
    pub bucket: usize,
}

/// KV caches for one stage: `[layer][shard] -> (k, v)`.
type StageCaches = Vec<Vec<(Tensor, Tensor)>>;

/// Executes generation through an asymmetric TP×PP plan on one thread.
pub struct PipelineExecutor {
    backend: Box<dyn ExecutionBackend>,
    stages: Vec<StagePlan>,
}

impl PipelineExecutor {
    /// Load the default backend for this build (PJRT when the `pjrt`
    /// feature is enabled, pure-Rust reference otherwise) from
    /// `artifacts_dir` and validate the plan against the manifest.
    pub fn new(artifacts_dir: &Path, stages: Vec<StagePlan>) -> Result<PipelineExecutor> {
        let backend = crate::runtime::load_backend(BackendKind::default(), artifacts_dir)?;
        Self::with_backend(backend, stages)
    }

    /// Wrap an already-constructed backend (what per-replica worker
    /// threads do), validating the plan against its manifest (layer
    /// coverage, supported TP degrees).
    pub fn with_backend(
        backend: Box<dyn ExecutionBackend>,
        stages: Vec<StagePlan>,
    ) -> Result<PipelineExecutor> {
        let m = backend.manifest();
        let total: usize = stages.iter().map(|s| s.layer_count).sum();
        if total != m.model.layers {
            bail!("plan covers {total} layers, model has {}", m.model.layers);
        }
        let mut next = 0;
        for s in &stages {
            if s.layer_start != next {
                bail!("stages not contiguous at layer {next}");
            }
            next += s.layer_count;
            if !m.tp_degrees.contains(&s.tp) {
                bail!("tp={} has no artifacts (available {:?})", s.tp, m.tp_degrees);
            }
        }
        Ok(PipelineExecutor { backend, stages })
    }

    pub fn stages(&self) -> &[StagePlan] {
        &self.stages
    }

    /// The execution backend this pipeline runs on.
    pub fn backend(&self) -> &dyn ExecutionBackend {
        self.backend.as_ref()
    }

    /// The artifact catalog + model architecture being served.
    pub fn manifest(&self) -> &crate::runtime::Manifest {
        self.backend.manifest()
    }

    /// Strategy string in the paper's Appendix-F notation, e.g. `[2,1]`.
    pub fn strategy_string(&self) -> String {
        let v: Vec<String> = self.stages.iter().map(|s| s.tp.to_string()).collect();
        format!("[{}]", v.join(","))
    }

    /// Generate up to `max_new` tokens for a batch of prompts (each
    /// exactly `prompt_len` tokens; see [`crate::runtime::tokenizer`]).
    /// Greedy decoding.
    pub fn generate(&self, prompts: &[Vec<i32>], max_new: usize) -> Result<GenerationResult> {
        let info = self.backend.manifest().model.clone();
        let b_real = prompts.len();
        if b_real == 0 {
            bail!("empty batch");
        }
        for p in prompts {
            if p.len() != info.prompt_len {
                bail!("prompt must be exactly {} tokens, got {}", info.prompt_len, p.len());
            }
        }
        let max_new = max_new.min(info.max_seq - info.prompt_len);
        if max_new == 0 {
            bail!("max_new must be >= 1");
        }
        let bucket = self.backend.manifest().bucket_for(b_real)?;

        // Pad the batch to the bucket with PAD prompts.
        let mut tokens: Vec<i32> = Vec::with_capacity(bucket * info.prompt_len);
        for p in prompts {
            tokens.extend_from_slice(p);
        }
        tokens.resize(bucket * info.prompt_len, crate::runtime::tokenizer::PAD);

        let mut comm = CommStats::default();

        // ---- prefill --------------------------------------------------
        let t0 = Instant::now();
        let mut x = self.embed(&tokens, bucket, info.prompt_len, true)?;
        let mut caches: Vec<StageCaches> = Vec::with_capacity(self.stages.len());
        for (si, stage) in self.stages.iter().enumerate() {
            let mut stage_caches: StageCaches = Vec::with_capacity(stage.layer_count);
            for layer in stage.layers() {
                let (h, layer_caches) =
                    self.layer_prefill(&x, layer, stage.tp, bucket, &mut comm)?;
                x = h;
                stage_caches.push(layer_caches);
            }
            caches.push(stage_caches);
            if si + 1 < self.stages.len() {
                record_pp_send(&x, &mut comm);
            }
        }
        let logits = self.lm_head(&x, bucket, true)?;
        let mut next = argmax_rows(&logits, info.vocab);
        let prefill_seconds = t0.elapsed().as_secs_f64();

        let mut generated: Vec<Vec<i32>> = vec![Vec::with_capacity(max_new); bucket];
        for (row, g) in generated.iter_mut().enumerate() {
            g.push(next[row]);
        }

        // ---- decode ----------------------------------------------------
        let t1 = Instant::now();
        let mut steps = 1; // first token came from prefill logits
        for step in 1..max_new {
            let pos = (info.prompt_len + step - 1) as i32;
            let tok_batch: Vec<i32> = next.clone();
            let mut x = self.embed(&tok_batch, bucket, 1, false)?;
            for (si, stage) in self.stages.iter().enumerate() {
                for (li, layer) in stage.layers().enumerate() {
                    let h = self.layer_decode(
                        &x,
                        layer,
                        stage.tp,
                        bucket,
                        pos,
                        &mut caches[si][li],
                        &mut comm,
                    )?;
                    x = h;
                }
                if si + 1 < self.stages.len() {
                    record_pp_send(&x, &mut comm);
                }
            }
            let logits = self.lm_head(&x, bucket, false)?;
            next = argmax_rows(&logits, info.vocab);
            for (row, g) in generated.iter_mut().enumerate() {
                g.push(next[row]);
            }
            steps += 1;
        }
        let decode_seconds = t1.elapsed().as_secs_f64();

        generated.truncate(b_real);
        Ok(GenerationResult {
            tokens: generated,
            prefill_seconds,
            decode_seconds,
            decode_steps: steps,
            comm,
            bucket,
        })
    }

    // ---- stage pieces ---------------------------------------------------

    fn embed(&self, tokens: &[i32], bucket: usize, s: usize, prefill: bool) -> Result<Tensor> {
        let name = if prefill {
            format!("embed_prefill_b{bucket}")
        } else {
            format!("embed_decode_b{bucket}")
        };
        let mut outs = self.backend.execute(
            &name,
            &[InputArg::I32(tokens, vec![bucket, s]), InputArg::Weight("embed")],
        )?;
        Ok(outs.remove(0))
    }

    fn lm_head(&self, x: &Tensor, bucket: usize, prefill: bool) -> Result<Tensor> {
        let name = if prefill {
            format!("lm_head_prefill_b{bucket}")
        } else {
            format!("lm_head_decode_b{bucket}")
        };
        let mut outs = self.backend.execute(
            &name,
            &[InputArg::F32(x), InputArg::Weight("final_ln"), InputArg::Weight("lm_head")],
        )?;
        Ok(outs.remove(0))
    }

    /// One prefill layer: TP-sharded attention + MLP with host AllReduce.
    /// Returns (new hidden state, per-shard (k, v) caches).
    fn layer_prefill(
        &self,
        x: &Tensor,
        layer: usize,
        tp: usize,
        bucket: usize,
        comm: &mut CommStats,
    ) -> Result<(Tensor, Vec<(Tensor, Tensor)>)> {
        let attn_name = format!("attn_prefill_tp{tp}_b{bucket}");
        let ln1 = format!("layers.{layer}.ln1");
        let mut partials = Vec::with_capacity(tp);
        let mut layer_caches = Vec::with_capacity(tp);
        for r in 0..tp {
            let wq = WeightStore::shard_name(layer, "wq", tp, r);
            let wk = WeightStore::shard_name(layer, "wk", tp, r);
            let wv = WeightStore::shard_name(layer, "wv", tp, r);
            let wo = WeightStore::shard_name(layer, "wo", tp, r);
            let mut outs = self.backend.execute(
                &attn_name,
                &[
                    InputArg::F32(x),
                    InputArg::Weight(&ln1),
                    InputArg::Weight(&wq),
                    InputArg::Weight(&wk),
                    InputArg::Weight(&wv),
                    InputArg::Weight(&wo),
                ],
            )?;
            let v_cache = outs.pop().context("missing v_cache")?;
            let k_cache = outs.pop().context("missing k_cache")?;
            let partial = outs.pop().context("missing partial")?;
            partials.push(partial);
            layer_caches.push((k_cache, v_cache));
        }
        let mut h = x.clone();
        let reduced = all_reduce_sum(partials, comm);
        add_residual(&mut h, &reduced);

        let mlp_name = format!("mlp_prefill_tp{tp}_b{bucket}");
        let ln2 = format!("layers.{layer}.ln2");
        let mut mlp_partials = Vec::with_capacity(tp);
        for r in 0..tp {
            let w1 = WeightStore::shard_name(layer, "w1", tp, r);
            let w2 = WeightStore::shard_name(layer, "w2", tp, r);
            let mut outs = self.backend.execute(
                &mlp_name,
                &[InputArg::F32(&h), InputArg::Weight(&ln2), InputArg::Weight(&w1), InputArg::Weight(&w2)],
            )?;
            mlp_partials.push(outs.remove(0));
        }
        let reduced = all_reduce_sum(mlp_partials, comm);
        add_residual(&mut h, &reduced);
        Ok((h, layer_caches))
    }

    /// One decode layer; updates the per-shard caches in place.
    #[allow(clippy::too_many_arguments)]
    fn layer_decode(
        &self,
        x: &Tensor,
        layer: usize,
        tp: usize,
        bucket: usize,
        pos: i32,
        caches: &mut Vec<(Tensor, Tensor)>,
        comm: &mut CommStats,
    ) -> Result<Tensor> {
        let attn_name = format!("attn_decode_tp{tp}_b{bucket}");
        let ln1 = format!("layers.{layer}.ln1");
        let mut partials = Vec::with_capacity(tp);
        for (r, (k_cache, v_cache)) in caches.iter_mut().enumerate() {
            let wq = WeightStore::shard_name(layer, "wq", tp, r);
            let wk = WeightStore::shard_name(layer, "wk", tp, r);
            let wv = WeightStore::shard_name(layer, "wv", tp, r);
            let wo = WeightStore::shard_name(layer, "wo", tp, r);
            let mut outs = self.backend.execute(
                &attn_name,
                &[
                    InputArg::F32(x),
                    InputArg::F32(k_cache),
                    InputArg::F32(v_cache),
                    InputArg::ScalarI32(pos),
                    InputArg::Weight(&ln1),
                    InputArg::Weight(&wq),
                    InputArg::Weight(&wk),
                    InputArg::Weight(&wv),
                    InputArg::Weight(&wo),
                ],
            )?;
            let new_v = outs.pop().context("missing v_cache")?;
            let new_k = outs.pop().context("missing k_cache")?;
            let partial = outs.pop().context("missing partial")?;
            *k_cache = new_k;
            *v_cache = new_v;
            partials.push(partial);
        }
        let mut h = x.clone();
        let reduced = all_reduce_sum(partials, comm);
        add_residual(&mut h, &reduced);

        let mlp_name = format!("mlp_decode_tp{tp}_b{bucket}");
        let ln2 = format!("layers.{layer}.ln2");
        let mut mlp_partials = Vec::with_capacity(tp);
        for r in 0..tp {
            let w1 = WeightStore::shard_name(layer, "w1", tp, r);
            let w2 = WeightStore::shard_name(layer, "w2", tp, r);
            let mut outs = self.backend.execute(
                &mlp_name,
                &[InputArg::F32(&h), InputArg::Weight(&ln2), InputArg::Weight(&w1), InputArg::Weight(&w2)],
            )?;
            mlp_partials.push(outs.remove(0));
        }
        let reduced = all_reduce_sum(mlp_partials, comm);
        add_residual(&mut h, &reduced);
        Ok(h)
    }
}

/// Row-wise argmax over a `[b, vocab]` tensor.
pub fn argmax_rows(logits: &Tensor, vocab: usize) -> Vec<i32> {
    assert_eq!(logits.dims.len(), 2);
    assert_eq!(logits.dims[1], vocab);
    logits
        .data
        .chunks_exact(vocab)
        .map(|row| {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in row.iter().enumerate() {
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_from_strategy_builds_ranges() {
        let p = plan_from_strategy(&[2, 1], &[4, 2]).unwrap();
        assert_eq!(p[0], StagePlan { layer_start: 0, layer_count: 4, tp: 2 });
        assert_eq!(p[1], StagePlan { layer_start: 4, layer_count: 2, tp: 1 });
        assert_eq!(p[1].layers(), 4..6);
    }

    #[test]
    fn plan_validation_errors() {
        assert!(plan_from_strategy(&[2], &[4, 2]).is_err());
        assert!(plan_from_strategy(&[], &[]).is_err());
        assert!(plan_from_strategy(&[1], &[0]).is_err());
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor { dims: vec![2, 3], data: vec![0.1, 0.9, 0.3, 5.0, -1.0, 2.0] };
        assert_eq!(argmax_rows(&t, 3), vec![1, 0]);
    }
}
