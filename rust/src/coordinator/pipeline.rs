//! Asymmetric pipeline executor: runs a generation batch through a chain
//! of stages with per-stage TP degrees (paper §3.2), calling the stage
//! executables through an [`ExecutionBackend`] (pure-Rust reference or
//! PJRT) and performing the leader-side collectives in Rust.
//!
//! The execution scheme per transformer layer is Megatron's:
//!
//! ```text
//! x ─┬─ shard₀: attn_partial ─┐
//!    ├─ shard₁: attn_partial ─┼─ AllReduce(sum) ─ +x ─┬─ shard₀: mlp ─┐
//!    └─ …                     ┘                       └─ …            ┴─ AllReduce ─ +h
//! ```
//!
//! with the KV caches held per (layer, shard) between decode steps.
//!
//! Serving runs **continuous (iteration-level) batching** through a
//! persistent [`DecodeSession`]: slot-based KV caches sized to an
//! artifact bucket, with [`DecodeSession::prefill_into_slots`] admitting
//! requests into free slots at any decode-step boundary and
//! [`DecodeSession::decode_step`] retiring rows the moment they hit
//! their own `max_new` or emit their stop token. The monolithic
//! [`PipelineExecutor::generate`] remains as a thin run-to-completion
//! wrapper over a session.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::{tokenizer, BackendKind, ExecutionBackend, InputArg, Tensor, WeightStore};

use super::collective::{add_residual, all_reduce_sum, record_pp_send, CommStats};

/// One stage of the serving plan: a contiguous layer range at a TP degree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StagePlan {
    pub layer_start: usize,
    pub layer_count: usize,
    pub tp: usize,
}

impl StagePlan {
    pub fn layers(&self) -> std::ops::Range<usize> {
        self.layer_start..self.layer_start + self.layer_count
    }
}

/// Build a plan from TP-degree + layer-count lists (Appendix-F notation,
/// e.g. `tp=[2,1]`, `layers=[4,2]`).
pub fn plan_from_strategy(tps: &[usize], layers: &[usize]) -> Result<Vec<StagePlan>> {
    if tps.len() != layers.len() || tps.is_empty() {
        bail!("strategy lists must be equal-length and non-empty");
    }
    let mut start = 0;
    let mut out = Vec::with_capacity(tps.len());
    for (&tp, &lc) in tps.iter().zip(layers) {
        if lc == 0 {
            bail!("zero-layer stage");
        }
        out.push(StagePlan { layer_start: start, layer_count: lc, tp });
        start += lc;
    }
    Ok(out)
}

/// Result of one generation batch.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// Generated tokens per request row (pad rows removed).
    pub tokens: Vec<Vec<i32>>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    /// True decode iterations only — the token argmaxed from the prefill
    /// logits is *not* counted here (see [`Self::prefill_tokens`]), so
    /// `decode_steps / decode_seconds` is an honest decode rate.
    pub decode_steps: usize,
    /// Tokens produced by the prefill pass itself (one per request row).
    pub prefill_tokens: usize,
    pub comm: CommStats,
    /// Batch bucket actually executed (≥ the real batch).
    pub bucket: usize,
}

/// KV caches for one stage: `[layer][shard] -> (k, v)`.
type StageCaches = Vec<Vec<(Tensor, Tensor)>>;

/// Executes generation through an asymmetric TP×PP plan on one thread.
pub struct PipelineExecutor {
    backend: Box<dyn ExecutionBackend>,
    stages: Vec<StagePlan>,
}

impl PipelineExecutor {
    /// Load the default backend for this build (PJRT when the `pjrt`
    /// feature is enabled, pure-Rust reference otherwise) from
    /// `artifacts_dir` and validate the plan against the manifest.
    pub fn new(artifacts_dir: &Path, stages: Vec<StagePlan>) -> Result<PipelineExecutor> {
        let backend = crate::runtime::load_backend(BackendKind::default(), artifacts_dir)?;
        Self::with_backend(backend, stages)
    }

    /// Wrap an already-constructed backend (what per-replica worker
    /// threads do), validating the plan against its manifest (layer
    /// coverage, supported TP degrees).
    pub fn with_backend(
        backend: Box<dyn ExecutionBackend>,
        stages: Vec<StagePlan>,
    ) -> Result<PipelineExecutor> {
        let m = backend.manifest();
        let total: usize = stages.iter().map(|s| s.layer_count).sum();
        if total != m.model.layers {
            bail!("plan covers {total} layers, model has {}", m.model.layers);
        }
        let mut next = 0;
        for s in &stages {
            if s.layer_start != next {
                bail!("stages not contiguous at layer {next}");
            }
            next += s.layer_count;
            if !m.tp_degrees.contains(&s.tp) {
                bail!("tp={} has no artifacts (available {:?})", s.tp, m.tp_degrees);
            }
        }
        Ok(PipelineExecutor { backend, stages })
    }

    pub fn stages(&self) -> &[StagePlan] {
        &self.stages
    }

    /// The execution backend this pipeline runs on.
    pub fn backend(&self) -> &dyn ExecutionBackend {
        self.backend.as_ref()
    }

    /// The artifact catalog + model architecture being served.
    pub fn manifest(&self) -> &crate::runtime::Manifest {
        self.backend.manifest()
    }

    /// Strategy string in the paper's Appendix-F notation, e.g. `[2,1]`.
    pub fn strategy_string(&self) -> String {
        let v: Vec<String> = self.stages.iter().map(|s| s.tp.to_string()).collect();
        format!("[{}]", v.join(","))
    }

    /// Open a persistent decode session with `bucket` KV-cache slots
    /// (`bucket` must be one of the manifest's batch buckets). Caches are
    /// allocated zeroed; requests are admitted with
    /// [`DecodeSession::prefill_into_slots`].
    pub fn new_session(&self, bucket: usize) -> Result<DecodeSession<'_>> {
        let m = self.backend.manifest();
        if !m.batch_buckets.contains(&bucket) {
            bail!("session bucket {bucket} not in manifest buckets {:?}", m.batch_buckets);
        }
        let info = &m.model;
        let mut caches: Vec<StageCaches> = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            if stage.tp == 0 || info.heads % stage.tp != 0 {
                bail!("tp={} does not divide {} heads", stage.tp, info.heads);
            }
            let nhs = info.heads / stage.tp;
            let dims = vec![bucket, nhs, info.max_seq, info.head_dim];
            let n = bucket * nhs * info.max_seq * info.head_dim;
            let mut stage_caches: StageCaches = Vec::with_capacity(stage.layer_count);
            for _ in 0..stage.layer_count {
                let shards: Vec<(Tensor, Tensor)> = (0..stage.tp)
                    .map(|_| {
                        (
                            Tensor { dims: dims.clone(), data: vec![0.0; n] },
                            Tensor { dims: dims.clone(), data: vec![0.0; n] },
                        )
                    })
                    .collect();
                stage_caches.push(shards);
            }
            caches.push(stage_caches);
        }
        Ok(DecodeSession {
            exec: self,
            bucket,
            caches,
            slots: (0..bucket).map(|_| None).collect(),
            comm: CommStats::default(),
            decode_steps: 0,
            prefill_tokens: 0,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
        })
    }

    /// Generate up to `max_new` tokens for a batch of prompts (each
    /// exactly `prompt_len` tokens; see [`crate::runtime::tokenizer`]).
    /// Greedy decoding. Thin run-to-completion wrapper over a
    /// [`DecodeSession`]; each row still stops at its own limit.
    pub fn generate(&self, prompts: &[Vec<i32>], max_new: usize) -> Result<GenerationResult> {
        self.generate_with_limits(prompts, &vec![max_new; prompts.len()])
    }

    /// Like [`Self::generate`] but with a per-request `max_new`: row `i`
    /// receives exactly `max_new[i]` tokens (clamped to the cache), no
    /// matter what its co-batched neighbours asked for.
    pub fn generate_with_limits(
        &self,
        prompts: &[Vec<i32>],
        max_new: &[usize],
    ) -> Result<GenerationResult> {
        let b_real = prompts.len();
        if b_real == 0 {
            bail!("empty batch");
        }
        if max_new.len() != b_real {
            bail!("{} max_new limits for {b_real} prompts", max_new.len());
        }
        let bucket = self.backend.manifest().bucket_for(b_real)?;
        let mut session = self.new_session(bucket)?;
        let reqs: Vec<(usize, SlotRequest)> = prompts
            .iter()
            .zip(max_new)
            .enumerate()
            .map(|(i, (p, &mn))| {
                (i, SlotRequest { prompt: p.clone(), max_new: mn, stop: None })
            })
            .collect();
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); b_real];
        for (slot, toks) in session.prefill_into_slots(reqs)?.finished {
            out[slot] = toks;
        }
        while session.active() > 0 {
            for (slot, toks) in session.decode_step()?.finished {
                out[slot] = toks;
            }
        }
        Ok(session.into_result(out))
    }

    // ---- stage pieces ---------------------------------------------------

    fn embed(&self, tokens: &[i32], bucket: usize, s: usize, prefill: bool) -> Result<Tensor> {
        let name = if prefill {
            format!("embed_prefill_b{bucket}")
        } else {
            format!("embed_decode_b{bucket}")
        };
        let mut outs = self.backend.execute(
            &name,
            &[InputArg::I32(tokens, vec![bucket, s]), InputArg::Weight("embed")],
        )?;
        Ok(outs.remove(0))
    }

    fn lm_head(&self, x: &Tensor, bucket: usize, prefill: bool) -> Result<Tensor> {
        let name = if prefill {
            format!("lm_head_prefill_b{bucket}")
        } else {
            format!("lm_head_decode_b{bucket}")
        };
        let mut outs = self.backend.execute(
            &name,
            &[InputArg::F32(x), InputArg::Weight("final_ln"), InputArg::Weight("lm_head")],
        )?;
        Ok(outs.remove(0))
    }

    /// One prefill layer: TP-sharded attention + MLP with host AllReduce.
    /// Returns (new hidden state, per-shard (k, v) caches).
    fn layer_prefill(
        &self,
        x: &Tensor,
        layer: usize,
        tp: usize,
        bucket: usize,
        comm: &mut CommStats,
    ) -> Result<(Tensor, Vec<(Tensor, Tensor)>)> {
        let attn_name = format!("attn_prefill_tp{tp}_b{bucket}");
        let ln1 = format!("layers.{layer}.ln1");
        let mut partials = Vec::with_capacity(tp);
        let mut layer_caches = Vec::with_capacity(tp);
        for r in 0..tp {
            let wq = WeightStore::shard_name(layer, "wq", tp, r);
            let wk = WeightStore::shard_name(layer, "wk", tp, r);
            let wv = WeightStore::shard_name(layer, "wv", tp, r);
            let wo = WeightStore::shard_name(layer, "wo", tp, r);
            let mut outs = self.backend.execute(
                &attn_name,
                &[
                    InputArg::F32(x),
                    InputArg::Weight(&ln1),
                    InputArg::Weight(&wq),
                    InputArg::Weight(&wk),
                    InputArg::Weight(&wv),
                    InputArg::Weight(&wo),
                ],
            )?;
            let v_cache = outs.pop().context("missing v_cache")?;
            let k_cache = outs.pop().context("missing k_cache")?;
            let partial = outs.pop().context("missing partial")?;
            partials.push(partial);
            layer_caches.push((k_cache, v_cache));
        }
        let mut h = x.clone();
        let reduced = all_reduce_sum(partials, comm);
        add_residual(&mut h, &reduced);

        let mlp_name = format!("mlp_prefill_tp{tp}_b{bucket}");
        let ln2 = format!("layers.{layer}.ln2");
        let mut mlp_partials = Vec::with_capacity(tp);
        for r in 0..tp {
            let w1 = WeightStore::shard_name(layer, "w1", tp, r);
            let w2 = WeightStore::shard_name(layer, "w2", tp, r);
            let mut outs = self.backend.execute(
                &mlp_name,
                &[InputArg::F32(&h), InputArg::Weight(&ln2), InputArg::Weight(&w1), InputArg::Weight(&w2)],
            )?;
            mlp_partials.push(outs.remove(0));
        }
        let reduced = all_reduce_sum(mlp_partials, comm);
        add_residual(&mut h, &reduced);
        Ok((h, layer_caches))
    }

    /// One decode layer; updates the per-shard caches in place.
    /// `positions[row]` is where that row's new KV entry lands (its cache
    /// depth); a uniform batch lowers to the scalar-position artifact
    /// signature, mixed depths (continuous batching) to a per-row vector.
    #[allow(clippy::too_many_arguments)]
    fn layer_decode(
        &self,
        x: &Tensor,
        layer: usize,
        tp: usize,
        bucket: usize,
        positions: &[i32],
        caches: &mut Vec<(Tensor, Tensor)>,
        comm: &mut CommStats,
    ) -> Result<Tensor> {
        let attn_name = format!("attn_decode_tp{tp}_b{bucket}");
        let ln1 = format!("layers.{layer}.ln1");
        let uniform = positions.windows(2).all(|w| w[0] == w[1]);
        let mut partials = Vec::with_capacity(tp);
        for (r, (k_cache, v_cache)) in caches.iter_mut().enumerate() {
            let wq = WeightStore::shard_name(layer, "wq", tp, r);
            let wk = WeightStore::shard_name(layer, "wk", tp, r);
            let wv = WeightStore::shard_name(layer, "wv", tp, r);
            let wo = WeightStore::shard_name(layer, "wo", tp, r);
            let pos_arg = if uniform {
                InputArg::ScalarI32(positions[0])
            } else {
                InputArg::I32(positions, vec![bucket])
            };
            let mut outs = self.backend.execute(
                &attn_name,
                &[
                    InputArg::F32(x),
                    InputArg::F32(k_cache),
                    InputArg::F32(v_cache),
                    pos_arg,
                    InputArg::Weight(&ln1),
                    InputArg::Weight(&wq),
                    InputArg::Weight(&wk),
                    InputArg::Weight(&wv),
                    InputArg::Weight(&wo),
                ],
            )?;
            let new_v = outs.pop().context("missing v_cache")?;
            let new_k = outs.pop().context("missing k_cache")?;
            let partial = outs.pop().context("missing partial")?;
            *k_cache = new_k;
            *v_cache = new_v;
            partials.push(partial);
        }
        let mut h = x.clone();
        let reduced = all_reduce_sum(partials, comm);
        add_residual(&mut h, &reduced);

        let mlp_name = format!("mlp_decode_tp{tp}_b{bucket}");
        let ln2 = format!("layers.{layer}.ln2");
        let mut mlp_partials = Vec::with_capacity(tp);
        for r in 0..tp {
            let w1 = WeightStore::shard_name(layer, "w1", tp, r);
            let w2 = WeightStore::shard_name(layer, "w2", tp, r);
            let mut outs = self.backend.execute(
                &mlp_name,
                &[InputArg::F32(&h), InputArg::Weight(&ln2), InputArg::Weight(&w1), InputArg::Weight(&w2)],
            )?;
            mlp_partials.push(outs.remove(0));
        }
        let reduced = all_reduce_sum(mlp_partials, comm);
        add_residual(&mut h, &reduced);
        Ok(h)
    }
}

/// Result of one session step — an admission
/// ([`DecodeSession::prefill_into_slots`]) or a decode iteration
/// ([`DecodeSession::decode_step`]). `tokens` reports **every** row's new
/// token for the step (the serving loop streams these as
/// [`RequestEvent::Token`](super::api::RequestEvent) events while rows
/// are still decoding); `finished` the subset that retired.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// One `(slot, token)` per row that produced a token this step, in
    /// slot order.
    pub tokens: Vec<(usize, i32)>,
    /// Rows that retired this step: `(slot, full generated sequence)`.
    /// Their slots are freed (cache rows zeroed) and admissible again.
    pub finished: Vec<(usize, Vec<i32>)>,
}

/// A request to admit into a [`DecodeSession`] slot.
#[derive(Debug, Clone)]
pub struct SlotRequest {
    /// Exactly `prompt_len` tokens (see [`crate::runtime::tokenizer`]).
    pub prompt: Vec<i32>,
    /// Per-request generation limit (clamped to `max_seq - prompt_len`).
    pub max_new: usize,
    /// Optional stop token: the row retires as soon as it emits this.
    pub stop: Option<i32>,
}

/// Per-slot decode state.
struct SlotState {
    max_new: usize,
    stop: Option<i32>,
    /// Tokens generated so far (the first came from prefill logits).
    generated: Vec<i32>,
    /// Next input token for the coming decode step.
    next: i32,
    /// Cache depth = where the next KV entry is written.
    pos: usize,
}

/// Persistent step-granular decode state over a [`PipelineExecutor`]:
/// `bucket` KV-cache slots shared by all in-flight rows. The serving
/// loop interleaves [`Self::prefill_into_slots`] (admission) with
/// [`Self::decode_step`] (one token for every active row), so a late
/// request joins an in-flight batch at the next step boundary instead of
/// waiting behind it, and every row stops at its own `max_new`/stop
/// token — continuous (iteration-level) batching.
pub struct DecodeSession<'a> {
    exec: &'a PipelineExecutor,
    bucket: usize,
    /// `[stage][layer][shard] -> (k, v)`, each `[bucket, nhs, max_seq, dh]`.
    caches: Vec<StageCaches>,
    slots: Vec<Option<SlotState>>,
    comm: CommStats,
    decode_steps: usize,
    prefill_tokens: usize,
    prefill_seconds: f64,
    decode_seconds: f64,
}

impl<'a> DecodeSession<'a> {
    /// Cache slots in this session (an artifact bucket).
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Rows currently decoding.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Slots available for admission.
    pub fn free_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    /// True decode iterations executed so far.
    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    pub fn prefill_seconds(&self) -> f64 {
        self.prefill_seconds
    }

    pub fn decode_seconds(&self) -> f64 {
        self.decode_seconds
    }

    /// Drain the communication counters accumulated since the last call.
    pub fn take_comm(&mut self) -> CommStats {
        std::mem::take(&mut self.comm)
    }

    /// Admit requests into free slots: run their prefill (at the smallest
    /// bucket that fits the admission batch) and scatter the resulting KV
    /// rows into the slots' cache rows. Callable between any two decode
    /// steps; in-flight rows are untouched. The outcome's `tokens` carry
    /// each admitted row's prefill-produced token; `finished` the rows
    /// that already completed at prefill (`max_new == 1` or stop token
    /// emitted), whose slots are freed again.
    ///
    /// Admitting while other rows are mid-decode leaves rows at different
    /// cache depths, which requires
    /// [`ExecutionBackend::supports_rowwise_decode_positions`]; on
    /// scalar-position backends (the AOT artifact signature) only admit
    /// into an idle session, as the service loop does.
    pub fn prefill_into_slots(&mut self, reqs: Vec<(usize, SlotRequest)>) -> Result<StepOutcome> {
        if reqs.is_empty() {
            return Ok(StepOutcome::default());
        }
        let info = self.exec.backend.manifest().model.clone();
        let mut claimed = vec![false; self.bucket];
        for (slot, r) in &reqs {
            if *slot >= self.bucket {
                bail!("slot {slot} outside session bucket {}", self.bucket);
            }
            if self.slots[*slot].is_some() || claimed[*slot] {
                bail!("slot {slot} is already occupied");
            }
            claimed[*slot] = true;
            if r.prompt.len() != info.prompt_len {
                bail!("prompt must be exactly {} tokens, got {}", info.prompt_len, r.prompt.len());
            }
            if r.max_new == 0 {
                bail!("max_new must be >= 1");
            }
        }
        let pb = self.exec.backend.manifest().bucket_for(reqs.len())?;

        let t0 = Instant::now();
        let mut tokens: Vec<i32> = Vec::with_capacity(pb * info.prompt_len);
        for (_, r) in &reqs {
            tokens.extend_from_slice(&r.prompt);
        }
        tokens.resize(pb * info.prompt_len, tokenizer::PAD);

        let mut x = self.exec.embed(&tokens, pb, info.prompt_len, true)?;
        for (si, stage) in self.exec.stages.iter().enumerate() {
            for (li, layer) in stage.layers().enumerate() {
                let (h, layer_caches) =
                    self.exec.layer_prefill(&x, layer, stage.tp, pb, &mut self.comm)?;
                x = h;
                for (shard, (kc, vc)) in layer_caches.iter().enumerate() {
                    for (row, (slot, _)) in reqs.iter().enumerate() {
                        let (dst_k, dst_v) = &mut self.caches[si][li][shard];
                        dst_k.copy_slot_from(*slot, kc, row)?;
                        dst_v.copy_slot_from(*slot, vc, row)?;
                    }
                }
            }
            if si + 1 < self.exec.stages.len() {
                record_pp_send(&x, &mut self.comm);
            }
        }
        let logits = self.exec.lm_head(&x, pb, true)?;
        let next = argmax_rows(&logits, info.vocab);
        self.prefill_seconds += t0.elapsed().as_secs_f64();
        self.prefill_tokens += reqs.len();

        let max_decode = info.max_seq - info.prompt_len;
        let mut out = StepOutcome::default();
        for (row, (slot, r)) in reqs.into_iter().enumerate() {
            let tok = next[row];
            out.tokens.push((slot, tok));
            let st = SlotState {
                max_new: r.max_new.min(max_decode).max(1),
                stop: r.stop,
                generated: vec![tok],
                next: tok,
                pos: info.prompt_len,
            };
            if st.generated.len() >= st.max_new || Some(tok) == st.stop {
                self.evict(slot);
                out.finished.push((slot, st.generated));
            } else {
                self.slots[slot] = Some(st);
            }
        }
        Ok(out)
    }

    /// Run one decode iteration for every active row, reporting each
    /// row's new token in the outcome's `tokens`. Rows that hit their own
    /// `max_new` or stop token retire into `finished`: their slots are
    /// freed (cache rows zeroed) and their full token sequences returned.
    /// A no-op returning an empty outcome when nothing is active.
    pub fn decode_step(&mut self) -> Result<StepOutcome> {
        if self.active() == 0 {
            return Ok(StepOutcome::default());
        }
        let info = self.exec.backend.manifest().model.clone();
        let t0 = Instant::now();

        let mut tok_batch = vec![tokenizer::PAD; self.bucket];
        let mut positions = vec![0i32; self.bucket];
        let mut filler_pos = 0i32;
        for (slot, st) in self.slots.iter().enumerate() {
            if let Some(st) = st {
                tok_batch[slot] = st.next;
                positions[slot] = st.pos as i32;
                filler_pos = st.pos as i32;
            }
        }
        // Free slots mirror an active row's position so a uniform batch
        // keeps the scalar-position artifact signature available.
        for (slot, st) in self.slots.iter().enumerate() {
            if st.is_none() {
                positions[slot] = filler_pos;
            }
        }

        let mut x = self.exec.embed(&tok_batch, self.bucket, 1, false)?;
        for (si, stage) in self.exec.stages.iter().enumerate() {
            for (li, layer) in stage.layers().enumerate() {
                x = self.exec.layer_decode(
                    &x,
                    layer,
                    stage.tp,
                    self.bucket,
                    &positions,
                    &mut self.caches[si][li],
                    &mut self.comm,
                )?;
            }
            if si + 1 < self.exec.stages.len() {
                record_pp_send(&x, &mut self.comm);
            }
        }
        let logits = self.exec.lm_head(&x, self.bucket, false)?;
        let next = argmax_rows(&logits, info.vocab);
        self.decode_steps += 1;
        self.decode_seconds += t0.elapsed().as_secs_f64();

        let mut out = StepOutcome::default();
        for slot in 0..self.bucket {
            let done = {
                let Some(st) = self.slots[slot].as_mut() else { continue };
                let tok = next[slot];
                st.generated.push(tok);
                st.next = tok;
                st.pos += 1;
                out.tokens.push((slot, tok));
                st.generated.len() >= st.max_new || Some(tok) == st.stop
            };
            if done {
                let st = self.slots[slot].take().expect("slot state");
                self.evict(slot);
                out.finished.push((slot, st.generated));
            }
        }
        Ok(out)
    }

    /// Cancel the request occupying `slot`: drop its decode state, zero
    /// its KV-cache rows, and free the slot for admission. Returns the
    /// tokens generated so far, or `None` when the slot was already free
    /// (the request may have retired in the same step it was cancelled).
    /// The serving loop calls this at decode-step boundaries, so
    /// cancellation never tears a step in half.
    pub fn cancel_slot(&mut self, slot: usize) -> Option<Vec<i32>> {
        let st = self.slots.get_mut(slot).and_then(Option::take)?;
        self.evict(slot);
        Some(st.generated)
    }

    /// Zero a slot's cache rows across all stages/layers/shards (evict).
    fn evict(&mut self, slot: usize) {
        for stage in self.caches.iter_mut() {
            for layer in stage.iter_mut() {
                for (k, v) in layer.iter_mut() {
                    let _ = k.clear_slot(slot);
                    let _ = v.clear_slot(slot);
                }
            }
        }
    }

    /// Fold the session's counters into a [`GenerationResult`].
    fn into_result(mut self, tokens: Vec<Vec<i32>>) -> GenerationResult {
        GenerationResult {
            tokens,
            prefill_seconds: self.prefill_seconds,
            decode_seconds: self.decode_seconds,
            decode_steps: self.decode_steps,
            prefill_tokens: self.prefill_tokens,
            comm: std::mem::take(&mut self.comm),
            bucket: self.bucket,
        }
    }
}

/// Row-wise argmax over a `[b, vocab]` tensor.
pub fn argmax_rows(logits: &Tensor, vocab: usize) -> Vec<i32> {
    assert_eq!(logits.dims.len(), 2);
    assert_eq!(logits.dims[1], vocab);
    logits
        .data
        .chunks_exact(vocab)
        .map(|row| {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in row.iter().enumerate() {
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_from_strategy_builds_ranges() {
        let p = plan_from_strategy(&[2, 1], &[4, 2]).unwrap();
        assert_eq!(p[0], StagePlan { layer_start: 0, layer_count: 4, tp: 2 });
        assert_eq!(p[1], StagePlan { layer_start: 4, layer_count: 2, tp: 1 });
        assert_eq!(p[1].layers(), 4..6);
    }

    #[test]
    fn plan_validation_errors() {
        assert!(plan_from_strategy(&[2], &[4, 2]).is_err());
        assert!(plan_from_strategy(&[], &[]).is_err());
        assert!(plan_from_strategy(&[1], &[0]).is_err());
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor { dims: vec![2, 3], data: vec![0.1, 0.9, 0.3, 5.0, -1.0, 2.0] };
        assert_eq!(argmax_rows(&t, 3), vec![1, 0]);
    }
}
