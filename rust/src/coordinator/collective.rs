//! Collectives between TP shard executions (paper §3.2).
//!
//! On the CPU testbed all shards execute in one process, so the
//! AllReduce is a host-side element-wise sum; the module still accounts
//! the bytes that would cross the wire (2 all-reduces per layer, the
//! traffic Eq. 5 models) so serving metrics can report communication
//! volumes.

use crate::runtime::Tensor;

/// Byte/op counters for a pipeline's collective traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    /// AllReduce invocations (2 per layer per token step at TP>1).
    pub allreduce_ops: usize,
    /// Bytes that would be aggregated across TP shards.
    pub allreduce_bytes: f64,
    /// Leader→leader stage hand-offs.
    pub pp_sends: usize,
    /// Bytes handed between pipeline stages.
    pub pp_bytes: f64,
    /// Prefill→decode KV-segment transfers (disaggregated hand-offs).
    pub kv_transfers: usize,
    /// KV rows shipped between replicas, in bytes.
    pub kv_transfer_bytes: f64,
}

impl CommStats {
    pub fn merge(&mut self, other: &CommStats) {
        self.allreduce_ops += other.allreduce_ops;
        self.allreduce_bytes += other.allreduce_bytes;
        self.pp_sends += other.pp_sends;
        self.pp_bytes += other.pp_bytes;
        self.kv_transfers += other.kv_transfers;
        self.kv_transfer_bytes += other.kv_transfer_bytes;
    }
}

/// Sum shard partials in place into the first tensor (AllReduce-sum).
/// Returns the reduced tensor; panics on shape mismatch (a plan bug).
pub fn all_reduce_sum(mut parts: Vec<Tensor>, stats: &mut CommStats) -> Tensor {
    assert!(!parts.is_empty(), "all_reduce over zero shards");
    let mut acc = parts.remove(0);
    for p in &parts {
        assert_eq!(p.dims, acc.dims, "shard partial shape mismatch");
        for (a, b) in acc.data.iter_mut().zip(&p.data) {
            *a += b;
        }
    }
    if !parts.is_empty() {
        stats.allreduce_ops += 1;
        stats.allreduce_bytes += (acc.data.len() * 4 * (parts.len() + 1)) as f64;
    }
    acc
}

/// Residual add: `x += delta` (same shape).
pub fn add_residual(x: &mut Tensor, delta: &Tensor) {
    assert_eq!(x.dims, delta.dims, "residual shape mismatch");
    for (a, b) in x.data.iter_mut().zip(&delta.data) {
        *a += b;
    }
}

/// Record a leader→leader pipeline hand-off of `t`.
pub fn record_pp_send(t: &Tensor, stats: &mut CommStats) {
    stats.pp_sends += 1;
    stats.pp_bytes += (t.data.len() * 4) as f64;
}

/// Record a prefill→decode KV-segment hand-off of `bytes` (metered on
/// the exporting side, like [`record_pp_send`]).
pub fn record_kv_transfer(bytes: f64, stats: &mut CommStats) {
    stats.kv_transfers += 1;
    stats.kv_transfer_bytes += bytes;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: Vec<f32>) -> Tensor {
        Tensor { dims: vec![data.len()], data }
    }

    #[test]
    fn sum_of_shards() {
        let mut stats = CommStats::default();
        let out = all_reduce_sum(
            vec![t(vec![1.0, 2.0]), t(vec![10.0, 20.0]), t(vec![100.0, 200.0])],
            &mut stats,
        );
        assert_eq!(out.data, vec![111.0, 222.0]);
        assert_eq!(stats.allreduce_ops, 1);
        assert_eq!(stats.allreduce_bytes, (2 * 4 * 3) as f64);
    }

    #[test]
    fn single_shard_is_free() {
        let mut stats = CommStats::default();
        let out = all_reduce_sum(vec![t(vec![5.0])], &mut stats);
        assert_eq!(out.data, vec![5.0]);
        assert_eq!(stats.allreduce_ops, 0);
        assert_eq!(stats.allreduce_bytes, 0.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_shards_panic() {
        let mut stats = CommStats::default();
        all_reduce_sum(vec![t(vec![1.0]), t(vec![1.0, 2.0])], &mut stats);
    }

    #[test]
    fn residual_and_pp_accounting() {
        let mut x = t(vec![1.0, 1.0]);
        add_residual(&mut x, &t(vec![2.0, 3.0]));
        assert_eq!(x.data, vec![3.0, 4.0]);
        let mut stats = CommStats::default();
        record_pp_send(&x, &mut stats);
        assert_eq!(stats.pp_sends, 1);
        assert_eq!(stats.pp_bytes, 8.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = CommStats {
            allreduce_ops: 1,
            allreduce_bytes: 8.0,
            pp_sends: 2,
            pp_bytes: 16.0,
            kv_transfers: 1,
            kv_transfer_bytes: 32.0,
        };
        a.merge(&CommStats {
            allreduce_ops: 3,
            allreduce_bytes: 24.0,
            pp_sends: 1,
            pp_bytes: 4.0,
            kv_transfers: 2,
            kv_transfer_bytes: 64.0,
        });
        assert_eq!(a.allreduce_ops, 4);
        assert_eq!(a.pp_bytes, 20.0);
        assert_eq!(a.kv_transfers, 3);
        assert_eq!(a.kv_transfer_bytes, 96.0);
    }

    #[test]
    fn kv_transfer_accounting() {
        let mut stats = CommStats::default();
        record_kv_transfer(128.0, &mut stats);
        record_kv_transfer(64.0, &mut stats);
        assert_eq!(stats.kv_transfers, 2);
        assert_eq!(stats.kv_transfer_bytes, 192.0);
    }
}
