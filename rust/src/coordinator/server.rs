//! Std-only HTTP/1.1 front-end over the threaded service — the
//! long-running face of `hexgen serve --listen ADDR`.
//!
//! No async runtime, no HTTP crate: a [`TcpListener`] accept loop with
//! one thread per connection (the service's own worker threads do the
//! heavy lifting; connection threads just block on event streams).
//!
//! Endpoints:
//!
//! | route | behaviour |
//! |---|---|
//! | `POST /v1/completions` | body `{"prompt", "max_new"?, "stop"?, "stream"?, "deadline_ms"?}`; `"stream": true` streams the request's [`RequestEvent`]s as Server-Sent Events (`queued` / `admitted` / `token` / `retrying` / `done` / `failed`), otherwise blocks and returns the completion JSON |
//! | `GET /healthz` | liveness (`ok` / `degraded` when replicas are quarantined) + per-replica breaker health |
//! | `GET /metrics` | router speeds & queue depths, replica health, request counters (incl. retries/failovers/losses), comm stats |
//! | `GET /v1/plan` | the per-replica stage plans being served, with breaker health |
//!
//! Per-request deadlines: the `x-hexgen-deadline-ms` header (overridden
//! by a `deadline_ms` body field) propagates into
//! [`GenRequest::deadline_ms`], enforced by the replica workers at every
//! admission/decode-step boundary — an expired request frees its KV
//! blocks and fails with 504, it does not burn decode steps until a
//! wait-side timer notices. Unset, requests get the server default
//! [`REQUEST_DEADLINE`].
//!
//! A client that disconnects mid-stream cancels its request: the SSE
//! write fails, the handler drops the [`RequestHandle`], and handle drop
//! propagates cancellation to the replica worker — freeing the KV slot
//! for the next admission.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::api::{Completion, GenRequest, RequestEvent, ServiceError};
use super::service::HexGenService;

/// Default per-request deadline (queue + prefill + decode) when the
/// client sets none; enforced service-side at the step boundary.
const REQUEST_DEADLINE: Duration = Duration::from_secs(600);
/// Extra slack the waiting side grants past the service-side deadline,
/// so the worker's `DeadlineExceeded` (which frees the KV blocks) wins
/// the race against the client-side `Timeout`.
const DEADLINE_GRACE: Duration = Duration::from_secs(5);
/// Socket read timeout while parsing a request head/body.
const READ_TIMEOUT: Duration = Duration::from_secs(30);
/// Largest accepted request body — the declared Content-Length is
/// attacker-controlled and is allocated up front, so it must be bounded.
const MAX_BODY_BYTES: usize = 1 << 20;
/// Largest accepted request head (request line + headers).
const MAX_HEAD_BYTES: usize = 64 << 10;

/// A running HTTP front-end (accept loop on its own thread).
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port 0 picks an ephemeral
    /// port — read it back from [`Self::addr`]) and serve the service on
    /// it until [`Self::shutdown`].
    pub fn serve(service: Arc<HexGenService>, addr: &str) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let accept = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let service = service.clone();
                        std::thread::spawn(move || {
                            if let Err(e) = handle_connection(&service, stream) {
                                crate::log_debug!("http connection ended: {e:#}");
                            }
                        });
                    }
                    Err(e) => crate::log_warn!("accept failed: {e}"),
                }
            }
        });
        Ok(HttpServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections and join the accept loop. In-flight
    /// connection handlers run to completion on their own threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a no-op connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block on the accept loop forever (`hexgen serve --listen`).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

struct HttpRequest {
    method: String,
    path: String,
    body: String,
    /// `x-hexgen-deadline-ms` header, if present (a `deadline_ms` body
    /// field overrides it).
    deadline_ms: Option<u64>,
}

/// Read one request; errors carry the HTTP status to answer with.
fn read_request(stream: &mut TcpStream) -> std::result::Result<HttpRequest, (u16, String)> {
    let bad = |e: &dyn std::fmt::Display| (400, format!("bad request: {e}"));
    let mut reader = BufReader::new(&mut *stream);
    let mut line = String::new();
    if reader.read_line(&mut line).map_err(|e| bad(&e))? == 0 {
        return Err((400, "empty request".to_string()));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| bad(&"missing method"))?.to_string();
    let path = parts.next().ok_or_else(|| bad(&"missing path"))?.to_string();
    let mut content_length = 0usize;
    let mut deadline_ms: Option<u64> = None;
    let mut head_bytes = line.len();
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header).map_err(|e| bad(&e))? == 0 {
            break;
        }
        head_bytes += header.len();
        if head_bytes > MAX_HEAD_BYTES {
            return Err((431, format!("request head exceeds {MAX_HEAD_BYTES} bytes")));
        }
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    v.trim().parse().map_err(|_| bad(&format!("bad content-length '{v}'")))?;
            } else if k.trim().eq_ignore_ascii_case("x-hexgen-deadline-ms") {
                deadline_ms = Some(
                    v.trim()
                        .parse()
                        .map_err(|_| bad(&format!("bad x-hexgen-deadline-ms '{v}'")))?,
                );
            }
        }
    }
    // The declared length is allocated up front: bound it before trusting it.
    if content_length > MAX_BODY_BYTES {
        return Err((413, format!("body of {content_length} bytes exceeds {MAX_BODY_BYTES}")));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| bad(&e))?;
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
        deadline_ms,
    })
}

fn handle_connection(service: &HexGenService, mut stream: TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err((status, msg)) => {
            respond_error(&mut stream, status, &msg)?;
            return Ok(());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond_json(&mut stream, 200, &health_json(service))?,
        ("GET", "/metrics") => respond_json(&mut stream, 200, &metrics_json(service))?,
        ("GET", "/v1/plan") => respond_json(&mut stream, 200, &plan_json(service))?,
        ("POST", "/v1/completions") => {
            handle_completions(service, &mut stream, &req.body, req.deadline_ms)?
        }
        _ => respond_error(&mut stream, 404, &format!("no route {} {}", req.method, req.path))?,
    }
    Ok(())
}

fn handle_completions(
    service: &HexGenService,
    stream: &mut TcpStream,
    body: &str,
    header_deadline_ms: Option<u64>,
) -> Result<()> {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return respond_error(stream, 400, &format!("bad json body: {e}")),
    };
    let Ok(prompt) = parsed.str("prompt") else {
        return respond_error(stream, 400, "missing required string field 'prompt'");
    };
    let mut req = GenRequest::new(prompt);
    if let Some(v) = parsed.opt("max_new") {
        match v.as_usize() {
            Ok(n) => req.max_new = Some(n),
            Err(_) => return respond_error(stream, 400, "'max_new' must be a non-negative integer"),
        }
    }
    if let Some(v) = parsed.opt("stop") {
        match v.as_f64() {
            Ok(x) if x.fract() == 0.0 => req.stop = Some(x as i32),
            _ => return respond_error(stream, 400, "'stop' must be an integer token id"),
        }
    }
    let streaming = match parsed.opt("stream") {
        None => false,
        Some(v) => match v.as_bool() {
            Ok(b) => b,
            Err(_) => return respond_error(stream, 400, "'stream' must be a boolean"),
        },
    };
    req.deadline_ms = header_deadline_ms;
    if let Some(v) = parsed.opt("deadline_ms") {
        match v.as_u64() {
            Ok(ms) => req.deadline_ms = Some(ms),
            Err(_) => {
                return respond_error(stream, 400, "'deadline_ms' must be a non-negative integer")
            }
        }
    }
    // The deadline is enforced by the replica workers at the step
    // boundary (freeing KV blocks); the wait below is only a backstop,
    // granted extra grace so the service-side verdict arrives first.
    let effective = Duration::from_millis(
        req.deadline_ms.unwrap_or(REQUEST_DEADLINE.as_millis() as u64),
    );
    req.deadline_ms = Some(effective.as_millis() as u64);

    let handle = service.submit(req);
    let deadline = Instant::now() + effective + DEADLINE_GRACE;
    if !streaming {
        return match handle.wait_deadline(deadline) {
            Ok(c) => respond_json(stream, 200, &completion_json(&c)),
            Err(e) => respond_service_error(stream, &e),
        };
    }

    // SSE: stream lifecycle events as they happen. A failed write means
    // the client hung up — bailing out drops `handle`, which cancels the
    // request at the next decode-step boundary.
    stream.write_all(
        b"HTTP/1.1 200 OK\r\n\
          Content-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\n\
          Connection: close\r\n\r\n",
    )?;
    loop {
        let ev = match handle.next_event_before(deadline) {
            Ok(ev) => ev,
            Err(e) => {
                write_sse(stream, "failed", &error_json(&e))?;
                break;
            }
        };
        match ev {
            RequestEvent::Queued => {
                let mut j = Json::obj();
                j.set("id", Json::from(handle.id().to_string()));
                write_sse(stream, "queued", &j)?;
            }
            RequestEvent::Admitted { replica, batch_size } => {
                let mut j = Json::obj();
                j.set("replica", Json::from(replica)).set("batch_size", Json::from(batch_size));
                write_sse(stream, "admitted", &j)?;
            }
            RequestEvent::Token { index, token, text_delta } => {
                let mut j = Json::obj();
                j.set("index", Json::from(index))
                    .set("token", Json::from(token as i64))
                    .set("text", Json::from(text_delta));
                write_sse(stream, "token", &j)?;
            }
            RequestEvent::Retrying { replica, attempt } => {
                let mut j = Json::obj();
                j.set("replica", Json::from(replica)).set("attempt", Json::from(attempt as u64));
                write_sse(stream, "retrying", &j)?;
            }
            RequestEvent::Done(c) => {
                write_sse(stream, "done", &completion_json(&c))?;
                break;
            }
            RequestEvent::Failed(e) => {
                write_sse(stream, "failed", &error_json(&e))?;
                break;
            }
        }
    }
    Ok(())
}

// ---- JSON views ---------------------------------------------------------

/// Per-replica breaker states as a JSON array of
/// `"healthy" | "quarantined" | "half_open"`.
fn health_array(service: &HexGenService) -> Json {
    Json::Arr(service.router_health().iter().map(|h| Json::from(h.as_str())).collect())
}

fn health_json(service: &HexGenService) -> Json {
    let health = service.router_health();
    let degraded = health.iter().any(|&h| h != super::router::ReplicaHealth::Healthy);
    let mut j = Json::obj();
    j.set("status", Json::from(if degraded { "degraded" } else { "ok" }))
        .set("replicas", Json::from(service.replicas()))
        .set("health", health_array(service));
    j
}

fn metrics_json(service: &HexGenService) -> Json {
    let snapshot = service.router_snapshot();
    let mut router = Json::obj();
    router
        .set("speeds", Json::Arr(snapshot.iter().map(|&(_, s)| Json::from(s)).collect()))
        .set("outstanding", Json::Arr(snapshot.iter().map(|&(o, _)| Json::from(o)).collect()))
        .set("health", health_array(service));
    let stats = service.stats();
    let mut requests = Json::obj();
    requests
        .set("submitted", Json::from(stats.submitted))
        .set("completed", Json::from(stats.completed))
        .set("failed", Json::from(stats.failed))
        .set("cancelled", Json::from(stats.cancelled))
        .set("tokens_out", Json::from(stats.tokens_out))
        .set("retries", Json::from(stats.retries))
        .set("failovers", Json::from(stats.failovers))
        .set("requests_lost", Json::from(stats.requests_lost))
        .set("deadline_expired", Json::from(stats.deadline_expired));
    let mut kv = Json::obj();
    kv.set("blocks_total", Json::from(stats.kv_blocks_total))
        .set("blocks_used", Json::from(stats.kv_blocks_used))
        .set("prefix_cache_hits", Json::from(stats.prefix_cache_hits))
        .set("prefix_cache_misses", Json::from(stats.prefix_cache_misses))
        .set("prefill_skips", Json::from(stats.prefill_skips));
    let mut spec = Json::obj();
    spec.set("rounds", Json::from(stats.spec_rounds))
        .set("proposed", Json::from(stats.spec_proposed))
        .set("accepted", Json::from(stats.spec_accepted))
        .set("acceptance_rate", Json::from(stats.spec_acceptance_rate()));
    let c = service.comm_stats();
    let mut comm = Json::obj();
    comm.set("allreduce_ops", Json::from(c.allreduce_ops))
        .set("allreduce_bytes", Json::from(c.allreduce_bytes))
        .set("pp_sends", Json::from(c.pp_sends))
        .set("pp_bytes", Json::from(c.pp_bytes))
        .set("kv_transfers_total", Json::from(c.kv_transfers))
        .set("kv_transfer_bytes", Json::from(c.kv_transfer_bytes));
    let mut j = Json::obj();
    j.set("replicas", Json::from(service.replicas()))
        .set("router", router)
        .set("requests", requests)
        .set("kv", kv)
        .set("spec", spec)
        .set("comm", comm);
    j
}

fn plan_json(service: &HexGenService) -> Json {
    let roles = service.roles();
    let health = service.router_health();
    let replicas: Vec<Json> = service
        .stage_plans()
        .iter()
        .enumerate()
        .map(|(i, plan)| {
            let stages: Vec<Json> = plan
                .iter()
                .map(|s| {
                    let mut j = Json::obj();
                    j.set("tp", Json::from(s.tp))
                        .set("layer_start", Json::from(s.layer_start))
                        .set("layer_count", Json::from(s.layer_count));
                    j
                })
                .collect();
            let tps: Vec<String> = plan.iter().map(|s| s.tp.to_string()).collect();
            let mut j = Json::obj();
            j.set("strategy", Json::from(format!("[{}]", tps.join(","))))
                .set("phase_role", Json::from(roles.get(i).copied().unwrap_or_default().as_str()))
                .set(
                    "health",
                    Json::from(
                        health
                            .get(i)
                            .copied()
                            .unwrap_or(super::router::ReplicaHealth::Healthy)
                            .as_str(),
                    ),
                )
                .set("stages", Json::Arr(stages));
            j
        })
        .collect();
    let mut j = Json::obj();
    j.set("replicas", Json::Arr(replicas))
        .set("speeds", Json::Arr(service.router_speeds().into_iter().map(Json::from).collect()))
        .set(
            "prefill_speeds",
            Json::Arr(service.router_prefill_speeds().into_iter().map(Json::from).collect()),
        );
    j
}

fn completion_json(c: &Completion) -> Json {
    let mut j = Json::obj();
    j.set("id", Json::from(c.id.to_string()))
        .set("text", Json::from(c.text.clone()))
        .set("tokens", Json::Arr(c.tokens.iter().map(|&t| Json::from(t as i64)).collect()))
        .set("prompt_tokens", Json::from(c.prompt_tokens))
        .set("truncated", Json::from(c.truncated))
        .set("replica", Json::from(c.replica))
        .set("batch_size", Json::from(c.batch_size))
        .set("latency_seconds", Json::from(c.latency))
        .set("queued_seconds", Json::from(c.queued))
        .set("prefill_seconds", Json::from(c.prefill_seconds))
        .set("decode_seconds", Json::from(c.decode_seconds))
        .set("decode_steps", Json::from(c.decode_steps));
    j
}

fn error_json(e: &ServiceError) -> Json {
    let mut j = Json::obj();
    j.set("error", Json::from(e.to_string()));
    j
}

fn error_status(e: &ServiceError) -> u16 {
    match e {
        ServiceError::InvalidRequest(_) => 400,
        ServiceError::Cancelled => 499,
        ServiceError::ReplicaFailed { .. } => 500,
        ServiceError::AllReplicasDown | ServiceError::Disconnected => 503,
        ServiceError::Timeout | ServiceError::DeadlineExceeded => 504,
    }
}

/// Map a [`ServiceError`] to its HTTP response; 503s carry `Retry-After`
/// so clients back off instead of hammering a quarantined fleet.
fn respond_service_error(stream: &mut TcpStream, e: &ServiceError) -> Result<()> {
    let status = error_status(e);
    let extra = if status == 503 { "Retry-After: 1\r\n" } else { "" };
    respond_json_headers(stream, status, extra, &error_json(e))
}

// ---- wire helpers -------------------------------------------------------

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn respond_json(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    respond_json_headers(stream, status, "", body)
}

/// `respond_json` with extra response headers (each `\r\n`-terminated).
fn respond_json_headers(
    stream: &mut TcpStream,
    status: u16,
    extra_headers: &str,
    body: &Json,
) -> Result<()> {
    let body = body.to_string();
    let resp = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n{extra_headers}Connection: close\r\n\r\n{body}",
        reason_phrase(status),
        body.len(),
    );
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

fn respond_error(stream: &mut TcpStream, status: u16, msg: &str) -> Result<()> {
    let mut j = Json::obj();
    j.set("error", Json::from(msg));
    respond_json(stream, status, &j)
}

fn write_sse(stream: &mut TcpStream, event: &str, data: &Json) -> Result<()> {
    stream.write_all(format!("event: {event}\ndata: {data}\n\n").as_bytes())?;
    stream.flush()?;
    Ok(())
}
