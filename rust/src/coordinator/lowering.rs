//! Lower a scheduler [`DeploymentPlan`] onto an artifact manifest.
//!
//! The scheduler plans the paper-scale model (e.g. LLAMA-2 70B, TP up to
//! 8) while the serving runtime executes whatever the AOT step actually
//! compiled (the demo/fixture model: few layers, a small set of
//! `tp_degrees`). [`lower_plan`] maps each replica of the plan to a
//! servable `Vec<StagePlan>`:
//!
//! - stage **TP degrees clamp down** to the largest compiled degree that
//!   also divides the served model's head count;
//! - per-stage **layer counts re-apportion** proportionally onto the
//!   served model's layer total (every stage keeps ≥ 1 layer);
//! - when a replica has more stages than the served model has layers,
//!   **adjacent stages merge** (smallest combined layer count first)
//!   until the pipeline fits.
//!
//! Every adjustment is reported in [`LoweredPlan::adjustments`] so the
//! operator sees exactly how the serving shape diverges from σ. The
//! plan's per-replica Eq. 2 cost estimates become normalized router
//! speed seeds (see [`super::router::Router::set_speeds`]).

use anyhow::{bail, Result};

use crate::parallelism::{DeploymentPlan, PhaseRole};
use crate::runtime::Manifest;

use super::pipeline::StagePlan;

/// A plan mapped onto the artifact manifest, ready for
/// [`super::service::ServiceConfig`].
#[derive(Debug, Clone)]
pub struct LoweredPlan {
    /// One stage plan per replica.
    pub replicas: Vec<Vec<StagePlan>>,
    /// Phase role per replica (v1 plans lower as all-hybrid).
    pub roles: Vec<PhaseRole>,
    /// Relative routing speed seed per replica, from the plan's Eq. 2
    /// cost estimates (normalized to mean 1.0; replicas without an
    /// estimate get 1.0). These are the *decode*-side seeds when the
    /// plan carries per-phase costs.
    pub speeds: Vec<f64>,
    /// Prefill-phase routing seeds (from `prefill_cost`, falling back
    /// to `cost_estimate`), normalized like [`Self::speeds`].
    pub prefill_speeds: Vec<f64>,
    /// Human-readable report of every merge/rescale/clamp applied.
    pub adjustments: Vec<String>,
}

/// Lower `plan` onto `manifest` (see module docs).
pub fn lower_plan(plan: &DeploymentPlan, manifest: &Manifest) -> Result<LoweredPlan> {
    plan.validate()?;
    validate_role_mix(plan)?;
    let m_layers = manifest.model.layers;
    if m_layers == 0 {
        bail!("manifest model has zero layers");
    }
    // TP degrees the runtime can execute: compiled artifacts exist AND
    // the degree divides the served model's head count.
    let mut avail: Vec<usize> = manifest
        .tp_degrees
        .iter()
        .copied()
        .filter(|&t| t >= 1 && manifest.model.heads % t == 0)
        .collect();
    avail.sort_unstable();
    let Some(&min_tp) = avail.first() else {
        bail!(
            "no usable tp degree in manifest (compiled {:?}, model has {} heads)",
            manifest.tp_degrees,
            manifest.model.heads
        );
    };

    let mut adjustments = Vec::new();
    let mut replicas = Vec::with_capacity(plan.replicas.len());
    for (i, r) in plan.replicas.iter().enumerate() {
        // (tp, layers) working copy of the replica's stages.
        let mut stages: Vec<(usize, usize)> = r.stages.iter().map(|s| (s.tp, s.layers)).collect();

        // ---- merge until the pipeline fits the served layer count ----
        if stages.len() > m_layers {
            while stages.len() > m_layers {
                // `stages.len() > m_layers >= 1` here, so there is always
                // an adjacent pair; bailing (not breaking) keeps the
                // re-apportionment below from underflowing if that
                // invariant ever breaks.
                let Some(j) =
                    (0..stages.len() - 1).min_by_key(|&j| stages[j].1 + stages[j + 1].1)
                else {
                    bail!("internal: replica {i} has no adjacent stage pair to merge");
                };
                stages[j] = (stages[j].0.max(stages[j + 1].0), stages[j].1 + stages[j + 1].1);
                stages.remove(j + 1);
            }
            adjustments.push(format!(
                "replica {i}: merged {} stages into {} (served model has {m_layers} layers)",
                r.stages.len(),
                stages.len(),
            ));
        }

        // ---- re-apportion layers proportionally (each stage ≥ 1) -----
        let plan_total: usize = stages.iter().map(|s| s.1).sum();
        let mut layers = vec![1usize; stages.len()];
        for _ in 0..(m_layers - stages.len()) {
            // Greedy largest-deficit apportionment: deterministic and
            // proportional to the plan's layer split.
            let Some(j) = (0..stages.len()).max_by(|&a, &b| {
                let deficit = |k: usize| {
                    stages[k].1 as f64 * m_layers as f64 / plan_total as f64 - layers[k] as f64
                };
                deficit(a).total_cmp(&deficit(b))
            }) else {
                bail!("internal: replica {i} lowered to zero stages");
            };
            layers[j] += 1;
        }
        if plan.model_layers != m_layers {
            adjustments.push(format!(
                "replica {i}: rescaled layer split {} ({} layers) -> {} ({m_layers} layers)",
                r.layer_string(),
                plan.model_layers,
                layers.iter().map(|l| l.to_string()).collect::<Vec<_>>().join("/"),
            ));
        }

        // ---- clamp TP degrees to compiled artifacts ------------------
        let mut out = Vec::with_capacity(stages.len());
        let mut start = 0usize;
        for (j, (&(want_tp, _), &lc)) in stages.iter().zip(&layers).enumerate() {
            let tp = avail.iter().copied().filter(|&t| t <= want_tp).max().unwrap_or(min_tp);
            if tp != want_tp {
                adjustments.push(format!(
                    "replica {i} stage {j}: tp {want_tp} -> {tp} (compiled degrees {:?})",
                    manifest.tp_degrees
                ));
            }
            out.push(StagePlan { layer_start: start, layer_count: lc, tp });
            start += lc;
        }
        debug_assert_eq!(start, m_layers);
        replicas.push(out);
    }

    let decode_costs: Vec<Option<f64>> =
        plan.replicas.iter().map(|r| r.decode_cost.or(r.cost_estimate)).collect();
    let prefill_costs: Vec<Option<f64>> =
        plan.replicas.iter().map(|r| r.prefill_cost.or(r.cost_estimate)).collect();
    Ok(LoweredPlan {
        replicas,
        roles: plan.replicas.iter().map(|r| r.phase_role).collect(),
        speeds: speeds_from_costs(&decode_costs),
        prefill_speeds: speeds_from_costs(&prefill_costs),
        adjustments,
    })
}

/// Reject role mixes the service cannot serve: a deployment needs at
/// least one decode-capable replica (every request must finish its
/// tokens somewhere) and at least one prefill-capable replica (every
/// request must enter somewhere); a prefill-only replica in particular
/// needs a decode partner to ship its KV segments to.
fn validate_role_mix(plan: &DeploymentPlan) -> Result<()> {
    let n_decode = plan.replicas.iter().filter(|r| r.phase_role.can_decode()).count();
    let n_prefill = plan.replicas.iter().filter(|r| r.phase_role.can_prefill()).count();
    if n_decode == 0 {
        bail!(
            "plan has no decode-capable replica ({} prefill-only): \
             prefill-only replicas need a decode partner for the KV hand-off",
            plan.replicas.len()
        );
    }
    if n_prefill == 0 {
        bail!("plan has no prefill-capable replica: no replica can admit prompts");
    }
    Ok(())
}

/// Normalized relative speed seeds from per-replica Eq. 2 cost
/// estimates: speed ∝ 1/cost, scaled so the mean over estimated
/// replicas is 1.0; replicas without an estimate default to 1.0.
fn speeds_from_costs(costs: &[Option<f64>]) -> Vec<f64> {
    let raw: Vec<Option<f64>> = costs
        .iter()
        .map(|c| c.and_then(|c| if c.is_finite() && c > 0.0 { Some(1.0 / c) } else { None }))
        .collect();
    let known: Vec<f64> = raw.iter().flatten().copied().collect();
    if known.is_empty() {
        return vec![1.0; costs.len()];
    }
    let mean = known.iter().sum::<f64>() / known.len() as f64;
    raw.iter().map(|o| o.map(|v| v / mean).unwrap_or(1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallelism::{PlanStage, ReplicaPlan};

    /// 6-layer manifest with tp {1,2,4} compiled (4 heads), no artifacts
    /// — lowering only consults model shape + tp_degrees.
    fn manifest_6l() -> Manifest {
        Manifest::parse(
            r#"{
              "model": {"name":"demo","layers":6,"hidden":128,"heads":4,"vocab":256,
                        "prompt_len":32,"max_seq":64,"head_dim":32,"ffn":512},
              "tp_degrees":[1,2,4],
              "batch_buckets":[1,4],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap()
    }

    /// 2-layer fixture-shaped manifest (2 heads, tp {1,2}).
    fn manifest_2l() -> Manifest {
        Manifest::parse(
            r#"{
              "model": {"name":"ref-demo","layers":2,"hidden":16,"heads":2,"vocab":256,
                        "prompt_len":8,"max_seq":16,"head_dim":8,"ffn":64},
              "tp_degrees":[1,2],
              "batch_buckets":[1,2],
              "weight_order":[],
              "artifacts":{}
            }"#,
        )
        .unwrap()
    }

    fn plan(model_layers: usize, replicas: Vec<ReplicaPlan>) -> DeploymentPlan {
        DeploymentPlan {
            cluster: "test".into(),
            model_name: "m".into(),
            model_layers,
            fitness: None,
            replicas,
        }
    }

    fn replica(stages: Vec<(usize, usize)>, cost: Option<f64>) -> ReplicaPlan {
        // Device bindings: consecutive ids, sized to each stage's tp.
        let mut next = NEXT_DEVICE.with(|n| *n.borrow());
        let stages = stages
            .into_iter()
            .map(|(tp, layers)| {
                let devices: Vec<usize> = (next..next + tp).collect();
                next += tp;
                PlanStage { tp, layers, devices }
            })
            .collect();
        NEXT_DEVICE.with(|n| *n.borrow_mut() = next);
        ReplicaPlan { stages, cost_estimate: cost, ..Default::default() }
    }

    thread_local! {
        static NEXT_DEVICE: std::cell::RefCell<usize> = const { std::cell::RefCell::new(0) };
    }

    fn reset_devices() {
        NEXT_DEVICE.with(|n| *n.borrow_mut() = 0);
    }

    #[test]
    fn identity_lowering_when_shapes_match() {
        reset_devices();
        let p = plan(6, vec![replica(vec![(2, 4), (1, 2)], None)]);
        let l = lower_plan(&p, &manifest_6l()).unwrap();
        assert_eq!(
            l.replicas[0],
            vec![
                StagePlan { layer_start: 0, layer_count: 4, tp: 2 },
                StagePlan { layer_start: 4, layer_count: 2, tp: 1 },
            ]
        );
        assert!(l.adjustments.is_empty(), "{:?}", l.adjustments);
        assert_eq!(l.speeds, vec![1.0]);
    }

    #[test]
    fn tp_clamps_to_largest_compiled_degree() {
        reset_devices();
        let p = plan(6, vec![replica(vec![(8, 6)], None)]);
        let l = lower_plan(&p, &manifest_6l()).unwrap();
        assert_eq!(l.replicas[0], vec![StagePlan { layer_start: 0, layer_count: 6, tp: 4 }]);
        assert_eq!(l.adjustments.len(), 1);
        assert!(l.adjustments[0].contains("tp 8 -> 4"), "{:?}", l.adjustments);
    }

    #[test]
    fn layers_rescale_proportionally() {
        reset_devices();
        // §3.1 layout 48/20/12 over 80 layers → 4/1/1 over 6.
        let p = plan(80, vec![replica(vec![(4, 48), (2, 20), (2, 12)], None)]);
        let l = lower_plan(&p, &manifest_6l()).unwrap();
        let counts: Vec<usize> = l.replicas[0].iter().map(|s| s.layer_count).collect();
        assert_eq!(counts, vec![4, 1, 1]);
        assert!(l.adjustments.iter().any(|a| a.contains("rescaled")), "{:?}", l.adjustments);
        // contiguous coverage
        assert_eq!(l.replicas[0][0].layers(), 0..4);
        assert_eq!(l.replicas[0][1].layers(), 4..5);
        assert_eq!(l.replicas[0][2].layers(), 5..6);
    }

    #[test]
    fn deep_pipelines_merge_to_fit() {
        reset_devices();
        // 8-stage TP=1 swarm chain → 2-layer fixture model: merge to 2.
        let p = plan(80, vec![replica(vec![(1, 10); 8], None)]);
        let l = lower_plan(&p, &manifest_2l()).unwrap();
        assert_eq!(
            l.replicas[0],
            vec![
                StagePlan { layer_start: 0, layer_count: 1, tp: 1 },
                StagePlan { layer_start: 1, layer_count: 1, tp: 1 },
            ]
        );
        assert!(l.adjustments.iter().any(|a| a.contains("merged 8 stages into 2")));
    }

    #[test]
    fn merge_keeps_the_larger_tp() {
        reset_devices();
        // [4,2,2] 48/20/12 → 2 layers: merge (20,12) first, keep tp 2;
        // then clamp 4 → 2.
        let p = plan(80, vec![replica(vec![(4, 48), (2, 20), (2, 12)], None)]);
        let l = lower_plan(&p, &manifest_2l()).unwrap();
        assert_eq!(
            l.replicas[0],
            vec![
                StagePlan { layer_start: 0, layer_count: 1, tp: 2 },
                StagePlan { layer_start: 1, layer_count: 1, tp: 2 },
            ]
        );
    }

    #[test]
    fn speeds_normalize_around_mean() {
        reset_devices();
        let p = plan(
            6,
            vec![
                replica(vec![(1, 6)], Some(0.5)),
                replica(vec![(1, 6)], Some(2.0)),
                replica(vec![(1, 6)], None),
            ],
        );
        let l = lower_plan(&p, &manifest_6l()).unwrap();
        // raw 1/cost = [2.0, 0.5], mean 1.25 → [1.6, 0.4]; unknown → 1.0
        assert!((l.speeds[0] - 1.6).abs() < 1e-12, "{:?}", l.speeds);
        assert!((l.speeds[1] - 0.4).abs() < 1e-12, "{:?}", l.speeds);
        assert_eq!(l.speeds[2], 1.0);
    }

    #[test]
    fn invalid_plans_are_rejected() {
        reset_devices();
        let mut p = plan(6, vec![replica(vec![(2, 4), (1, 2)], None)]);
        p.replicas[0].stages[0].layers = 3; // sum 5 != 6
        assert!(lower_plan(&p, &manifest_6l()).is_err());
    }

    #[test]
    fn role_mix_needs_a_decode_and_a_prefill_capable_replica() {
        use crate::parallelism::PhaseRole;
        reset_devices();
        let mut p = plan(6, vec![replica(vec![(1, 6)], None), replica(vec![(1, 6)], None)]);
        // prefill-only + decode-only is a valid disaggregated pair...
        p.replicas[0].phase_role = PhaseRole::Prefill;
        p.replicas[1].phase_role = PhaseRole::Decode;
        let l = lower_plan(&p, &manifest_6l()).unwrap();
        assert_eq!(l.roles, vec![PhaseRole::Prefill, PhaseRole::Decode]);
        // ...but all-prefill has nowhere to ship KV, and all-decode has
        // no entry point for prompts.
        p.replicas[1].phase_role = PhaseRole::Prefill;
        let err = lower_plan(&p, &manifest_6l()).unwrap_err().to_string();
        assert!(err.contains("decode partner"), "{err}");
        p.replicas[0].phase_role = PhaseRole::Decode;
        p.replicas[1].phase_role = PhaseRole::Decode;
        let err = lower_plan(&p, &manifest_6l()).unwrap_err().to_string();
        assert!(err.contains("prefill-capable"), "{err}");
    }

    #[test]
    fn per_phase_speeds_fall_back_to_the_fused_estimate() {
        reset_devices();
        let mut p = plan(
            6,
            vec![replica(vec![(1, 6)], Some(1.0)), replica(vec![(1, 6)], Some(1.0))],
        );
        // Replica 0: fast prefill (0.25), slow decode (2.0); replica 1
        // has only the fused estimate, which both phases fall back to.
        p.replicas[0].prefill_cost = Some(0.25);
        p.replicas[0].decode_cost = Some(2.0);
        let l = lower_plan(&p, &manifest_6l()).unwrap();
        // decode raw 1/cost = [0.5, 1.0], mean 0.75 → [2/3, 4/3]
        assert!((l.speeds[0] - 0.5 / 0.75).abs() < 1e-12, "{:?}", l.speeds);
        assert!((l.speeds[1] - 1.0 / 0.75).abs() < 1e-12, "{:?}", l.speeds);
        // prefill raw 1/cost = [4.0, 1.0], mean 2.5 → [1.6, 0.4]
        assert!((l.prefill_speeds[0] - 1.6).abs() < 1e-12, "{:?}", l.prefill_speeds);
        assert!((l.prefill_speeds[1] - 0.4).abs() < 1e-12, "{:?}", l.prefill_speeds);
    }
}
