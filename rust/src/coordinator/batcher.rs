//! Dynamic batching: collect queued requests into one execution batch.
//!
//! HexGen's batching is deliberately simple (paper Appendix D): a worker
//! blocks for the first request, then keeps admitting until either the
//! batch cap or the wait window is hit. Batch size is later padded to an
//! artifact bucket by the pipeline executor.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batch-formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per batch (≤ the largest artifact bucket).
    pub max_batch: usize,
    /// How long to wait for co-batchable requests after the first.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, window: Duration::from_millis(20) }
    }
}

/// Collect one batch from `rx`. Blocks for the first item; returns
/// `None` when the channel is closed and drained.
pub fn collect_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.window;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_waiting_items_up_to_cap() {
        let (tx, rx) = channel();
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, window: Duration::from_millis(5) };
        let b = collect_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = collect_batch(&rx, &policy).unwrap();
        assert_eq!(b2, vec![4, 5]);
    }

    #[test]
    fn returns_none_on_closed_empty_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let policy = BatchPolicy::default();
        assert!(collect_batch(&rx, &policy).is_none());
    }

    #[test]
    fn window_bounds_the_wait() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy { max_batch: 8, window: Duration::from_millis(10) };
        let t0 = Instant::now();
        let b = collect_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn late_items_join_within_window() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
        });
        let policy = BatchPolicy { max_batch: 4, window: Duration::from_millis(200) };
        let b = collect_batch(&rx, &policy).unwrap();
        handle.join().unwrap();
        assert!(b.contains(&1));
        // item 2 should usually join; tolerate scheduler jitter
        assert!(b.len() <= 2);
    }
}
