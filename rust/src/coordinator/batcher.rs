//! Admission policy for continuous (iteration-level) batching.
//!
//! HexGen's original batching (paper Appendix D) collected a one-shot
//! batch and ran it to completion. The serving loop now admits at every
//! decode-step boundary instead: an [`AdmissionQueue`] buffers arrivals
//! off the worker's channel, and [`AdmissionQueue::admit`] hands over as
//! many requests as there are free KV-cache slots. The wait `window`
//! only applies when the worker is idle (nothing decoding) — co-batching
//! prefills is worth a short wait, but stalling an in-flight batch is
//! not.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Admission policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum co-batched requests (the serving loop sizes its KV-cache
    /// slot count to the largest artifact bucket ≤ this).
    pub max_batch: usize,
    /// How long an *idle* worker waits for co-batchable requests after
    /// the first arrival (never delays an in-flight batch).
    pub window: Duration,
    /// Iteration-level scheduling: admit queued requests into freed
    /// slots at decode-step boundaries. `false` reverts to
    /// run-to-completion batching (the static baseline benchmarked by
    /// `benches/batching.rs`).
    pub continuous: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, window: Duration::from_millis(20), continuous: true }
    }
}

/// Outcome of a bounded idle wait ([`AdmissionQueue::wait_for`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitOutcome {
    /// At least one request is pending.
    Ready,
    /// Nothing arrived within the timeout (channel still open) — the
    /// caller gets control back, e.g. to honour cancellations.
    TimedOut,
    /// Channel closed and fully drained: shut down.
    Closed,
}

/// Buffered view over a worker's request channel.
pub struct AdmissionQueue<T> {
    rx: Receiver<T>,
    pending: VecDeque<T>,
    disconnected: bool,
}

impl<T> AdmissionQueue<T> {
    pub fn new(rx: Receiver<T>) -> AdmissionQueue<T> {
        AdmissionQueue { rx, pending: VecDeque::new(), disconnected: false }
    }

    /// Drain everything currently queued on the channel, without blocking.
    pub fn poll(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok(item) => self.pending.push_back(item),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.disconnected = true;
                    break;
                }
            }
        }
    }

    /// Requests buffered and not yet admitted.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// True once the channel is closed and every request was handed out.
    pub fn is_closed(&self) -> bool {
        self.disconnected && self.pending.is_empty()
    }

    /// Block until at least one request is available. Returns `false`
    /// when the channel closed with nothing left (shutdown).
    pub fn wait(&mut self) -> bool {
        self.poll();
        if !self.pending.is_empty() {
            return true;
        }
        if self.disconnected {
            return false;
        }
        match self.rx.recv() {
            Ok(item) => {
                self.pending.push_back(item);
                true
            }
            Err(_) => {
                self.disconnected = true;
                false
            }
        }
    }

    /// Bounded [`Self::wait`]: block until a request is available, the
    /// channel closes, or `timeout` elapses. The timeout arm lets the
    /// serving loop wake periodically while idle to sweep cancelled
    /// requests out of its queue.
    pub fn wait_for(&mut self, timeout: Duration) -> WaitOutcome {
        self.poll();
        if !self.pending.is_empty() {
            return WaitOutcome::Ready;
        }
        if self.disconnected {
            return WaitOutcome::Closed;
        }
        match self.rx.recv_timeout(timeout) {
            Ok(item) => {
                self.pending.push_back(item);
                WaitOutcome::Ready
            }
            Err(RecvTimeoutError::Timeout) => WaitOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => {
                self.disconnected = true;
                WaitOutcome::Closed
            }
        }
    }

    /// Remove and return the buffered requests matching `pred` (the
    /// channel is polled first so newly arrived items are considered).
    /// The serving loop uses this to purge cancelled requests before
    /// they ever occupy a slot.
    pub fn drain_where<F: FnMut(&T) -> bool>(&mut self, mut pred: F) -> Vec<T> {
        self.poll();
        let mut out = Vec::new();
        let mut kept = VecDeque::with_capacity(self.pending.len());
        for item in self.pending.drain(..) {
            if pred(&item) {
                out.push(item);
            } else {
                kept.push_back(item);
            }
        }
        self.pending = kept;
        out
    }

    /// Remove and return everything buffered (worker teardown: a dying
    /// replica must fail its queued requests, not drop them silently).
    pub fn drain_all(&mut self) -> Vec<T> {
        self.poll();
        self.pending.drain(..).collect()
    }

    /// Hand out up to `min(free, policy.max_batch)` requests. When `idle`
    /// and fewer are pending, waits up to `policy.window` for
    /// co-batchable arrivals first.
    pub fn admit(&mut self, free: usize, idle: bool, policy: &BatchPolicy) -> Vec<T> {
        self.admit_budgeted(free, idle, policy, usize::MAX, |_| 0)
    }

    /// [`Self::admit`] with a resource budget: hand out the longest FIFO
    /// prefix of the pending queue whose summed `cost` fits `budget`, up
    /// to `min(free, policy.max_batch)` items. The serving loop passes
    /// the KV pool's free-block count as the budget and each request's
    /// worst-case block need as its cost, so admission **defers** when
    /// the pool cannot cover a request (it stays queued for a later
    /// boundary, after blocks are freed) instead of over-committing and
    /// failing mid-decode. The scan is strictly FIFO — a cheap request
    /// never jumps an expensive one, so an over-budget head blocks until
    /// retirements free its budget (no starvation).
    pub fn admit_budgeted<C: FnMut(&T) -> usize>(
        &mut self,
        free: usize,
        idle: bool,
        policy: &BatchPolicy,
        budget: usize,
        mut cost: C,
    ) -> Vec<T> {
        self.poll();
        let cap = free.min(policy.max_batch);
        if cap == 0 || self.pending.is_empty() {
            return Vec::new();
        }
        if idle && self.pending.len() < cap && !self.disconnected {
            let deadline = Instant::now() + policy.window;
            while self.pending.len() < cap {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.rx.recv_timeout(deadline - now) {
                    Ok(item) => self.pending.push_back(item),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        self.disconnected = true;
                        break;
                    }
                }
            }
        }
        let mut n = 0;
        let mut spent = 0usize;
        while n < cap.min(self.pending.len()) {
            let c = cost(&self.pending[n]);
            match spent.checked_add(c) {
                Some(total) if total <= budget => spent = total,
                _ => break,
            }
            n += 1;
        }
        self.pending.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn policy(max_batch: usize, window_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, window: Duration::from_millis(window_ms), continuous: true }
    }

    #[test]
    fn admits_waiting_items_up_to_cap() {
        let (tx, rx) = channel();
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        let mut q = AdmissionQueue::new(rx);
        assert_eq!(q.admit(4, true, &policy(4, 5)), vec![0, 1, 2, 3]);
        assert_eq!(q.admit(4, true, &policy(4, 5)), vec![4, 5]);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn free_slots_bound_admission() {
        let (tx, rx) = channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let mut q = AdmissionQueue::new(rx);
        // only 1 free slot: admit exactly one, keep the rest pending
        assert_eq!(q.admit(1, false, &policy(4, 5)), vec![0]);
        assert_eq!(q.pending(), 3);
        assert_eq!(q.admit(2, false, &policy(4, 5)), vec![1, 2]);
    }

    #[test]
    fn busy_admission_never_waits() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let mut q = AdmissionQueue::new(rx);
        let t0 = Instant::now();
        // idle=false: even with a huge window and spare capacity, return
        // immediately with what is pending.
        assert_eq!(q.admit(8, false, &policy(8, 5_000)), vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn idle_window_bounds_the_wait() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let mut q = AdmissionQueue::new(rx);
        let t0 = Instant::now();
        assert_eq!(q.admit(8, true, &policy(8, 10)), vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(500));
        drop(tx);
    }

    #[test]
    fn late_items_join_within_idle_window() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
        });
        let mut q = AdmissionQueue::new(rx);
        let b = q.admit(4, true, &policy(4, 200));
        handle.join().unwrap();
        assert!(b.contains(&1));
        // item 2 should usually join; tolerate scheduler jitter
        assert!(b.len() <= 2);
    }

    #[test]
    fn budget_bounds_the_admitted_prefix() {
        let (tx, rx) = channel();
        // Costs: 3, 3, 1 — budget 4 covers only the first item; the
        // cheap third item must NOT jump the over-budget second (FIFO).
        for c in [3usize, 3, 1] {
            tx.send(c).unwrap();
        }
        let mut q = AdmissionQueue::new(rx);
        assert_eq!(q.admit_budgeted(8, false, &policy(8, 5), 4, |&c| c), vec![3]);
        assert_eq!(q.pending(), 2);
        // Budget freed up: the rest fits.
        assert_eq!(q.admit_budgeted(8, false, &policy(8, 5), 4, |&c| c), vec![3, 1]);
    }

    #[test]
    fn zero_budget_defers_everything() {
        let (tx, rx) = channel();
        tx.send(1usize).unwrap();
        let mut q = AdmissionQueue::new(rx);
        assert!(q.admit_budgeted(4, false, &policy(4, 5), 0, |&c| c).is_empty());
        assert_eq!(q.pending(), 1, "deferred requests stay queued");
        // Zero-cost items always fit (admit delegates with cost 0).
        assert_eq!(q.admit_budgeted(4, false, &policy(4, 5), 0, |_| 0), vec![1]);
    }

    #[test]
    fn budget_and_slots_bound_independently() {
        let (tx, rx) = channel();
        for i in 0..4usize {
            tx.send(i).unwrap();
        }
        let mut q = AdmissionQueue::new(rx);
        // 2 free slots but budget for 3 unit-cost items: slots win.
        assert_eq!(q.admit_budgeted(2, false, &policy(8, 5), 3, |_| 1), vec![0, 1]);
        // 8 slots but budget for 1: budget wins.
        assert_eq!(q.admit_budgeted(8, false, &policy(8, 5), 1, |_| 1), vec![2]);
        assert_eq!(q.pending(), 1);
    }

    #[test]
    fn wait_returns_false_on_closed_empty_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let mut q = AdmissionQueue::new(rx);
        assert!(!q.wait());
        assert!(q.is_closed());
        assert!(q.admit(4, true, &BatchPolicy::default()).is_empty());
    }

    #[test]
    fn wait_for_times_out_then_sees_items() {
        let (tx, rx) = channel();
        let mut q = AdmissionQueue::new(rx);
        assert_eq!(q.wait_for(Duration::from_millis(5)), WaitOutcome::TimedOut);
        tx.send(3).unwrap();
        assert_eq!(q.wait_for(Duration::from_millis(5)), WaitOutcome::Ready);
        drop(tx);
        assert_eq!(q.admit(4, false, &BatchPolicy::default()), vec![3]);
        assert_eq!(q.wait_for(Duration::from_millis(5)), WaitOutcome::Closed);
    }

    #[test]
    fn drain_where_removes_matching_keeps_order() {
        let (tx, rx) = channel();
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        let mut q = AdmissionQueue::new(rx);
        assert_eq!(q.drain_where(|&x| x % 2 == 0), vec![0, 2, 4]);
        assert_eq!(q.pending(), 3);
        assert_eq!(q.admit(8, false, &BatchPolicy::default()), vec![1, 3, 5]);
    }

    #[test]
    fn drain_all_empties_queue_and_channel() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let mut q = AdmissionQueue::new(rx);
        q.poll();
        tx.send(3).unwrap();
        assert_eq!(q.drain_all(), vec![1, 2, 3]);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn wait_drains_channel_after_close() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let mut q = AdmissionQueue::new(rx);
        assert!(q.wait());
        assert_eq!(q.admit(4, true, &BatchPolicy::default()), vec![7]);
        assert!(!q.wait());
    }
}
