//! Speculative decoding: a small **draft** model proposes `k` tokens per
//! round and the **target** model verifies them in one batched forward —
//! fewer target iterations per emitted token, the biggest per-step
//! decode-latency lever that needs no new hardware (ROADMAP direction 2,
//! SNIPPETS §8; it compounds with disaggregated serving, where cheap
//! replicas can run drafts while strong replicas verify).
//!
//! [`SpeculativeSession`] wraps two [`DecodeSession`]s over a two-model
//! manifest pair (same vocabulary, prompt length, and context; layer
//! count / width may differ). Per [`SpeculativeSession::spec_round`]:
//!
//! 1. the draft runs `k` greedy [`DecodeSession::decode_step`]s,
//!    proposing `p_1 .. p_k` per row;
//! 2. the target scores the row's pending token plus all `k` proposals
//!    in **one** batched forward ([`DecodeSession::verify_step`] →
//!    [`ExecutionBackend::execute_attn_score_inplace`]), returning the
//!    greedy token after every fed position;
//! 3. greedy verification accepts the longest prefix of proposals that
//!    match the target's tokens, plus the target's one correction token
//!    — so every round commits at least 1 and at most `k + 1` tokens;
//! 4. **both** sessions roll their paged KV back past the rejected tail
//!    ([`DecodeSession::truncate_rows`]: tail blocks pop to the free
//!    list with the row's reservation restored, no leak, shared prompt
//!    prefixes untouched) and commit the accepted tokens
//!    ([`DecodeSession::commit_tokens`]).
//!
//! **Parity contract.** Every committed token is either a proposal the
//! target's own argmax agreed with at that position, or the target's
//! argmax itself — by induction the emitted stream is *token-identical*
//! to the target decoding alone, for every acceptance pattern (full,
//! partial, zero). The draft only decides how many target iterations
//! that stream costs. Golden tests pin this against the ref_demo
//! fixtures (`tests/reference_parity.rs`).
//!
//! [`ExecutionBackend::execute_attn_score_inplace`]:
//!     crate::runtime::ExecutionBackend::execute_attn_score_inplace

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use super::pipeline::{DecodeSession, SlotRequest, StepOutcome};

/// Opt-in speculative-decoding policy carried by a service config: serve
/// with a draft model proposing `k` tokens per round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecPolicy {
    /// Draft tokens proposed per round (≥ 1). Each round costs one draft
    /// step per proposal plus **one** target forward, and commits
    /// between 1 and `k + 1` tokens.
    pub k: usize,
    /// Artifacts directory of the draft model (manifest + weights). Must
    /// agree with the target on vocabulary, prompt length, and context
    /// length.
    pub draft_model: PathBuf,
}

/// Lifetime speculation counters ([`SpeculativeSession::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Completed propose/verify rounds.
    pub rounds: u64,
    /// Draft tokens proposed across all rounds.
    pub proposed: u64,
    /// Proposed tokens the target accepted (committed to the stream).
    pub accepted: u64,
}

impl SpecStats {
    /// Fraction of proposed tokens accepted (0 when nothing proposed).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// Draft-propose / target-verify serving session: two [`DecodeSession`]s
/// in lock-step, slot `i` of the target paired with slot `i` of the
/// draft. The target is authoritative — its prefill and verify tokens
/// are the emitted stream; the draft mirrors the target's committed
/// tokens after every round so its next proposals continue the right
/// prefix.
pub struct SpeculativeSession<'a> {
    target: DecodeSession<'a>,
    draft: DecodeSession<'a>,
    k: usize,
    stats: SpecStats,
    // Round-scoped scratch, reused so steady-state rounds allocate only
    // inside the wrapped sessions.
    scratch_active: Vec<usize>,
    scratch_feed: Vec<i32>,
    scratch_acc: Vec<i32>,
    proposals: Vec<Vec<i32>>,
}

impl<'a> SpeculativeSession<'a> {
    /// Pair a target session with a draft session proposing `k` tokens
    /// per round. The sessions must have the same bucket (slot `i` maps
    /// to slot `i`) and their models must agree on vocabulary, prompt
    /// length, and `max_seq` — token streams and cache depths are shared
    /// between them; layer count, width, and head count may differ
    /// freely (that asymmetry is the whole point of a draft).
    pub fn new(
        target: DecodeSession<'a>,
        draft: DecodeSession<'a>,
        k: usize,
    ) -> Result<SpeculativeSession<'a>> {
        if k == 0 {
            bail!("speculative k must be >= 1");
        }
        if target.bucket() != draft.bucket() {
            bail!(
                "target bucket {} != draft bucket {}: slots pair one-to-one",
                target.bucket(),
                draft.bucket()
            );
        }
        let (t, d) = (&target.manifest().model, &draft.manifest().model);
        if t.vocab != d.vocab {
            bail!("target vocab {} != draft vocab {}", t.vocab, d.vocab);
        }
        if t.prompt_len != d.prompt_len {
            bail!("target prompt_len {} != draft prompt_len {}", t.prompt_len, d.prompt_len);
        }
        if t.max_seq != d.max_seq {
            bail!("target max_seq {} != draft max_seq {}", t.max_seq, d.max_seq);
        }
        if t.max_seq < t.prompt_len + 2 {
            bail!(
                "max_seq {} leaves no decode room past prompt_len {} to speculate in",
                t.max_seq,
                t.prompt_len
            );
        }
        let bucket = target.bucket();
        Ok(SpeculativeSession {
            target,
            draft,
            k,
            stats: SpecStats::default(),
            scratch_active: Vec::with_capacity(bucket),
            scratch_feed: Vec::with_capacity(k + 1),
            scratch_acc: Vec::with_capacity(k + 1),
            proposals: (0..bucket).map(|_| Vec::with_capacity(k)).collect(),
        })
    }

    /// The authoritative (verifying) session.
    pub fn target(&self) -> &DecodeSession<'a> {
        &self.target
    }

    /// The proposing session.
    pub fn draft(&self) -> &DecodeSession<'a> {
        &self.draft
    }

    /// Proposals per round.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Lifetime speculation counters.
    pub fn stats(&self) -> SpecStats {
        self.stats
    }

    /// Rows currently decoding (target view; the draft mirrors it).
    pub fn active(&self) -> usize {
        self.target.active()
    }

    /// Slots available for admission.
    pub fn free_slots(&self) -> Vec<usize> {
        self.target.free_slots()
    }

    /// Drain both sessions' communication counters, merged.
    pub fn take_comm(&mut self) -> super::collective::CommStats {
        let mut c = self.target.take_comm();
        let d = self.draft.take_comm();
        c.allreduce_ops += d.allreduce_ops;
        c.allreduce_bytes += d.allreduce_bytes;
        c.pp_sends += d.pp_sends;
        c.pp_bytes += d.pp_bytes;
        c.kv_transfers += d.kv_transfers;
        c.kv_transfer_bytes += d.kv_transfer_bytes;
        c
    }

    /// Admit requests into paired free slots: the target prefills first
    /// (its tokens are the emitted stream — the outcome is exactly what
    /// [`DecodeSession::prefill_into_slots`] reports), then the draft
    /// prefills the same prompts into its own paired slots with the
    /// widest limit and no stop token (the driver retires draft rows in
    /// lock-step with the target, so a draft row must never retire on
    /// its own mid-round). Each surviving draft row's pending token is
    /// forced to the target's prefill token; draft rows whose target row
    /// already finished at prefill are released immediately. A draft
    /// admission failure rolls the target rows back and surfaces the
    /// error — the caller should gate on **both** sessions' block
    /// budgets to defer instead.
    pub fn admit(&mut self, reqs: Vec<(usize, SlotRequest)>) -> Result<StepOutcome> {
        if reqs.is_empty() {
            return Ok(StepOutcome::default());
        }
        let info = &self.draft.manifest().model;
        let draft_max = info.max_seq - info.prompt_len;
        let draft_reqs: Vec<(usize, SlotRequest)> = reqs
            .iter()
            .map(|(slot, r)| {
                (*slot, SlotRequest { prompt: r.prompt.clone(), max_new: draft_max, stop: None })
            })
            .collect();
        let out = self.target.prefill_into_slots(reqs)?;
        if let Err(e) = self.draft.prefill_into_slots(draft_reqs) {
            for &(slot, _) in &out.tokens {
                self.target.cancel_slot(slot)?;
            }
            return Err(e);
        }
        for &(slot, tok) in &out.tokens {
            if out.finished.iter().any(|(s, _)| *s == slot) {
                self.draft.cancel_slot(slot)?;
            } else {
                self.draft.force_next(slot, tok)?;
            }
        }
        Ok(out)
    }

    /// Cancel the paired rows in `slot`, releasing both sessions' KV
    /// blocks. Returns the target's tokens generated so far (`None` when
    /// the slot was already free), like [`DecodeSession::cancel_slot`].
    pub fn cancel_slot(&mut self, slot: usize) -> Result<Option<Vec<i32>>> {
        let toks = self.target.cancel_slot(slot)?;
        self.draft.cancel_slot(slot)?;
        Ok(toks)
    }

    /// Run one propose/verify/commit round for every active row. The
    /// outcome streams **all** tokens committed this round (1 to `k + 1`
    /// per row, in acceptance order) through `tokens`, and retired rows
    /// through `finished` — the same shape as
    /// [`DecodeSession::decode_step`], so the serving loop treats a
    /// speculative round as a decode step that may emit several tokens
    /// per row.
    ///
    /// The round size is `k` clamped so no row's verify pass writes past
    /// its admission-time block reservation (`max_new - generated - 1`
    /// over the active rows); near a row's limit it degrades to 0
    /// proposals — a verify-only round that is plain greedy decode
    /// through the scoring path.
    pub fn spec_round(&mut self) -> Result<StepOutcome> {
        if self.target.active() == 0 {
            return Ok(StepOutcome::default());
        }
        let mut active = std::mem::take(&mut self.scratch_active);
        active.clear();
        let mut k_round = self.k;
        for slot in 0..self.target.bucket() {
            if let Some(v) = self.target.slot_view(slot) {
                active.push(slot);
                k_round = k_round.min(v.max_new.saturating_sub(v.generated + 1));
            }
        }

        // Phase 1 — draft proposes k_round tokens per row (batched
        // decode steps across all active rows).
        for p in self.proposals.iter_mut() {
            p.clear();
        }
        for _ in 0..k_round {
            let out = self.draft.decode_step()?;
            if !out.finished.is_empty() {
                bail!("internal: draft row retired mid-round (limits should prevent this)");
            }
            for (slot, tok) in out.tokens {
                self.proposals[slot].push(tok);
            }
        }

        // Phase 2 — per row: one batched target verify, greedy-prefix
        // acceptance, rollback of the rejected tail in both sessions,
        // and the token commit.
        let mut outcome = StepOutcome::default();
        let mut feed = std::mem::take(&mut self.scratch_feed);
        let mut acc = std::mem::take(&mut self.scratch_acc);
        for &slot in &active {
            let v = self
                .target
                .slot_view(slot)
                .ok_or_else(|| anyhow!("internal: active slot {slot} lost its target row"))?;
            let (g, pos0) = (v.generated, v.pos);
            if self.proposals[slot].len() != k_round {
                bail!(
                    "internal: draft proposed {} tokens for slot {slot}, round wants {k_round}",
                    self.proposals[slot].len()
                );
            }
            // The verify feed is the row's pending token followed by the
            // proposals; `scored[i]` is the target's greedy token after
            // feed position i.
            feed.clear();
            feed.push(v.next);
            feed.extend_from_slice(&self.proposals[slot]);
            let scored = self.target.verify_step(slot, &feed)?;

            // Longest matching prefix, then the target's correction.
            let mut m = 0;
            while m < k_round && self.proposals[slot][m] == scored[m] {
                m += 1;
            }
            acc.clear();
            acc.extend_from_slice(&self.proposals[slot][..m]);
            acc.push(scored[m]);
            // A stop token anywhere in the accepted run ends the row
            // there — tokens past it were never part of the stream.
            if let Some(stop) = v.stop {
                if let Some(i) = acc.iter().position(|&t| t == stop) {
                    acc.truncate(i + 1);
                }
            }
            let e = acc.len();
            self.stats.proposed += k_round as u64;
            self.stats.accepted += m.min(e) as u64;

            // Target: drop the KV of rejected positions, commit tokens.
            self.target.truncate_rows(slot, pos0 + e)?;
            let finished = self.target.commit_tokens(slot, g, &acc)?;

            // Draft: mirror the target exactly. A fully accepted round
            // leaves the draft one KV entry *short* (its last proposal
            // was never fed back), so it catches up with a one-token
            // scoring pass; otherwise it rolls back like the target.
            if finished.is_some() {
                self.draft.cancel_slot(slot)?;
            } else {
                let dv = self
                    .draft
                    .slot_view(slot)
                    .ok_or_else(|| anyhow!("internal: active slot {slot} lost its draft row"))?;
                if e == k_round + 1 {
                    let catch = [dv.next];
                    self.draft.verify_step(slot, &catch)?;
                } else {
                    self.draft.truncate_rows(slot, pos0 + e)?;
                }
                if self.draft.commit_tokens(slot, g, &acc)?.is_some() {
                    bail!("internal: draft row retired ahead of its target row");
                }
            }

            for &t in &acc {
                outcome.tokens.push((slot, t));
            }
            if let Some(toks) = finished {
                outcome.finished.push((slot, toks));
            }
        }
        self.stats.rounds += 1;
        self.scratch_active = active;
        self.scratch_feed = feed;
        self.scratch_acc = acc;
        Ok(outcome)
    }
}
