//! The HexGen coordinator (Layer 3): the request-lifecycle serving API
//! (streaming, cancellable [`RequestHandle`]s), request routing,
//! continuous (iteration-level) batching, leader-side collectives, the
//! asymmetric TP×PP pipeline executor, and a std-only HTTP/1.1 front-end
//! — the real serving path (paper §3.2, Appendix C). Python never runs
//! here; the executors run stage artifacts through a pluggable
//! [`crate::runtime::ExecutionBackend`] (pure-Rust reference by default,
//! PJRT behind the `pjrt` feature).

pub mod api;
pub mod batcher;
pub mod collective;
pub mod lowering;
pub mod pipeline;
pub mod router;
pub mod server;
pub mod service;
pub mod speculative;

pub use api::{
    collect_all, Completion, GenRequest, RequestEvent, RequestHandle, RequestId, ServiceError,
};
pub use batcher::{AdmissionQueue, BatchPolicy};
pub use collective::{add_residual, all_reduce_sum, CommStats};
pub use lowering::{lower_plan, LoweredPlan};
pub use pipeline::{
    argmax_rows, plan_from_strategy, DecodeSession, GenerationResult, KvSegment,
    PipelineExecutor, SlotRequest, SlotView, StagePlan, StepOutcome,
};
pub use router::{BreakerPolicy, ReplicaHealth, RoutePolicy, Router, ServePhase};
pub use server::HttpServer;
pub use service::{FaultPolicy, HexGenService, ServiceConfig, ServiceStats};
pub use speculative::{SpecPolicy, SpecStats, SpeculativeSession};

// Convenience: the KV sizing policy lives with the block pool in
// `runtime::kvcache`, and the fault-injection plan with its backend
// wrapper in `runtime::faults`, but service configurations are
// assembled from this layer — re-export both next to `ServiceConfig`.
pub use crate::runtime::{FaultPlan, KvPolicy};
