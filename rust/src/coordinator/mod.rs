//! The HexGen coordinator (Layer 3): request routing, continuous
//! (iteration-level) batching, leader-side collectives, and the
//! asymmetric TP×PP pipeline executor — the real serving path (paper
//! §3.2, Appendix C). Python never runs
//! here; the executors run stage artifacts through a pluggable
//! [`crate::runtime::ExecutionBackend`] (pure-Rust reference by default,
//! PJRT behind the `pjrt` feature).

pub mod batcher;
pub mod collective;
pub mod lowering;
pub mod pipeline;
pub mod router;
pub mod service;

pub use batcher::{AdmissionQueue, BatchPolicy};
pub use collective::{add_residual, all_reduce_sum, CommStats};
pub use lowering::{lower_plan, LoweredPlan};
pub use pipeline::{
    argmax_rows, plan_from_strategy, DecodeSession, GenerationResult, PipelineExecutor,
    SlotRequest, StagePlan,
};
pub use router::{RoutePolicy, Router};
pub use service::{collect_all, Completion, HexGenService, ServiceConfig};
