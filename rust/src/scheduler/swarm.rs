//! Petals-style swarm-parallelism baseline (§5.3, Figure 3).
//!
//! Petals assigns each server (GPU) a contiguous block of layers sized to
//! its memory and routes each request through a dynamically chosen chain
//! of servers covering all layers — with **no static schedule**: chains
//! are formed by availability, not by the communication topology, and
//! there is no tensor parallelism. We reproduce the *policy*: TP=1 stages,
//! layer blocks proportional to device memory, chains stitched in device
//! order shuffled by the join order of a decentralized swarm (seeded),
//! i.e. oblivious to region boundaries.

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::CostModel;
use crate::model::ModelSpec;
use crate::parallelism::{Deployment, Pipeline, Stage};
use crate::util::rng::Xoshiro256pp;

/// Build the swarm deployment: devices join in random order; each takes as
/// many remaining layers of the current replica chain as its memory
/// allows (with a KV/activation reserve); when a chain reaches `L`
/// layers, a new chain starts. Incomplete trailing chains are dropped.
pub fn swarm_deployment(
    cluster: &Cluster,
    model: &ModelSpec,
    seed: u64,
) -> Deployment {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut devices: Vec<DeviceId> = cluster.online_devices();
    rng.shuffle(&mut devices);

    let per_layer_bytes = model.params_per_layer() * model.btype();
    // Petals reserves room for attention caches; use 70% of memory for
    // weights, matching its default block auto-sizing spirit.
    let usable = 0.7;

    let mut pipelines = Vec::new();
    let mut current: Vec<Stage> = Vec::new();
    let mut remaining = model.layers;
    for d in devices {
        if remaining == 0 {
            pipelines.push(Pipeline { stages: std::mem::take(&mut current) });
            remaining = model.layers;
        }
        let mem = cluster.devices[d].gpu.spec().memory_bytes * usable;
        let fit = (mem / per_layer_bytes).floor() as usize;
        if fit == 0 {
            continue; // device too small to host even one block
        }
        let take = fit.min(remaining);
        current.push(Stage { devices: vec![d], layers: take });
        remaining -= take;
    }
    if remaining == 0 && !current.is_empty() {
        pipelines.push(Pipeline { stages: current });
    }
    Deployment { pipelines }
}

/// Swarm chains have no planner: re-forming after churn is just re-running
/// [`swarm_deployment`] with a new seed.
pub fn validate_swarm(
    cluster: &Cluster,
    model: &ModelSpec,
    cm: &CostModel,
    deployment: &Deployment,
) -> Result<(), String> {
    deployment.validate(cluster, model)?;
    let t = crate::costmodel::InferenceTask::new(1, 64, 32);
    deployment.validate_memory(cm, &t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    #[test]
    fn swarm_covers_layers_with_tp1_stages() {
        let c = cluster::heterogeneous_half_price();
        let m = ModelSpec::llama2_70b();
        let d = swarm_deployment(&c, &m, 42);
        assert!(!d.pipelines.is_empty());
        for p in &d.pipelines {
            assert_eq!(p.total_layers(), 80);
            assert!(p.stages.iter().all(|s| s.tp_degree() == 1));
        }
        let cm = CostModel::new(&c, &m);
        validate_swarm(&c, &m, &cm, &d).unwrap();
    }

    #[test]
    fn swarm_chains_ignore_regions() {
        // With 3 regions and shuffled join order, at least one chain should
        // straddle regions (that's the point of the baseline).
        let c = cluster::heterogeneous_half_price();
        let m = ModelSpec::llama2_70b();
        let d = swarm_deployment(&c, &m, 7);
        let straddles = d.pipelines.iter().any(|p| {
            let r0 = c.devices[p.devices()[0]].region;
            p.devices().iter().any(|&dd| c.devices[dd].region != r0)
        });
        assert!(straddles);
    }

    #[test]
    fn swarm_is_deterministic_per_seed() {
        let c = cluster::heterogeneous_half_price();
        let m = ModelSpec::llama2_70b();
        assert_eq!(swarm_deployment(&c, &m, 3), swarm_deployment(&c, &m, 3));
        assert_ne!(swarm_deployment(&c, &m, 3), swarm_deployment(&c, &m, 4));
    }

    #[test]
    fn small_pool_yields_no_chain() {
        // 2×A4000 cannot host 80 layers
        let c = cluster::case_study();
        let mut c2 = c.clone();
        c2.take_offline(&(0..6).collect::<Vec<_>>());
        let m = ModelSpec::llama2_70b();
        let d = swarm_deployment(&c2, &m, 1);
        assert!(d.pipelines.is_empty());
    }
}
