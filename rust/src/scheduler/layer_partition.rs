//! Pipeline layer-partition heuristic (paper §4.3, "Determine the
//! pipeline partitions").
//!
//! For a fresh offspring the partition starts even (`l_ij = L/S_i`); after
//! a DP pass bound the stages to concrete device sets, the partition is
//! adjusted proportionally to each stage's total device memory — an
//! expectation-maximization-style alternation with Algorithm 1.

/// Even partition of `total_layers` into `stages` parts (remainder spread
/// over the leading stages).
pub fn even_partition(total_layers: usize, stages: usize) -> Vec<usize> {
    assert!(stages > 0 && stages <= total_layers);
    let base = total_layers / stages;
    let rem = total_layers % stages;
    (0..stages)
        .map(|j| base + usize::from(j < rem))
        .collect()
}

/// Redistribute layers proportionally to per-stage memory capacity
/// (bytes). Every stage keeps at least one layer and the result sums to
/// `total_layers`. Uses largest-remainder apportionment for determinism.
pub fn memory_proportional_partition(total_layers: usize, stage_memory: &[f64]) -> Vec<usize> {
    let stages = stage_memory.len();
    assert!(stages > 0 && stages <= total_layers);
    let total_mem: f64 = stage_memory.iter().sum();
    assert!(total_mem > 0.0);

    // Reserve 1 layer per stage, apportion the rest by memory share.
    let free = total_layers - stages;
    let quotas: Vec<f64> = stage_memory
        .iter()
        .map(|m| free as f64 * m / total_mem)
        .collect();
    let mut out: Vec<usize> = quotas.iter().map(|q| 1 + q.floor() as usize).collect();
    let mut assigned: usize = out.iter().sum();

    // Largest remainders get the leftover layers.
    let mut rema: Vec<(usize, f64)> = quotas
        .iter()
        .enumerate()
        .map(|(i, q)| (i, q - q.floor()))
        .collect();
    rema.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut k = 0;
    while assigned < total_layers {
        out[rema[k % stages].0] += 1;
        assigned += 1;
        k += 1;
    }
    debug_assert_eq!(out.iter().sum::<usize>(), total_layers);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_partition_sums_and_balances() {
        assert_eq!(even_partition(80, 3), vec![27, 27, 26]);
        assert_eq!(even_partition(80, 8), vec![10; 8]);
        assert_eq!(even_partition(7, 7), vec![1; 7]);
        for s in 1..=10 {
            let p = even_partition(80, s);
            assert_eq!(p.iter().sum::<usize>(), 80);
            assert!(p.iter().max().unwrap() - p.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn memory_proportional_tracks_capacity() {
        // case study: 4×48G, 2×24G, 2×16G → 192G/48G/32G per stage
        let p = memory_proportional_partition(80, &[192e9, 48e9, 32e9]);
        assert_eq!(p.iter().sum::<usize>(), 80);
        // close to the paper's 48/20/12 hand layout
        assert!(p[0] >= 52 && p[0] <= 60, "{p:?}");
        assert!(p[1] >= 12 && p[1] <= 18, "{p:?}");
        assert!(p[2] >= 8 && p[2] <= 12, "{p:?}");
        assert!(p[0] > p[1] && p[1] > p[2]);
    }

    #[test]
    fn minimum_one_layer_per_stage() {
        let p = memory_proportional_partition(4, &[1e12, 1.0, 1.0, 1.0]);
        assert_eq!(p, vec![1, 1, 1, 1]);
    }

    #[test]
    fn proportional_is_deterministic() {
        let m = [3.0, 2.0, 2.0, 1.0];
        assert_eq!(
            memory_proportional_partition(13, &m),
            memory_proportional_partition(13, &m)
        );
    }

    #[test]
    fn equal_memory_gives_even() {
        let p = memory_proportional_partition(80, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(p, vec![20, 20, 20, 20]);
    }
}
