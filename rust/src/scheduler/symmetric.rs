//! Symmetric-only pipeline planning: the constraint the paper ablates
//! (§5.2) and the homogeneous baselines (FlashAttention serving, HF-TGI)
//! operate under — every pipeline stage has the *same* TP degree and the
//! *same* number of layers.

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::parallelism::{Pipeline, Stage};

use super::dp::{DpResult, GroupPool};
use super::layer_partition::even_partition;

/// Best symmetric plan for a device group: enumerate (stages S, tp) with
/// S·tp ≤ |group|, tp | heads, even layer split, machine-major binding;
/// pick the feasible plan with minimal Eq. 2 cost.
pub fn symmetric_pipeline(
    cm: &CostModel,
    cluster: &Cluster,
    devices: &[DeviceId],
    task: &InferenceTask,
    max_stages: usize,
    max_tp: usize,
) -> Option<DpResult> {
    let pool = GroupPool::new(cluster, devices);
    let n = pool.total();
    let l = cm.model.layers;
    let mut best: Option<DpResult> = None;
    for s in 1..=max_stages.min(n).min(l) {
        for tp in 1..=max_tp.min(n / s) {
            if cm.model.heads % tp != 0 {
                continue;
            }
            // Bind S stages of `tp` GPUs each from the per-type
            // machine-major orders; symmetric systems also require one GPU
            // type per TP group, so stages consume types greedily.
            let mut stages: Vec<Stage> = Vec::with_capacity(s);
            let mut used = [0usize; crate::parallelism::group::NUM_TYPES];
            let partition = even_partition(l, s);
            let mut ok = true;
            for layers in partition.iter().take(s) {
                // next type with enough remaining GPUs
                let mut bound: Option<Vec<DeviceId>> = None;
                for k in 0..crate::parallelism::group::NUM_TYPES {
                    if pool.caps[k] - used[k] >= tp {
                        bound = Some(pool.bind(k, used[k], tp).to_vec());
                        used[k] += tp;
                        break;
                    }
                }
                match bound {
                    Some(devs) => stages.push(Stage { devices: devs, layers: *layers }),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
            let pipeline = Pipeline { stages };
            let Some(exact) = pipeline.cost(cm, task, Phase::Both) else {
                continue; // memory violation somewhere
            };
            let better = best.as_ref().map(|b| exact < b.exact_cost).unwrap_or(true);
            if better {
                best = Some(DpResult { pipeline, dp_cost: exact, exact_cost: exact });
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::model::ModelSpec;
    use crate::scheduler::dp::optimal_pipeline;

    #[test]
    fn symmetric_plans_are_symmetric() {
        let c = cluster::homogeneous_a100();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        let res = symmetric_pipeline(&cm, &c, &(0..8).collect::<Vec<_>>(), &t, 8, 8).unwrap();
        let tp0 = res.pipeline.stages[0].tp_degree();
        assert!(res.pipeline.stages.iter().all(|s| s.tp_degree() == tp0));
        let layers: Vec<usize> = res.pipeline.stages.iter().map(|s| s.layers).collect();
        assert!(layers.iter().max().unwrap() - layers.iter().min().unwrap() <= 1);
    }

    #[test]
    fn symmetric_never_beats_asymmetric() {
        // The asymmetric DP searches a superset of the symmetric space, so
        // its optimum is at least as good on every pool.
        let m = ModelSpec::llama2_70b();
        let t = InferenceTask::case_study();
        for c in [cluster::case_study(), cluster::homogeneous_a100()] {
            let cm = CostModel::new(&c, &m);
            let devs: Vec<DeviceId> = (0..8).collect();
            let sym = symmetric_pipeline(&cm, &c, &devs, &t, 8, 8);
            let asym = optimal_pipeline(&cm, &c, &devs, &t, 8, 8);
            if let (Some(s), Some(a)) = (&sym, &asym) {
                assert!(
                    a.exact_cost <= s.exact_cost * 1.0001,
                    "{}: asym {} vs sym {}",
                    c.name,
                    a.exact_cost,
                    s.exact_cost
                );
            } else {
                assert!(sym.is_none(), "sym feasible where asym infeasible");
            }
        }
    }

    #[test]
    fn symmetric_ooms_on_case_study_where_asymmetric_fits() {
        // §3.1: the symmetric planner cannot fit the model over the whole
        // mixed pool at every (S, tp) that uses the A4000s evenly... it may
        // still find a plan ignoring the weak GPUs; what it must NOT find
        // is any plan better than the asymmetric one.
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let sym = symmetric_pipeline(&cm, &c, &(0..8).collect::<Vec<_>>(), &t, 8, 8);
        let asym = optimal_pipeline(&cm, &c, &(0..8).collect::<Vec<_>>(), &t, 8, 8).unwrap();
        if let Some(s) = sym {
            assert!(asym.exact_cost <= s.exact_cost * 1.0001);
        }
    }
}
