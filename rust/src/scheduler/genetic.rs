//! Genetic search for the global device partition (paper §4.3).
//!
//! An individual is a partition of the online device pool into candidate
//! pipeline groups. Each group is planned by the Algorithm-1 DP
//! ([`super::dp::optimal_pipeline`]); groups that cannot hold a model
//! replica contribute no pipeline (their GPUs idle). Fitness is the
//! estimated SLO attainment of the resulting deployment on a sampled
//! workload — the paper estimates expected SLO with AlpaServe's simulator;
//! we use our discrete-event engine the same way.
//!
//! Mutations are the paper's *merge*, *split* and *swap* with the
//! hold-a-replica early check; `MutationMode::Random` replaces them with
//! unguided single-device moves (the Figure-6 strawman).

use std::collections::HashMap;
use std::time::Instant;

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::{CostModel, InferenceTask};
use crate::model::ModelSpec;
use crate::parallelism::Deployment;
use crate::simulator::{simulate, SimConfig, SloModel};
use crate::util::rng::Xoshiro256pp;
use crate::workload::{LengthDist, Request, WorkloadSpec};

use super::dp::{optimal_pipeline_opt, DpResult};
use super::kmeans::initial_groups;
use super::planner::PipelinePlanner;

/// Mutation strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MutationMode {
    /// Paper §4.3: merge / split / swap with early feasibility pruning.
    Guided,
    /// Strawman: unguided random single-device moves (Figure 6 baseline).
    Random,
}

/// GA configuration.
#[derive(Debug, Clone)]
pub struct GaConfig {
    pub population: usize,
    pub iterations: usize,
    /// Stop after this many iterations without improvement.
    pub patience: usize,
    pub seed: u64,
    pub max_stages: usize,
    pub max_tp: usize,
    pub mutation: MutationMode,
    /// Workload used for fitness estimation.
    pub fitness_rate: f64,
    pub fitness_requests: usize,
    pub s_out: usize,
    /// SLO scale at which attainment is estimated.
    pub slo_scale: f64,
    /// Pipeline planning flavor (asymmetric HexGen vs symmetric ablation).
    pub planner: PipelinePlanner,
    pub sim: SimConfig,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 16,
            iterations: 60,
            patience: 15,
            seed: 0x4E58_6E47, // "HexGn"
            max_stages: 8,
            max_tp: 8,
            mutation: MutationMode::Guided,
            fitness_rate: 2.0,
            fitness_requests: 200,
            s_out: 32,
            slo_scale: 5.0,
            planner: PipelinePlanner::Asymmetric,
            sim: SimConfig::default(),
        }
    }
}

/// One step of the convergence history.
#[derive(Debug, Clone, Copy)]
pub struct HistoryPoint {
    pub iteration: usize,
    pub wall_time: f64,
    pub best_fitness: f64,
}

/// Search result.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub deployment: Deployment,
    pub fitness: f64,
    pub history: Vec<HistoryPoint>,
    pub iterations_run: usize,
    pub wall_time: f64,
    /// The k-means-initialized individual's fitness (Figure 7's
    /// "random init" bar).
    pub init_fitness: f64,
}

type Partition = Vec<Vec<DeviceId>>;

/// The genetic scheduler.
pub struct GeneticScheduler<'a> {
    cluster: &'a Cluster,
    model: &'a ModelSpec,
    cfg: GaConfig,
    /// Fitness traces at the configured rate and at 4× (the high-pressure
    /// trace keeps the objective from saturating at 1.0 once every plan
    /// meets the SLO at the base rate — resilience to peak rate is half
    /// of the paper's headline metric).
    traces: [Vec<Request>; 2],
    slo: SloModel,
    /// Memoized per-group DP plans keyed by sorted device ids.
    plan_cache: HashMap<Vec<DeviceId>, Option<DpResult>>,
    /// Memoized fitness keyed by canonical partition signature.
    fitness_cache: HashMap<String, f64>,
    /// Representative planning task for the DP objective.
    plan_task: InferenceTask,
}

impl<'a> GeneticScheduler<'a> {
    pub fn new(cluster: &'a Cluster, model: &'a ModelSpec, cfg: GaConfig) -> Self {
        let mk_trace = |rate: f64, salt: u64| {
            WorkloadSpec {
                rate,
                num_requests: cfg.fitness_requests,
                lengths: LengthDist::LmsysLike { s_out: cfg.s_out },
                seed: cfg.seed ^ salt,
            }
            .generate()
        };
        let traces = [
            mk_trace(cfg.fitness_rate, 0x57_AC_E0),
            mk_trace(cfg.fitness_rate * 6.0, 0x57_AC_E1),
        ];
        let plan_task = InferenceTask::new(1, 64, cfg.s_out);
        GeneticScheduler {
            cluster,
            model,
            cfg,
            traces,
            slo: SloModel::new(model),
            plan_cache: HashMap::new(),
            fitness_cache: HashMap::new(),
            plan_task,
        }
    }

    /// Plan one group with the configured planner (memoized).
    fn plan_group(&mut self, group: &[DeviceId]) -> Option<DpResult> {
        let mut key = group.to_vec();
        key.sort_unstable();
        if let Some(hit) = self.plan_cache.get(&key) {
            return hit.clone();
        }
        let cm = CostModel::new(self.cluster, self.model);
        let res = match self.cfg.planner {
            PipelinePlanner::Asymmetric => optimal_pipeline_opt(
                &cm,
                self.cluster,
                group,
                &self.plan_task,
                self.cfg.max_stages,
                self.cfg.max_tp,
                false,
            ),
            PipelinePlanner::Symmetric => super::symmetric::symmetric_pipeline(
                &cm,
                self.cluster,
                group,
                &self.plan_task,
                self.cfg.max_stages,
                self.cfg.max_tp,
            ),
        };
        self.plan_cache.insert(key, res.clone());
        res
    }

    /// Build the deployment a partition induces (feasible groups only).
    pub fn deployment_of(&mut self, partition: &Partition) -> Deployment {
        let mut pipelines = Vec::new();
        for g in partition {
            if g.is_empty() {
                continue;
            }
            if let Some(res) = self.plan_group(g) {
                pipelines.push(res.pipeline);
            }
        }
        Deployment { pipelines }
    }

    /// Estimated SLO attainment of a partition (memoized).
    pub fn fitness_of(&mut self, partition: &Partition) -> f64 {
        let sig = signature(partition);
        if let Some(&f) = self.fitness_cache.get(&sig) {
            return f;
        }
        let deployment = self.deployment_of(partition);
        let f = if deployment.pipelines.is_empty() {
            0.0
        } else {
            let cm = CostModel::new(self.cluster, self.model);
            // Mean attainment over the base-rate and high-pressure traces
            // (both at the configured SLO scale): the high-rate trace
            // keeps discriminating by *capacity* once every plan meets
            // the SLO at the base rate.
            let mut att = 0.0;
            let mut mean_norm = 0.0;
            for trace in self.traces.iter() {
                let out = simulate(&cm, &deployment, trace, &self.cfg.sim);
                att += out.attainment(&self.slo, self.cfg.slo_scale);
                // Secondary objective: prefer lower normalized latency
                // among equal-attainment plans (breaks plateaus at 0/1).
                let mut s = 0.0;
                let mut n = 0;
                for r in &out.records {
                    if r.latency.is_finite() {
                        s += r.latency / self.slo.reference_latency(&r.task);
                        n += 1;
                    }
                }
                mean_norm += if n == 0 { 1e9 } else { s / n as f64 };
            }
            att /= self.traces.len() as f64;
            mean_norm /= self.traces.len() as f64;
            att + 1e-3 / (1.0 + mean_norm)
        };
        self.fitness_cache.insert(sig, f);
        f
    }

    /// Run the search.
    pub fn run(&mut self) -> GaResult {
        let start = Instant::now();
        let mut rng = Xoshiro256pp::seed_from_u64(self.cfg.seed);
        let devices = self.cluster.online_devices();
        assert!(!devices.is_empty(), "empty device pool");

        // §4.3 initialization: k-means over the comm matrix, then greedy
        // capacity splits — the paper's scheduler "aims to maximize device
        // memory utilization by incorporating as many model replicas as
        // possible" (§5.2), so the population starts from groups just big
        // enough to hold one replica instead of whole-region blobs.
        let seed_partition = normalize(self.saturate_splits(initial_groups(
            self.cluster,
            &devices,
            &mut rng,
        )));
        let init_fitness = self.fitness_of(&seed_partition);

        let mut population: Vec<(Partition, f64)> = vec![(seed_partition.clone(), init_fitness)];
        while population.len() < self.cfg.population {
            let mut p = seed_partition.clone();
            // Diversify with a few random (guided) mutations.
            for _ in 0..1 + rng.gen_range(3) {
                if let Some(q) = self.mutate(&p, &mut rng) {
                    p = q;
                }
            }
            let f = self.fitness_of(&p);
            population.push((p, f));
        }

        let mut history = Vec::new();
        let mut best = population
            .iter()
            .cloned()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        history.push(HistoryPoint {
            iteration: 0,
            wall_time: start.elapsed().as_secs_f64(),
            best_fitness: best.1,
        });

        let mut stale = 0usize;
        let mut iterations_run = 0usize;
        for iter in 1..=self.cfg.iterations {
            iterations_run = iter;
            // Generate offspring: one per population slot, tournament parent.
            let mut offspring: Vec<(Partition, f64)> = Vec::with_capacity(self.cfg.population);
            for _ in 0..self.cfg.population {
                let parent = tournament(&population, &mut rng);
                let mut child = parent.clone();
                let n_mut = 1 + rng.gen_range(2);
                let mut changed = false;
                for _ in 0..n_mut {
                    if let Some(c) = self.mutate(&child, &mut rng) {
                        child = c;
                        changed = true;
                    }
                }
                if !changed {
                    continue;
                }
                let f = self.fitness_of(&child);
                offspring.push((child, f));
            }
            // Elitist truncation selection.
            population.extend(offspring);
            population.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            population.dedup_by(|a, b| signature(&a.0) == signature(&b.0));
            population.truncate(self.cfg.population);

            let iter_best = population[0].clone();
            if iter_best.1 > best.1 + 1e-12 {
                best = iter_best;
                stale = 0;
            } else {
                stale += 1;
            }
            history.push(HistoryPoint {
                iteration: iter,
                wall_time: start.elapsed().as_secs_f64(),
                best_fitness: best.1,
            });
            if stale >= self.cfg.patience {
                break;
            }
        }

        let deployment = self.deployment_of(&best.0);
        GaResult {
            deployment,
            fitness: best.1,
            history,
            iterations_run,
            wall_time: start.elapsed().as_secs_f64(),
            init_fitness,
        }
    }

    /// Greedily split groups (per-type even splits) while both halves can
    /// still hold a full model replica — the §4.3 split mutation applied
    /// to saturation at initialization time.
    fn saturate_splits(&self, groups: Partition) -> Partition {
        let param_bytes = self.model.param_bytes();
        let holds = |g: &Vec<DeviceId>| -> bool {
            g.iter()
                .map(|&d| self.cluster.devices[d].gpu.spec().memory_bytes)
                .sum::<f64>()
                >= param_bytes
        };
        let mut out: Partition = Vec::new();
        let mut work = groups;
        while let Some(g) = work.pop() {
            if g.len() >= 2 {
                let (a, b) = split_group(self.cluster, &g);
                if !a.is_empty() && !b.is_empty() && holds(&a) && holds(&b) {
                    work.push(a);
                    work.push(b);
                    continue;
                }
            }
            out.push(g);
        }
        out
    }

    /// Apply one mutation; `None` if the draw was inapplicable or pruned.
    fn mutate(&mut self, p: &Partition, rng: &mut Xoshiro256pp) -> Option<Partition> {
        match self.cfg.mutation {
            MutationMode::Guided => self.mutate_guided(p, rng),
            MutationMode::Random => mutate_random(p, rng),
        }
    }

    fn mutate_guided(&mut self, p: &Partition, rng: &mut Xoshiro256pp) -> Option<Partition> {
        let param_bytes = self.model.param_bytes();
        let holds = |g: &Vec<DeviceId>| -> bool {
            g.iter()
                .map(|&d| self.cluster.devices[d].gpu.spec().memory_bytes)
                .sum::<f64>()
                >= param_bytes
        };
        match rng.gen_range(3) {
            // Merge two groups.
            0 => {
                if p.len() < 2 {
                    return None;
                }
                let i = rng.gen_range(p.len());
                let mut j = rng.gen_range(p.len() - 1);
                if j >= i {
                    j += 1;
                }
                let mut q: Partition = Vec::with_capacity(p.len() - 1);
                let mut merged = p[i].clone();
                merged.extend_from_slice(&p[j]);
                merged.sort_unstable();
                for (k, g) in p.iter().enumerate() {
                    if k != i && k != j {
                        q.push(g.clone());
                    }
                }
                q.push(merged);
                Some(normalize(q))
            }
            // Split one group evenly per type (machine-major halves).
            1 => {
                let candidates: Vec<usize> =
                    (0..p.len()).filter(|&i| p[i].len() >= 2).collect();
                let &i = rng.choose(&candidates)?;
                let (a, b) = split_group(self.cluster, &p[i]);
                // Early check (§4.3): both halves must hold a replica.
                if !holds(&a) || !holds(&b) {
                    return None;
                }
                let mut q: Partition = Vec::with_capacity(p.len() + 1);
                for (k, g) in p.iter().enumerate() {
                    if k != i {
                        q.push(g.clone());
                    }
                }
                q.push(a);
                q.push(b);
                Some(normalize(q))
            }
            // Swap: move one GPU from one group to another.
            _ => {
                if p.len() < 2 {
                    return None;
                }
                let donors: Vec<usize> = (0..p.len()).filter(|&i| p[i].len() >= 2).collect();
                let &i = rng.choose(&donors)?;
                let mut j = rng.gen_range(p.len() - 1);
                if j >= i {
                    j += 1;
                }
                let mut q = p.clone();
                let di = rng.gen_range(q[i].len());
                let dev = q[i].remove(di);
                // Early check: donor should still hold a replica if it did.
                if holds(&p[i]) && !holds(&q[i]) {
                    return None;
                }
                q[j].push(dev);
                q[j].sort_unstable();
                Some(normalize(q))
            }
        }
    }
}

/// Unguided baseline: move a random device to a random group (possibly a
/// new singleton). No feasibility pruning, no structured merge/split.
fn mutate_random(p: &Partition, rng: &mut Xoshiro256pp) -> Option<Partition> {
    let total: usize = p.iter().map(|g| g.len()).sum();
    if total < 2 {
        return None;
    }
    let mut q = p.clone();
    let gi = rng.gen_range(q.len());
    if q[gi].is_empty() {
        return None;
    }
    let di = rng.gen_range(q[gi].len());
    let dev = q[gi].remove(di);
    let target = rng.gen_range(q.len() + 1);
    if target == q.len() {
        q.push(vec![dev]);
    } else {
        q[target].push(dev);
        q[target].sort_unstable();
    }
    Some(normalize(q))
}

/// Drop empty groups and order deterministically (canonical form).
fn normalize(mut p: Partition) -> Partition {
    for g in p.iter_mut() {
        g.sort_unstable();
    }
    p.retain(|g| !g.is_empty());
    p.sort();
    p
}

fn signature(p: &Partition) -> String {
    let mut s = String::new();
    for g in p {
        for d in g {
            s.push_str(&d.to_string());
            s.push(',');
        }
        s.push(';');
    }
    s
}

/// Split a group per GPU type, machine-major (keeps machines intact where
/// possible) — the τ-vector *split* of §4.3 bound to concrete devices.
fn split_group(cluster: &Cluster, g: &[DeviceId]) -> (Vec<DeviceId>, Vec<DeviceId>) {
    use std::collections::BTreeMap;
    let mut by_type: BTreeMap<usize, Vec<DeviceId>> = BTreeMap::new();
    for &d in g {
        by_type.entry(cluster.devices[d].gpu.index()).or_default().push(d);
    }
    let mut a = Vec::new();
    let mut b = Vec::new();
    for (_, mut devs) in by_type {
        // machine-major ordering so halves align with machines
        devs.sort_by_key(|&d| (cluster.devices[d].machine, d));
        let half = devs.len() / 2;
        a.extend_from_slice(&devs[..half]);
        b.extend_from_slice(&devs[half..]);
    }
    (a, b)
}

fn tournament<'p>(
    population: &'p [(Partition, f64)],
    rng: &mut Xoshiro256pp,
) -> &'p Partition {
    let i = rng.gen_range(population.len());
    let j = rng.gen_range(population.len());
    if population[i].1 >= population[j].1 {
        &population[i].0
    } else {
        &population[j].0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::util::prop::{prop_assert, prop_check};

    fn quick_cfg(seed: u64) -> GaConfig {
        GaConfig {
            population: 6,
            iterations: 8,
            patience: 5,
            seed,
            fitness_requests: 60,
            fitness_rate: 0.5,
            ..GaConfig::default()
        }
    }

    #[test]
    fn ga_finds_feasible_deployment_half_price() {
        let c = cluster::heterogeneous_half_price();
        let m = ModelSpec::llama2_70b();
        let mut ga = GeneticScheduler::new(&c, &m, quick_cfg(1));
        let res = ga.run();
        assert!(!res.deployment.pipelines.is_empty());
        res.deployment.validate(&c, &m).unwrap();
        assert!(res.fitness > 0.0);
        assert!(res.fitness >= res.init_fitness - 1e-9);
        // history monotone non-decreasing
        assert!(res
            .history
            .windows(2)
            .all(|w| w[1].best_fitness >= w[0].best_fitness - 1e-12));
    }

    #[test]
    fn guided_mutations_preserve_device_multiset() {
        let c = cluster::heterogeneous_half_price();
        let m = ModelSpec::llama2_70b();
        prop_check(60, 0xBEEF, |rng| {
            let mut ga = GeneticScheduler::new(&c, &m, quick_cfg(rng.next_u64()));
            let devices = c.online_devices();
            let mut p = normalize(initial_groups(&c, &devices, rng));
            for _ in 0..10 {
                if let Some(q) = ga.mutate(&p, rng) {
                    p = q;
                }
            }
            let mut all: Vec<DeviceId> = p.concat();
            all.sort_unstable();
            prop_assert(all == devices, format!("multiset changed: {all:?}"))
        });
    }

    #[test]
    fn random_mutations_preserve_device_multiset() {
        let c = cluster::heterogeneous_half_price();
        prop_check(60, 0xF00D, |rng| {
            let devices = c.online_devices();
            let mut p = normalize(initial_groups(&c, &devices, rng));
            for _ in 0..10 {
                if let Some(q) = mutate_random(&p, rng) {
                    p = q;
                }
            }
            let mut all: Vec<DeviceId> = p.concat();
            all.sort_unstable();
            prop_assert(all == devices, format!("multiset changed: {all:?}"))
        });
    }

    #[test]
    fn split_group_halves_types() {
        let c = cluster::heterogeneous_half_price();
        // Iceland machine 0+1: 16×3090Ti
        let g: Vec<DeviceId> = (0..16).collect();
        let (a, b) = split_group(&c, &g);
        assert_eq!(a.len(), 8);
        assert_eq!(b.len(), 8);
        // halves are machine-aligned
        let ma = c.devices[a[0]].machine;
        assert!(a.iter().all(|&d| c.devices[d].machine == ma));
    }

    #[test]
    fn guided_beats_or_ties_random_on_half_price() {
        let c = cluster::heterogeneous_half_price();
        let m = ModelSpec::llama2_70b();
        let mut g_cfg = quick_cfg(7);
        g_cfg.iterations = 12;
        let mut r_cfg = g_cfg.clone();
        r_cfg.mutation = MutationMode::Random;
        let gf = GeneticScheduler::new(&c, &m, g_cfg).run().fitness;
        let rf = GeneticScheduler::new(&c, &m, r_cfg).run().fitness;
        assert!(gf >= rf - 0.02, "guided {gf} vs random {rf}");
    }

    #[test]
    fn deployment_uses_only_online_devices() {
        let mut c = cluster::heterogeneous_half_price();
        c.take_offline(&[0, 1, 2, 3]);
        let m = ModelSpec::llama2_70b();
        let mut ga = GeneticScheduler::new(&c, &m, quick_cfg(3));
        let res = ga.run();
        res.deployment.validate(&c, &m).unwrap();
        for d in res.deployment.devices() {
            assert!(c.devices[d].online);
        }
    }
}
