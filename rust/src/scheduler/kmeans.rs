//! K-means over the communication matrix + elbow method (paper §4.3,
//! "Initialization").
//!
//! Devices are embedded by their row of the bandwidth matrix (log-scale,
//! since link classes span five orders of magnitude); k-means then groups
//! devices with similar connectivity — i.e. it discovers machines/regions
//! — and the elbow method picks the number of initial pipeline groups M.

use crate::cluster::{Cluster, DeviceId};
use crate::util::rng::Xoshiro256pp;

/// Embed device `d` as its log-bandwidth row (plus log-latency row) to all
/// other devices.
fn embed(cluster: &Cluster, devices: &[DeviceId]) -> Vec<Vec<f64>> {
    devices
        .iter()
        .map(|&d| {
            let mut row: Vec<f64> = Vec::with_capacity(devices.len() * 2);
            for &d2 in devices {
                if d == d2 {
                    row.push(0.0);
                    row.push(0.0);
                } else {
                    row.push(cluster.comm.beta(d, d2).log10());
                    row.push(-(cluster.comm.alpha(d, d2).log10()));
                }
            }
            row
        })
        .collect()
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Standard Lloyd's k-means. Returns (assignment per device, inertia).
pub fn kmeans(points: &[Vec<f64>], k: usize, rng: &mut Xoshiro256pp) -> (Vec<usize>, f64) {
    let n = points.len();
    assert!(k >= 1 && k <= n);
    let dim = points[0].len();

    // k-means++ seeding.
    let mut centers: Vec<Vec<f64>> = Vec::with_capacity(k);
    centers.push(points[rng.gen_range(n)].clone());
    while centers.len() < k {
        let d2: Vec<f64> = points
            .iter()
            .map(|p| {
                centers
                    .iter()
                    .map(|c| dist2(p, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            // all points identical to some center: pick arbitrary
            centers.push(points[rng.gen_range(n)].clone());
            continue;
        }
        let idx = rng.choose_weighted(&d2);
        centers.push(points[idx].clone());
    }

    let mut assign = vec![0usize; n];
    for _iter in 0..50 {
        // Assign.
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist2(p, &centers[a])
                        .partial_cmp(&dist2(p, &centers[b]))
                        .unwrap()
                })
                .unwrap();
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        // Update.
        let mut sums = vec![vec![0.0; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            counts[assign[i]] += 1;
            for (s, x) in sums[assign[i]].iter_mut().zip(p) {
                *s += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for s in sums[c].iter_mut() {
                    *s /= counts[c] as f64;
                }
                centers[c] = sums[c].clone();
            }
        }
        if !changed {
            break;
        }
    }
    let inertia: f64 = points
        .iter()
        .enumerate()
        .map(|(i, p)| dist2(p, &centers[assign[i]]))
        .sum();
    (assign, inertia)
}

/// Elbow method: run k-means for k = 1..=k_max, pick the k after which the
/// inertia improvement drops below `threshold` of the previous drop.
pub fn elbow_k(points: &[Vec<f64>], k_max: usize, rng: &mut Xoshiro256pp) -> usize {
    let k_max = k_max.min(points.len()).max(1);
    let mut inertias = Vec::with_capacity(k_max);
    for k in 1..=k_max {
        let (_, inertia) = kmeans(points, k, rng);
        inertias.push(inertia);
    }
    if inertias.len() == 1 {
        return 1;
    }
    // First k whose marginal improvement is < 15% of the k=1→2 drop.
    let first_drop = (inertias[0] - inertias[1]).max(1e-12);
    for k in 2..inertias.len() {
        let drop = inertias[k - 1] - inertias[k];
        if drop < 0.15 * first_drop {
            return k;
        }
    }
    inertias.len()
}

/// Communication-aware initial partition of the device pool into pipeline
/// groups (the GA's initial population seed).
pub fn initial_groups(
    cluster: &Cluster,
    devices: &[DeviceId],
    rng: &mut Xoshiro256pp,
) -> Vec<Vec<DeviceId>> {
    if devices.len() <= 1 {
        return vec![devices.to_vec()];
    }
    let points = embed(cluster, devices);
    let k = elbow_k(&points, devices.len().min(12), rng);
    let (assign, _) = kmeans(&points, k, rng);
    let mut groups: Vec<Vec<DeviceId>> = vec![Vec::new(); k];
    for (i, &d) in devices.iter().enumerate() {
        groups[assign[i]].push(d);
    }
    groups.retain(|g| !g.is_empty());
    // The initialization exists to "avoid using slow cross-region
    // communication links" (§4.3) — if the elbow under-segmented, split
    // any group spanning regions into per-region subgroups.
    let mut out: Vec<Vec<DeviceId>> = Vec::new();
    for g in groups {
        let mut by_region: std::collections::BTreeMap<usize, Vec<DeviceId>> =
            std::collections::BTreeMap::new();
        for d in g {
            by_region.entry(cluster.devices[d].region).or_default().push(d);
        }
        out.extend(by_region.into_values());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;

    #[test]
    fn kmeans_separates_obvious_clusters() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut points = Vec::new();
        for i in 0..10 {
            points.push(vec![0.0 + 0.01 * i as f64, 0.0]);
        }
        for i in 0..10 {
            points.push(vec![100.0 + 0.01 * i as f64, 100.0]);
        }
        let (assign, inertia) = kmeans(&points, 2, &mut rng);
        assert!(inertia < 1.0);
        let first = assign[0];
        assert!(assign[..10].iter().all(|&a| a == first));
        assert!(assign[10..].iter().all(|&a| a != first));
    }

    #[test]
    fn elbow_detects_two_blobs() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let mut points = Vec::new();
        for i in 0..12 {
            points.push(vec![(i % 3) as f64 * 0.01, 0.0]);
            points.push(vec![50.0 + (i % 3) as f64 * 0.01, 50.0]);
        }
        let k = elbow_k(&points, 8, &mut rng);
        assert!(k == 2 || k == 3, "k={k}");
    }

    #[test]
    fn initial_groups_respect_regions() {
        // half-price: Iceland (16), Norway (6), Nevada (8) — groups should
        // never mix regions (inter-region bandwidth is ~100× lower).
        let c = cluster::heterogeneous_half_price();
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let groups = initial_groups(&c, &c.online_devices(), &mut rng);
        assert!(groups.len() >= 2);
        for g in &groups {
            let r0 = c.devices[g[0]].region;
            assert!(
                g.iter().all(|&d| c.devices[d].region == r0),
                "group mixes regions: {g:?}"
            );
        }
        // every device appears exactly once
        let mut all: Vec<DeviceId> = groups.concat();
        all.sort_unstable();
        assert_eq!(all, c.online_devices());
    }

    #[test]
    fn single_device_pool() {
        let c = cluster::case_study();
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let groups = initial_groups(&c, &[3], &mut rng);
        assert_eq!(groups, vec![vec![3]]);
    }
}
