//! Two-phase scheduling over heterogeneity (paper §4): Algorithm-1 DP for
//! per-pipeline layouts, k-means/elbow initialization, and a genetic
//! algorithm (merge/split/swap) for the global partition; plus the
//! baseline policies the evaluation compares against (symmetric-only
//! ablation, Petals-style swarm).

pub mod dp;
pub mod genetic;
pub mod kmeans;
pub mod layer_partition;
pub mod planner;
pub mod swarm;
pub mod symmetric;

pub use dp::{optimal_pipeline, optimal_pipeline_opt, solve_dp, DpResult, GroupPool};
pub use genetic::{GaConfig, GaResult, GeneticScheduler, HistoryPoint, MutationMode};
pub use planner::PipelinePlanner;
pub use swarm::swarm_deployment;
pub use symmetric::symmetric_pipeline;
