//! Algorithm 1: dynamic programming over GPU-type-count vectors to find
//! the optimal stage layout of one pipeline (paper §4.2).
//!
//! The paper's heuristic — each TP group uses the *same GPU type*,
//! preferring the *same machine* — shrinks the per-stage choice from
//! `2^|d_i~|` subsets to `Σ_k #_k` homogeneous sets `τ_k·e_k`. We follow
//! it exactly and additionally make the τ → concrete-device *binding*
//! deterministic (devices of each type ordered machine-major, larger
//! machines first), so a memo state uniquely identifies a device set and
//! the transition can evaluate the exact Table-1 cost on real α/β links.
//!
//! After backtracking, [`optimal_pipeline`] re-evaluates the bound plan
//! with the exact Eq. 2 pipeline cost (the DP cost folds PP-comm along
//! the best-known path only, as the paper's transition does).

use crate::cluster::{Cluster, DeviceId};
use crate::costmodel::{CostModel, InferenceTask, Phase};
use crate::parallelism::group::{TypeVec, NUM_TYPES};
use crate::parallelism::{Pipeline, Stage};

use super::layer_partition::{even_partition, memory_proportional_partition};

/// Deterministic device ordering per type for τ→device binding.
#[derive(Debug, Clone)]
pub struct GroupPool {
    /// Device ids per GPU type, machine-major (machines with more GPUs of
    /// that type first), so a prefix of length `n` is the binding of
    /// `τ_k = n`.
    per_type: Vec<Vec<DeviceId>>,
    /// Type counts of the whole group (the DP capacity vector).
    pub caps: [usize; NUM_TYPES],
}

impl GroupPool {
    pub fn new(cluster: &Cluster, devices: &[DeviceId]) -> GroupPool {
        let mut per_type: Vec<Vec<DeviceId>> = vec![Vec::new(); NUM_TYPES];
        for &d in devices {
            assert!(cluster.devices[d].online, "offline device {d} in group");
            per_type[cluster.devices[d].gpu.index()].push(d);
        }
        // Machine-major order, larger machine chunks first: a TP stage
        // binding a prefix stays on one machine whenever it can.
        for k in 0..NUM_TYPES {
            let mut by_machine: std::collections::BTreeMap<usize, Vec<DeviceId>> =
                std::collections::BTreeMap::new();
            for &d in &per_type[k] {
                by_machine.entry(cluster.devices[d].machine).or_default().push(d);
            }
            let mut chunks: Vec<Vec<DeviceId>> = by_machine.into_values().collect();
            chunks.sort_by(|a, b| b.len().cmp(&a.len()).then(a[0].cmp(&b[0])));
            per_type[k] = chunks.into_iter().flatten().collect();
        }
        let mut caps = [0usize; NUM_TYPES];
        for (k, v) in per_type.iter().enumerate() {
            caps[k] = v.len();
        }
        GroupPool { per_type, caps }
    }

    pub fn total(&self) -> usize {
        self.caps.iter().sum()
    }

    /// Devices bound by taking `count` GPUs of type `k` starting at the
    /// used-offset `start`.
    pub fn bind(&self, k: usize, start: usize, count: usize) -> &[DeviceId] {
        &self.per_type[k][start..start + count]
    }

    pub fn type_vec(&self) -> TypeVec {
        TypeVec(self.caps)
    }
}

/// A stage choice recorded in the memo for backtracking.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Choice {
    /// GPU type index of this stage's TP group.
    k: usize,
    /// Number of GPUs taken.
    count: usize,
    /// Used-offset of type `k` *before* this stage (binding start).
    start: usize,
    /// Rank of the predecessor state at stage j-1.
    parent: usize,
}

/// Result of one DP solve: bound stages and costs.
#[derive(Debug, Clone)]
pub struct DpResult {
    /// The pipeline with concrete devices and the layer partition used.
    pub pipeline: Pipeline,
    /// DP objective (compute + TP comm + path PP comm), seconds.
    pub dp_cost: f64,
    /// Exact Eq. 2 cost of the bound pipeline, seconds.
    pub exact_cost: f64,
}

/// Solve Algorithm 1 for a fixed layer partition. Returns `None` when no
/// memory-feasible assignment exists.
///
/// TP degrees are restricted to divisors of the model's head count (the
/// implementation constraint behind the paper's `{1,2,4,8}` candidate-set
/// acceleration: Megatron-style head sharding needs `tp | heads`).
/// With `require_all`, every GPU in the pool must be assigned (the §3.1
/// case-study setting); otherwise leftover GPUs may idle.
pub fn solve_dp(
    cm: &CostModel,
    pool: &GroupPool,
    layer_partition: &[usize],
    task: &InferenceTask,
    max_tp: usize,
    require_all: bool,
) -> Option<DpResult> {
    let s_total = layer_partition.len();
    if s_total == 0 || pool.total() < s_total {
        return None;
    }
    let space = TypeVec::rank_space(&pool.caps);
    // dp[rank] = best cost reaching this used-vector after j stages.
    let mut prev = vec![f64::INFINITY; space];
    let mut prev_choice: Vec<Option<Choice>> = vec![None; space];
    let zero = TypeVec::zero();
    prev[zero.rank(&pool.caps)] = 0.0;
    let mut all_choices: Vec<Vec<Option<Choice>>> = Vec::with_capacity(s_total);

    // Enumerate reachable used-vectors stage by stage.
    let mut reachable: Vec<TypeVec> = vec![zero];
    for (j, &layers) in layer_partition.iter().enumerate() {
        let mut next = vec![f64::INFINITY; space];
        let mut next_choice: Vec<Option<Choice>> = vec![None; space];
        let mut next_reachable: Vec<TypeVec> = Vec::new();
        for used in &reachable {
            let ur = used.rank(&pool.caps);
            let base_cost = prev[ur];
            if !base_cost.is_finite() {
                continue;
            }
            // Previous stage's bound devices (for exact PP-comm on the
            // best-known path).
            let prev_devices: Option<Vec<DeviceId>> = prev_choice[ur].map(|c| {
                pool.bind(c.k, c.start, c.count).to_vec()
            });
            for k in 0..NUM_TYPES {
                let avail = pool.caps[k] - used.0[k];
                let cap = avail.min(max_tp);
                for count in 1..=cap {
                    if cm.model.heads % count != 0 {
                        continue; // head sharding requires tp | heads
                    }
                    let devices = pool.bind(k, used.0[k], count);
                    let Some(stage_cost) = cm.stage_cost(devices, layers, task, Phase::Both)
                    else {
                        continue; // memory violation ⇒ +inf
                    };
                    let pp_cost = match &prev_devices {
                        Some(pd) => cm.comm_pp_cost(pd, devices, task, Phase::Both),
                        None => 0.0,
                    };
                    let mut new_used = *used;
                    new_used.0[k] += count;
                    let nr = new_used.rank(&pool.caps);
                    let total = base_cost + stage_cost + pp_cost;
                    if total < next[nr] {
                        if !next[nr].is_finite() {
                            next_reachable.push(new_used);
                        }
                        next[nr] = total;
                        next_choice[nr] = Some(Choice {
                            k,
                            count,
                            start: used.0[k],
                            parent: ur,
                        });
                    }
                }
            }
        }
        if j + 1 < s_total && next_reachable.is_empty() {
            return None;
        }
        all_choices.push(next_choice.clone());
        prev = next;
        prev_choice = next_choice;
        reachable = next_reachable;
    }

    // Best terminal state (full consumption when `require_all`).
    let full = pool.type_vec();
    let (best_rank, best_cost) = reachable
        .iter()
        .filter(|v| !require_all || **v == full)
        .map(|v| {
            let r = v.rank(&pool.caps);
            (r, prev[r])
        })
        .filter(|(_, c)| c.is_finite())
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;

    // Backtrack.
    let mut stages_rev: Vec<Stage> = Vec::with_capacity(s_total);
    let mut rank = best_rank;
    for j in (0..s_total).rev() {
        let c = all_choices[j][rank].expect("backtrack hole");
        stages_rev.push(Stage {
            devices: pool.bind(c.k, c.start, c.count).to_vec(),
            layers: layer_partition[j],
        });
        rank = c.parent;
    }
    stages_rev.reverse();
    let pipeline = Pipeline { stages: stages_rev };
    let exact = pipeline.cost(cm, task, Phase::Both)?;
    Some(DpResult { pipeline, dp_cost: best_cost, exact_cost: exact })
}

/// Full §4.2+§4.3 pipeline optimizer for one device group: sweep stage
/// counts, alternate Algorithm-1 DP with the memory-proportional layer
/// partition (EM heuristic), return the best bound pipeline.
pub fn optimal_pipeline(
    cm: &CostModel,
    cluster: &Cluster,
    devices: &[DeviceId],
    task: &InferenceTask,
    max_stages: usize,
    max_tp: usize,
) -> Option<DpResult> {
    optimal_pipeline_opt(cm, cluster, devices, task, max_stages, max_tp, false)
}

/// [`optimal_pipeline`] with the `require_all` knob exposed.
pub fn optimal_pipeline_opt(
    cm: &CostModel,
    cluster: &Cluster,
    devices: &[DeviceId],
    task: &InferenceTask,
    max_stages: usize,
    max_tp: usize,
    require_all: bool,
) -> Option<DpResult> {
    let pool = GroupPool::new(cluster, devices);
    let l = cm.model.layers;
    let mut best: Option<DpResult> = None;
    let s_cap = max_stages.min(pool.total()).min(l);
    for s in 1..=s_cap {
        // Seed partitions for the EM alternation: the paper's even split,
        // plus a machine-memory-proportional split (the even split can be
        // memory-infeasible on strongly mixed pools — §3.1's A4000 stage —
        // which would strand the EM before its first M-step).
        let mut seeds: Vec<Vec<usize>> = vec![even_partition(l, s)];
        if let Some(p) = machine_memory_partition(cluster, devices, l, s) {
            if !seeds.contains(&p) {
                seeds.push(p);
            }
        }
        let mut local_best: Option<DpResult> = None;
        for seed in seeds {
            let mut partition = seed;
            // EM: DP under the current partition, then reshape the
            // partition by bound-stage memory. 3 rounds suffice.
            for _ in 0..3 {
                let Some(res) = solve_dp(cm, &pool, &partition, task, max_tp, require_all)
                else {
                    break;
                };
                let improved = local_best
                    .as_ref()
                    .map(|b| res.exact_cost < b.exact_cost)
                    .unwrap_or(true);
                if improved {
                    local_best = Some(res.clone());
                }
                let mem: Vec<f64> = res
                    .pipeline
                    .stages
                    .iter()
                    .map(|st| {
                        st.devices
                            .iter()
                            .map(|&d| cluster.devices[d].gpu.spec().memory_bytes)
                            .sum()
                    })
                    .collect();
                let new_partition = memory_proportional_partition(l, &mem);
                if new_partition == partition {
                    break;
                }
                partition = new_partition;
            }
        }
        if let Some(res) = local_best {
            let better = best
                .as_ref()
                .map(|b| res.exact_cost < b.exact_cost)
                .unwrap_or(true);
            if better {
                best = Some(res);
            }
        }
    }
    best
}

/// Memory-proportional seed partition: distribute layers over the `s`
/// largest-memory machines of the group (wrapping machine shares when
/// `s` exceeds the machine count).
fn machine_memory_partition(
    cluster: &Cluster,
    devices: &[DeviceId],
    layers: usize,
    s: usize,
) -> Option<Vec<usize>> {
    let mut mem_by_machine: std::collections::BTreeMap<usize, f64> =
        std::collections::BTreeMap::new();
    for &d in devices {
        *mem_by_machine.entry(cluster.devices[d].machine).or_insert(0.0) +=
            cluster.devices[d].gpu.spec().memory_bytes;
    }
    let mut mems: Vec<f64> = mem_by_machine.into_values().collect();
    mems.sort_by(|a, b| b.partial_cmp(a).unwrap());
    if s > layers {
        return None;
    }
    // One pseudo-stage per machine; extra stages split the largest shares.
    let mut shares: Vec<f64> = Vec::with_capacity(s);
    for i in 0..s {
        shares.push(mems[i % mems.len()] / ((s / mems.len()) as f64 + 1.0).max(1.0));
    }
    Some(memory_proportional_partition(layers, &shares))
}

/// Brute-force reference for tests: enumerate every ordered assignment of
/// homogeneous same-type prefix groups (the same search space as the DP)
/// and return the minimal exact Eq. 2 cost.
#[cfg(test)]
pub fn brute_force_reference(
    cm: &CostModel,
    pool: &GroupPool,
    layer_partition: &[usize],
    task: &InferenceTask,
    max_tp: usize,
) -> Option<f64> {
    fn recurse(
        cm: &CostModel,
        pool: &GroupPool,
        partition: &[usize],
        task: &InferenceTask,
        max_tp: usize,
        j: usize,
        used: TypeVec,
        stages: &mut Vec<Stage>,
        best: &mut Option<f64>,
    ) {
        if j == partition.len() {
            let p = Pipeline { stages: stages.clone() };
            if let Some(c) = p.cost(cm, task, Phase::Both) {
                if best.map(|b| c < b).unwrap_or(true) {
                    *best = Some(c);
                }
            }
            return;
        }
        for k in 0..NUM_TYPES {
            let avail = pool.caps[k] - used.0[k];
            for count in 1..=avail.min(max_tp) {
                if cm.model.heads % count != 0 {
                    continue;
                }
                let devices = pool.bind(k, used.0[k], count).to_vec();
                stages.push(Stage { devices, layers: partition[j] });
                let mut nu = used;
                nu.0[k] += count;
                recurse(cm, pool, partition, task, max_tp, j + 1, nu, stages, best);
                stages.pop();
            }
        }
    }
    let mut best = None;
    let mut stages = Vec::new();
    recurse(cm, pool, layer_partition, task, max_tp, 0, TypeVec::zero(), &mut stages, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::model::ModelSpec;

    #[test]
    fn case_study_dp_matches_paper_layout() {
        // §3.1: the paper's hand layout serves 4×A6000 | 2×A5000 | 2×A4000
        // as [4,2,2] with 48/20/12 layers. The DP must (a) be feasible on
        // the full pool, (b) never do worse than that hand layout under
        // the paper's own cost model, and (c) keep every TP group on a
        // single machine.
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let all: Vec<DeviceId> = (0..8).collect();
        let res =
            optimal_pipeline_opt(&cm, &c, &all, &t, 8, 8, true).expect("feasible");
        assert!(res.pipeline.validate(&m).is_ok());
        assert_eq!(res.pipeline.devices().len(), 8, "require_all honored");

        let paper = Pipeline {
            stages: vec![
                Stage { devices: vec![0, 1, 2, 3], layers: 48 },
                Stage { devices: vec![4, 5], layers: 20 },
                Stage { devices: vec![6, 7], layers: 12 },
            ],
        };
        let paper_cost = paper.cost(&cm, &t, Phase::Both).unwrap();
        assert!(
            res.exact_cost <= paper_cost * 1.0001,
            "DP {} worse than paper layout {paper_cost}",
            res.exact_cost
        );
        // Every TP group on one machine (the §4.2 heuristic).
        for s in &res.pipeline.stages {
            let m0 = c.devices[s.devices[0]].machine;
            assert!(s.devices.iter().all(|&d| c.devices[d].machine == m0));
        }
    }

    #[test]
    fn dp_equals_brute_force_on_small_pools() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let pool = GroupPool::new(&c, &(0..8).collect::<Vec<_>>());
        for partition in [vec![40, 40], vec![48, 20, 12], vec![30, 30, 20]] {
            let dp = solve_dp(&cm, &pool, &partition, &t, 8, false);
            let bf = brute_force_reference(&cm, &pool, &partition, &t, 8);
            match (dp, bf) {
                (Some(dp), Some(bf)) => {
                    // DP folds PP-comm along the best-known path, so it may
                    // be off the true optimum by path effects; exact cost
                    // must be within 10% of brute force here (and equal on
                    // these symmetric pools in practice).
                    assert!(
                        dp.exact_cost <= bf * 1.10 + 1e-9,
                        "partition {partition:?}: dp {} vs bf {bf}",
                        dp.exact_cost
                    );
                }
                (None, None) => {}
                (a, b) => panic!("feasibility mismatch {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn infeasible_when_memory_is_short() {
        // 2×A4000 alone cannot hold llama2-70b in any layout.
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let res = optimal_pipeline(&cm, &c, &[6, 7], &t, 8, 8);
        assert!(res.is_none());
    }

    #[test]
    fn homogeneous_pool_prefers_tp_on_nvlink() {
        // On 8×A100 with NVLink, TP=8 single stage should beat deep
        // pipelines for a single request.
        let c = cluster::homogeneous_a100();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 64);
        let res = optimal_pipeline(&cm, &c, &(0..8).collect::<Vec<_>>(), &t, 8, 8).unwrap();
        assert_eq!(res.pipeline.num_stages(), 1);
        assert_eq!(res.pipeline.stages[0].tp_degree(), 8);
    }

    #[test]
    fn pool_binding_is_machine_major() {
        let c = cluster::heterogeneous_half_price();
        // 3090Ti devices: 8+8 (Iceland) + 3+3 (Norway) = 22
        let all: Vec<DeviceId> = c.online_devices();
        let pool = GroupPool::new(&c, &all);
        let k = crate::cluster::GpuType::RTX3090TI.index();
        let first8 = pool.bind(k, 0, 8);
        let machine0 = c.devices[first8[0]].machine;
        assert!(first8.iter().all(|&d| c.devices[d].machine == machine0));
    }

    #[test]
    fn stage_count_exceeding_pool_is_none() {
        let c = cluster::case_study();
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::case_study();
        let pool = GroupPool::new(&c, &[0, 1]);
        assert!(solve_dp(&cm, &pool, &[30, 30, 20], &t, 8, false).is_none());
    }

    #[test]
    fn norway_style_split_works_across_machines() {
        // 3+3 3090Ti across two machines, type straddles machines: the DP
        // must still find a feasible multi-stage plan ([2,1,1,2]-like).
        let c = cluster::heterogeneous_half_price();
        let norway: Vec<DeviceId> = c
            .devices
            .iter()
            .filter(|d| c.regions[d.region].name == "norway")
            .map(|d| d.id)
            .collect();
        assert_eq!(norway.len(), 6);
        let m = ModelSpec::llama2_70b();
        let cm = CostModel::new(&c, &m);
        let t = InferenceTask::new(1, 128, 32);
        let res = optimal_pipeline_opt(&cm, &c, &norway, &t, 6, 8, true);
        let res = res.expect("6×24G = 144G total fits the 130G model + cache");
        assert!(res.pipeline.num_stages() >= 3, "{}", res.pipeline.strategy_string());
        assert_eq!(res.pipeline.total_layers(), 80);
        // No TP degree of 3 (heads=64 not divisible); paper found [2,1,1,2].
        assert!(res
            .pipeline
            .stages
            .iter()
            .all(|s| 64 % s.tp_degree() == 0));
    }
}
