//! Pipeline-planning flavor: HexGen's asymmetric planner vs the
//! symmetric-only ablation (§5.2 "HexGen w/o asymmetric parallel support").

/// Which per-group pipeline planner the GA uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelinePlanner {
    /// Full HexGen: per-stage layer counts and TP degrees may differ
    /// (Algorithm-1 DP).
    Asymmetric,
    /// Ablation: all stages share one TP degree and an even layer split —
    /// the FlashAttention/Megatron-style symmetric constraint.
    Symmetric,
}
